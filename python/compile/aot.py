"""AOT lowering: JAX decode steps -> HLO TEXT artifacts for the Rust runtime.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (behind
the `xla` crate) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo and its README.

Usage:  cd python && python -m compile.aot --out ../artifacts
Emits:
    sals_decode.hlo.txt    SALS decode step (Pallas kernels inlined)
    dense_decode.hlo.txt   dense-attention baseline step
    latent_score.hlo.txt   standalone stage-2 kernel (microbench)
    sparse_attn.hlo.txt    standalone stage-3 fused kernel (microbench)
    meta.txt               shape/config contract consumed by rust
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.latent_score import latent_score
from .kernels.sparse_recon_attn import sparse_recon_attn
from . import model as m


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default elides baked weight tensors to
    # "{...}", which XLA 0.5.1's text parser silently parses as ZEROS —
    # the executable then computes garbage. Full constants are mandatory.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO text contains elided constants"
    return text


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = m.DemoConfig()
    weights = m.init_weights(cfg, seed=args.seed)
    projectors = m.calibrate_projectors(cfg, weights, seed=args.seed + 1)

    i32 = jnp.int32
    f32 = jnp.float32
    tok = jax.ShapeDtypeStruct((), i32)
    pos = jax.ShapeDtypeStruct((), i32)
    klat = jax.ShapeDtypeStruct((cfg.n_layers, cfg.max_seq, cfg.rank), f32)
    kv = jax.ShapeDtypeStruct((cfg.n_layers, cfg.max_seq, cfg.kv_dim), f32)

    # ---- SALS decode step (weights + projectors baked as constants) ----
    sals_fn = functools.partial(m.sals_decode_step, cfg, weights, projectors)
    lowered = jax.jit(sals_fn).lower(tok, pos, klat, kv)
    write(os.path.join(args.out, "sals_decode.hlo.txt"), to_hlo_text(lowered))

    # ---- dense baseline step ----
    dense_fn = functools.partial(m.dense_decode_step, cfg, weights)
    lowered = jax.jit(dense_fn).lower(tok, pos, kv, kv)
    write(os.path.join(args.out, "dense_decode.hlo.txt"), to_hlo_text(lowered))

    # ---- standalone kernels for rust-side microbenches ----
    qlat = jax.ShapeDtypeStruct((cfg.rank,), f32)
    kcache1 = jax.ShapeDtypeStruct((cfg.max_seq, cfg.rank), f32)
    mask1 = jax.ShapeDtypeStruct((cfg.max_seq,), jnp.bool_)
    lowered = jax.jit(
        functools.partial(latent_score, r_star=cfg.r_star)
    ).lower(qlat, kcache1, mask1)
    write(os.path.join(args.out, "latent_score.hlo.txt"), to_hlo_text(lowered))

    q = jax.ShapeDtypeStruct((cfg.n_heads, cfg.head_dim), f32)
    ksel = jax.ShapeDtypeStruct((cfg.k_sel, cfg.rank), f32)
    vsel = jax.ShapeDtypeStruct((cfg.k_sel, cfg.n_heads, cfg.head_dim), f32)
    ut = jax.ShapeDtypeStruct((cfg.rank, cfg.kv_dim), f32)
    positions = jax.ShapeDtypeStruct((cfg.k_sel,), i32)
    posq = jax.ShapeDtypeStruct((), i32)
    selmask = jax.ShapeDtypeStruct((cfg.k_sel,), jnp.bool_)
    lowered = jax.jit(sparse_recon_attn).lower(q, ksel, vsel, ut, positions, posq, selmask)
    write(os.path.join(args.out, "sparse_attn.hlo.txt"), to_hlo_text(lowered))

    # ---- machine-readable contract for the rust loader ----
    meta = "\n".join([
        "sals-artifacts v1",
        f"vocab {cfg.vocab}",
        f"d_model {cfg.d_model}",
        f"n_layers {cfg.n_layers}",
        f"n_heads {cfg.n_heads}",
        f"head_dim {cfg.head_dim}",
        f"max_seq {cfg.max_seq}",
        f"rank {cfg.rank}",
        f"r_star {cfg.r_star}",
        f"k_sel {cfg.k_sel}",
        "",
    ])
    write(os.path.join(args.out, "meta.txt"), meta)


if __name__ == "__main__":
    main()

"""Pallas kernel: fused selective-reconstruction + RoPE + sparse attention
(paper §4.4/§4.5 — the Triton "fused reconstruct-RoPE kernel", re-thought
for TPU-shaped hardware).

One program fuses Algorithm 1 lines 6–9 for a decode step:

    K_C = K̃_C Uᵀ            # MXU matmul (k × r) @ (r × H·d)
    RoPE(q, pos_q); RoPE(K_C, positions)   # VPU elementwise
    p = softmax(q K_Cᵀ/√d);  y = p V_C      # MXU + VPU

Everything lives in VMEM for the whole program: with k = 512 selected
tokens, r = 256, H·d = 1024 the working set is K̃_C (512 KiB) + U (1 MiB)
+ V_C (2 MiB) + K_C (2 MiB) ≈ 5.5 MiB < 16 MiB VMEM, so the fusion needs
no spills — the paper's 7.69–14.28× HBM-traffic cut comes from reading only
(k·r + k·H·d + r·H·d) instead of the full 2·S·H·d cache. interpret=True is
mandatory on CPU PJRT (Mosaic custom-calls cannot run there).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(q_ref, klat_ref, v_ref, ut_ref, cosk_ref, sink_ref,
                  cosq_ref, sinq_ref, mask_ref, out_ref):
    h, d = q_ref.shape
    k = klat_ref.shape[0]
    half = d // 2

    # ---- reconstruction: K_C = K̃_C Uᵀ (MXU) ----
    k_sel = (klat_ref[...] @ ut_ref[...]).reshape(k, h, d)

    # ---- RoPE (VPU) ----
    cos_k = cosk_ref[...][:, None, :]   # (k, 1, d/2)
    sin_k = sink_ref[...][:, None, :]
    k1, k2 = k_sel[..., :half], k_sel[..., half:]
    k_rot = jnp.concatenate([k1 * cos_k - k2 * sin_k, k2 * cos_k + k1 * sin_k], axis=-1)

    q = q_ref[...]
    cos_q = cosq_ref[...]               # (1, d/2) broadcasts over heads
    sin_q = sinq_ref[...]
    q1, q2 = q[..., :half], q[..., half:]
    q_rot = jnp.concatenate([q1 * cos_q - q2 * sin_q, q2 * cos_q + q1 * sin_q], axis=-1)

    # ---- exact sparse attention (Eq. 5) ----
    scores = jnp.einsum("hd,khd->hk", q_rot, k_rot) / jnp.sqrt(float(d))
    scores = jnp.where(mask_ref[...][None, :], scores, -1e30)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / p.sum(axis=-1, keepdims=True)
    out_ref[...] = jnp.einsum("hk,khd->hd", p, v_ref[...])


@functools.partial(jax.jit, static_argnames=("rope_base",))
def sparse_recon_attn(q, k_sel_lat, v_sel, u_t, positions, pos_q, sel_mask,
                      rope_base: float = 10_000.0):
    """Fused sparse attention over a selected token set.

    Shapes: q (H, d); k_sel_lat (k, r); v_sel (k, H, d); u_t (r, H*d);
    positions (k,) int32; pos_q scalar int32; sel_mask (k,) bool.
    Returns (H, d).
    """
    h, d = q.shape
    half = d // 2
    # RoPE tables are computed in-graph (cheap) and handed to the kernel so
    # the kernel body stays a pure VMEM-resident fusion.
    freqs = rope_base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / d)
    theta_k = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos_k, sin_k = jnp.cos(theta_k), jnp.sin(theta_k)
    theta_q = jnp.asarray(pos_q, jnp.float32)[None, None] * freqs[None, :]
    cos_q, sin_q = jnp.cos(theta_q), jnp.sin(theta_q)

    return pl.pallas_call(
        _fused_kernel,
        out_shape=jax.ShapeDtypeStruct((h, d), jnp.float32),
        interpret=True,
    )(q, k_sel_lat, v_sel, u_t, cos_k, sin_k, cos_q, sin_q, sel_mask)

"""Pallas kernel: blocked latent-space token scoring (paper §4.3, stage 2).

Streams the latent key cache through VMEM in (BLOCK_S, r*) tiles and emits
the cheap approximate scores s_j = q̃[:r*] · k̃_j[:r*]. On a real TPU each
tile is one HBM→VMEM DMA and the dot products run on the VPU/MXU; under
interpret=True (CPU PJRT) the same program executes with numpy semantics,
which is the supported correctness path in this environment.

TPU sizing (DESIGN.md §Hardware-Adaptation / §Perf): with r* = 128 and
BLOCK_S = 512 the K-tile is 512×128×4B = 256 KiB — 2 tiles double-buffered
fit easily in 16 MiB VMEM alongside the resident q̃ (512 B).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 512


def _score_kernel(q_ref, k_ref, mask_ref, out_ref):
    """One grid step: score BLOCK_S tokens against the resident query."""
    q = q_ref[...]                        # (r*,) resident in VMEM
    k = k_ref[...]                        # (BLOCK_S, r*) streamed tile
    mask = mask_ref[...]                  # (BLOCK_S,)
    scores = k @ q                        # VPU/MXU dot per row
    out_ref[...] = jnp.where(mask, scores, -1e30)


@functools.partial(jax.jit, static_argnames=("r_star",))
def latent_score(q_lat, k_lat, length_mask, *, r_star: int):
    """Scores for every cached token.

    q_lat: (r,) full latent query (leading r* used).
    k_lat: (S, r) latent key cache; S must be a multiple of BLOCK_S or is
           padded by the caller (mask covers padding).
    length_mask: (S,) bool.
    Returns (S,) f32.
    """
    s, r = k_lat.shape
    assert r_star <= r, (r_star, r)
    q = q_lat[:r_star]
    k = k_lat[:, :r_star]
    block = min(BLOCK_S, s)
    if s % block != 0:
        pad = block - s % block
        k = jnp.pad(k, ((0, pad), (0, 0)))
        length_mask = jnp.pad(length_mask, (0, pad))
    grid = (k.shape[0] // block,)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_star,), lambda i: (0,)),           # q resident
            pl.BlockSpec((block, r_star), lambda i: (i, 0)),   # K streamed
            pl.BlockSpec((block,), lambda i: (i,)),            # mask tile
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k.shape[0],), jnp.float32),
        interpret=True,
    )(q, k, length_mask)
    return out[:s]

"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has a reference implementation here
written with plain jax.numpy ops; pytest (python/tests/test_kernel.py)
asserts allclose between kernel and oracle across a hypothesis-driven sweep
of shapes and data.
"""

import jax.numpy as jnp


def rope_tables(head_dim: int, positions, base: float = 10_000.0):
    """cos/sin tables for given integer positions, LLaMA rotate-half layout.

    Returns (cos, sin) of shape (len(positions), head_dim/2).
    """
    half = head_dim // 2
    freqs = base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / head_dim)
    theta = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(theta), jnp.sin(theta)


def apply_rope(x, cos, sin):
    """Rotate-half RoPE on the last dim. x: (..., head_dim)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _softmax_lastdim(scores):
    m = scores.max(-1, keepdims=True)
    p = jnp.exp(scores - m)
    return p / p.sum(-1, keepdims=True)


def latent_score_ref(q_lat, k_lat, length_mask):
    """Latent-space scores (paper §4.3): s_j = q̃[:r*] · k̃_j[:r*].

    q_lat: (r_star,) — already truncated to the scoring rank.
    k_lat: (S, r) latent key cache (full stored rank r).
    length_mask: (S,) bool; False positions score -1e30.
    Returns (S,) f32 scores.
    """
    r_star = q_lat.shape[0]
    scores = k_lat[:, :r_star] @ q_lat
    return jnp.where(length_mask, scores, -1e30)


def sparse_recon_attn_ref(q, k_sel_lat, v_sel, u_t, positions, pos_q, sel_mask,
                          rope_base: float = 10_000.0):
    """Fused selective-reconstruction sparse attention (Algorithm 1, 6–9).

    q:          (H, d) pre-RoPE query heads.
    k_sel_lat:  (k, r) gathered latent keys of the selected tokens.
    v_sel:      (k, H, d) gathered values.
    u_t:        (r, H*d) transposed projector (reconstruction matrix).
    positions:  (k,) int32 original positions of the selected tokens.
    pos_q:      scalar int32 query position.
    sel_mask:   (k,) bool; False entries are padding.
    Returns (H, d) attention output.
    """
    h, d = q.shape
    k = k_sel_lat.shape[0]
    # Reconstruct: K_C = K̃_C Uᵀ  -> (k, H, d)
    k_sel = (k_sel_lat @ u_t).reshape(k, h, d)
    # RoPE at original positions / query position.
    cos_k, sin_k = rope_tables(d, positions, rope_base)
    k_rot = apply_rope(k_sel, cos_k[:, None, :], sin_k[:, None, :])
    cos_q, sin_q = rope_tables(d, jnp.full((1,), pos_q, dtype=jnp.int32), rope_base)
    q_rot = apply_rope(q, cos_q, sin_q)
    # Exact attention over the selected set (Eq. 5).
    scores = jnp.einsum("hd,khd->hk", q_rot, k_rot) / jnp.sqrt(float(d))
    scores = jnp.where(sel_mask[None, :], scores, -1e30)
    probs = _softmax_lastdim(scores)
    return jnp.einsum("hk,khd->hd", probs, v_sel)


def full_attention_ref(q, keys, values, length_mask, pos_q, rope_base: float = 10_000.0):
    """Dense decode attention oracle: pre-RoPE keys (S, H, d), query (H, d)."""
    s, h, d = keys.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    cos_k, sin_k = rope_tables(d, positions, rope_base)
    k_rot = apply_rope(keys, cos_k[:, None, :], sin_k[:, None, :])
    cos_q, sin_q = rope_tables(d, jnp.full((1,), pos_q, dtype=jnp.int32), rope_base)
    q_rot = apply_rope(q, cos_q, sin_q)
    scores = jnp.einsum("hd,shd->hs", q_rot, k_rot) / jnp.sqrt(float(d))
    scores = jnp.where(length_mask[None, :], scores, -1e30)
    probs = _softmax_lastdim(scores)
    return jnp.einsum("hs,shd->hd", probs, values)

"""Layer 2: the SALS decode-step compute graph in JAX.

A small LLaMA-style decoder with the SALS attention path (latent scoring →
in-graph top-k → fused selective reconstruction) plus a dense baseline.
Weights and the calibrated projectors are baked into the lowered HLO as
constants, so the Rust side only moves token ids and caches.

Static shapes throughout (decode step with max_seq-sized caches) — this is
what lets jax.lax.top_k live inside the graph and the whole step lower to
one HLO module that `rust/src/runtime` compiles once and reuses.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.latent_score import latent_score
from .kernels.sparse_recon_attn import sparse_recon_attn
from .kernels import ref


@dataclass(frozen=True)
class DemoConfig:
    """Shape config of the AOT demo model (kept deliberately small: the e2e
    example drives hundreds of decode steps through PJRT-CPU)."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    max_seq: int = 512
    rank: int = 32          # r  (25% of kv_dim = n_heads*head_dim = 128)
    r_star: int = 16        # r* = r/2
    k_sel: int = 64         # selection budget (sink+recent+critical merged)
    sink: int = 4
    recent: int = 16
    rope_base: float = 10_000.0

    @property
    def kv_dim(self) -> int:
        return self.n_heads * self.head_dim


def init_weights(cfg: DemoConfig, seed: int = 0):
    """Seeded random weights as a pytree of jnp arrays."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8 * cfg.n_layers + 2)
    std = 1.0 / jnp.sqrt(cfg.d_model)
    i = iter(range(len(ks)))
    w = {
        "embedding": jax.random.normal(ks[next(i)], (cfg.vocab, cfg.d_model)) * 1.0,
        "layers": [],
    }
    # Real LLMs' pre-RoPE keys are empirically low-rank (the premise of §2.1
    # and Palu/Loki); random Gaussian wk would make them full-rank and
    # unrepresentative. Give wk an inner rank of rank/2 so the calibrated
    # rank-r projector captures the key energy the way it does on LLaMA.
    key_inner = max(2, cfg.rank // 2)
    for _ in range(cfg.n_layers):
        wk_a = jax.random.normal(ks[next(i)], (cfg.d_model, key_inner)) * std
        wk_b = jax.random.normal(jax.random.fold_in(ks[next(i)], 1), (key_inner, cfg.kv_dim))
        w["layers"].append({
            "wq": jax.random.normal(ks[next(i)], (cfg.d_model, cfg.kv_dim)) * std,
            "wk": wk_a @ wk_b / jnp.sqrt(key_inner),
            "wv": jax.random.normal(ks[next(i)], (cfg.d_model, cfg.kv_dim)) * std,
            "wo": jax.random.normal(ks[next(i)], (cfg.kv_dim, cfg.d_model)) * std,
            "w_gate": jax.random.normal(ks[next(i)], (cfg.d_model, cfg.d_ff)) * std,
            "w_up": jax.random.normal(ks[next(i)], (cfg.d_model, cfg.d_ff)) * std,
            "w_down": jax.random.normal(ks[next(i)], (cfg.d_ff, cfg.d_model)) / jnp.sqrt(cfg.d_ff),
        })
    return w


def _rmsnorm(x, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x) + eps)


def calibrate_projectors(cfg: DemoConfig, weights, n_tokens: int = 1024, seed: int = 1):
    """§4.2 offline calibration in JAX: run the dense model over random
    token streams, collect pre-RoPE keys per layer, eigendecompose KᵀK and
    keep the leading-r eigenvectors. Returns a list of (kv_dim, r) arrays."""
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (n_tokens,), 0, cfg.vocab)
    xs = weights["embedding"][tokens]          # (T, d_model)
    projs = []
    x = xs
    for lw in weights["layers"]:
        normed = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-5)
        k = normed @ lw["wk"]                  # (T, kv_dim) pre-RoPE keys
        c = k.T @ k
        _, vecs = jnp.linalg.eigh(c)           # ascending
        u = vecs[:, ::-1][:, : cfg.rank]       # (kv_dim, r) leading
        projs.append(u)
        # Cheap stream update so deeper layers see layer-mixed activations:
        # dense attention with uniform probs ≈ running mean (good enough for
        # covariance calibration of a random-weight model).
        v = normed @ lw["wv"]
        attn = jnp.cumsum(v, axis=0) / (jnp.arange(1, n_tokens + 1)[:, None])
        x = x + attn @ lw["wo"]
        g = x @ lw["w_gate"]
        x = x + (jax.nn.silu(g) * (x @ lw["w_up"])) @ lw["w_down"]
    return projs


def sals_decode_step(cfg: DemoConfig, weights, projectors,
                     token, pos, k_lat_cache, v_cache):
    """One SALS decode step.

    token: () int32; pos: () int32 (0-based position of this token)
    k_lat_cache: (L, S, r) latent key cache
    v_cache:     (L, S, kv_dim) value cache (fp32 in the XLA demo path;
                 quantized storage is exercised in the Rust backends)
    Returns (logits, new_k_lat_cache, new_v_cache).
    """
    h, d, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = weights["embedding"][token]
    new_klat, new_v = [], []
    idx = jnp.arange(s, dtype=jnp.int32)

    for layer, lw in enumerate(weights["layers"]):
        u = projectors[layer]                          # (kv, r)
        normed = _rmsnorm(x)
        q = (normed @ lw["wq"]).reshape(h, d)
        k = normed @ lw["wk"]                          # (kv,) pre-RoPE
        v = normed @ lw["wv"]

        # Stage 1: compress the new key into latent space; append (line 2–3).
        k_lat = k @ u                                   # (r,)
        kc = jax.lax.dynamic_update_slice(k_lat_cache[layer], k_lat[None, :], (pos, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[layer], v[None, :], (pos, 0))
        new_klat.append(kc)
        new_v.append(vc)

        # Stage 2: latent scoring (Pallas kernel) + top-k with sink/recent
        # bias (lines 4–5). Causal mask: positions > pos are invalid.
        valid = idx <= pos
        q_lat = q.reshape(-1) @ u                       # (r,)
        scores = latent_score(q_lat, kc, valid, r_star=cfg.r_star)
        is_sink = idx < cfg.sink
        is_recent = (idx + cfg.recent > pos) & valid
        biased = jnp.where(is_sink | is_recent, 1e30, scores)
        # top-k via full argsort: lowers to the classic `sort` HLO op, which
        # xla_extension 0.5.1's text parser accepts (jax.lax.top_k lowers to
        # a `topk(..., largest=true)` instruction it cannot parse).
        sel = jnp.argsort(-biased)[: cfg.k_sel]         # (k_sel,) indices
        sel_mask = valid[sel]

        # Stage 3: gather + fused reconstruct/RoPE/sparse-attention
        # (Pallas kernel, lines 6–9).
        k_sel_lat = kc[sel]                             # (k_sel, r)
        v_sel = vc[sel].reshape(cfg.k_sel, h, d)
        out = sparse_recon_attn(q, k_sel_lat, v_sel, u.T, sel, pos, sel_mask,
                                rope_base=cfg.rope_base)
        x = x + out.reshape(-1) @ lw["wo"]

        # FFN.
        normed = _rmsnorm(x)
        g = jax.nn.silu(normed @ lw["w_gate"]) * (normed @ lw["w_up"])
        x = x + g @ lw["w_down"]

    logits = weights["embedding"] @ _rmsnorm(x)
    return logits, jnp.stack(new_klat), jnp.stack(new_v)


def dense_decode_step(cfg: DemoConfig, weights, token, pos, k_cache, v_cache):
    """Baseline decode step with dense attention (GPT-fast stand-in).

    k_cache/v_cache: (L, S, kv_dim); keys cached pre-RoPE and rotated in the
    oracle for parity with the SALS path.
    """
    h, d, s = cfg.n_heads, cfg.head_dim, cfg.max_seq
    x = weights["embedding"][token]
    new_k, new_v = [], []
    idx = jnp.arange(s, dtype=jnp.int32)

    for lw in weights["layers"]:
        normed = _rmsnorm(x)
        q = (normed @ lw["wq"]).reshape(h, d)
        k = normed @ lw["wk"]
        v = normed @ lw["wv"]
        kc = jax.lax.dynamic_update_slice(k_cache[len(new_k)], k[None, :], (pos, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[len(new_v)], v[None, :], (pos, 0))
        new_k.append(kc)
        new_v.append(vc)
        valid = idx <= pos
        out = ref.full_attention_ref(q, kc.reshape(s, h, d), vc.reshape(s, h, d),
                                     valid, pos, rope_base=cfg.rope_base)
        x = x + out.reshape(-1) @ lw["wo"]
        normed = _rmsnorm(x)
        g = jax.nn.silu(normed @ lw["w_gate"]) * (normed @ lw["w_up"])
        x = x + g @ lw["w_down"]

    logits = weights["embedding"] @ _rmsnorm(x)
    return logits, jnp.stack(new_k), jnp.stack(new_v)

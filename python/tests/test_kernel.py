"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps shapes; every case asserts allclose between the Pallas
kernel (interpret mode) and the pure-jnp reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.latent_score import latent_score
from compile.kernels.sparse_recon_attn import sparse_recon_attn
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rnd(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- latent_score

@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(min_value=1, max_value=700),
    r=st.sampled_from([4, 8, 16, 32]),
    frac=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_latent_score_matches_ref(s, r, frac, seed):
    r_star = max(1, r // frac)
    q = rnd(seed, (r,))
    k = rnd(seed + 1, (s, r))
    length = int(jax.random.randint(jax.random.PRNGKey(seed + 2), (), 1, s + 1))
    mask = jnp.arange(s) < length
    got = latent_score(q, k, mask, r_star=r_star)
    want = ref.latent_score_ref(q[:r_star], k, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_latent_score_masks_invalid():
    q = jnp.ones((8,))
    k = jnp.ones((10, 8))
    mask = jnp.arange(10) < 3
    out = latent_score(q, k, mask, r_star=4)
    assert np.all(np.asarray(out[3:]) <= -1e29)
    assert np.all(np.isfinite(np.asarray(out[:3])))


def test_latent_score_non_multiple_of_block():
    # 700 is not a multiple of BLOCK_S=512: exercises the padding path.
    q = rnd(0, (16,))
    k = rnd(1, (700, 16))
    mask = jnp.ones((700,), bool)
    got = latent_score(q, k, mask, r_star=8)
    want = ref.latent_score_ref(q[:8], k, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- sparse_recon_attn

@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    k=st.integers(min_value=1, max_value=96),
    r=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_sparse_recon_attn_matches_ref(h, d, k, r, seed):
    q = rnd(seed, (h, d))
    klat = rnd(seed + 1, (k, r))
    v = rnd(seed + 2, (k, h, d))
    ut = rnd(seed + 3, (r, h * d), scale=0.3)
    kk = jax.random.PRNGKey(seed + 4)
    positions = jax.random.randint(kk, (k,), 0, 400).astype(jnp.int32)
    pos_q = jnp.asarray(400, jnp.int32)
    n_valid = int(jax.random.randint(jax.random.PRNGKey(seed + 5), (), 1, k + 1))
    mask = jnp.arange(k) < n_valid
    got = sparse_recon_attn(q, klat, v, ut, positions, pos_q, mask)
    want = ref.sparse_recon_attn_ref(q, klat, v, ut, positions, pos_q, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_sparse_attn_single_token_returns_value():
    # One valid token: softmax collapses to 1 -> out == its value.
    h, d, r = 2, 8, 4
    q = rnd(0, (h, d))
    klat = rnd(1, (1, r))
    v = rnd(2, (1, h, d))
    ut = rnd(3, (r, h * d))
    out = sparse_recon_attn(q, klat, v, ut,
                            jnp.zeros((1,), jnp.int32), jnp.asarray(5, jnp.int32),
                            jnp.ones((1,), bool))
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[0]), rtol=1e-5, atol=1e-6)


def test_sparse_attn_padding_is_ignored():
    # Identical valid prefix, garbage in the padding slots: same output.
    h, d, k, r = 2, 16, 12, 8
    q = rnd(0, (h, d))
    klat = rnd(1, (k, r))
    v = rnd(2, (k, h, d))
    ut = rnd(3, (r, h * d))
    pos = jnp.arange(k, dtype=jnp.int32)
    posq = jnp.asarray(99, jnp.int32)
    mask = jnp.arange(k) < 5
    out1 = sparse_recon_attn(q, klat, v, ut, pos, posq, mask)
    klat2 = klat.at[5:].set(1e3)
    v2 = v.at[5:].set(-1e3)
    out2 = sparse_recon_attn(q, klat2, v2, ut, pos, posq, mask)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


def test_full_rank_projector_recovers_dense_attention():
    # With r = H*d and U orthonormal (identity), selecting ALL tokens makes
    # the fused kernel equal to the dense oracle.
    h, d, s = 2, 8, 24
    kv = h * d
    q = rnd(0, (h, d))
    keys = rnd(1, (s, kv))
    v = rnd(2, (s, h, d))
    ut = jnp.eye(kv)
    pos = jnp.arange(s, dtype=jnp.int32)
    posq = jnp.asarray(s - 1, jnp.int32)
    mask = jnp.ones((s,), bool)
    got = sparse_recon_attn(q, keys, v, ut, pos, posq, mask)
    want = ref.full_attention_ref(q, keys.reshape(s, h, d), v, mask, s - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------- rope ref

def test_rope_ref_relative_property():
    d = 16
    q = rnd(0, (d,))
    k = rnd(1, (d,))

    def score(i, j):
        cq, sq = ref.rope_tables(d, jnp.array([i]))
        ck, sk = ref.rope_tables(d, jnp.array([j]))
        return float(ref.apply_rope(q, cq[0], sq[0]) @ ref.apply_rope(k, ck[0], sk[0]))

    assert score(9, 2) == pytest.approx(score(107, 100), rel=1e-4)

"""L2 model-graph tests: shapes, causality, SALS-vs-dense fidelity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def setup():
    cfg = m.DemoConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
                       d_ff=64, max_seq=64, rank=8, r_star=4, k_sel=16,
                       sink=2, recent=4)
    weights = m.init_weights(cfg, seed=3)
    projs = m.calibrate_projectors(cfg, weights, n_tokens=256, seed=4)
    return cfg, weights, projs


def empty_caches(cfg):
    klat = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.rank))
    v = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.kv_dim))
    return klat, v


def decode_seq(cfg, weights, projs, tokens):
    klat, v = empty_caches(cfg)
    logits = None
    for pos, t in enumerate(tokens):
        logits, klat, v = m.sals_decode_step(
            cfg, weights, projs, jnp.asarray(t, jnp.int32),
            jnp.asarray(pos, jnp.int32), klat, v)
    return logits, klat, v


def test_shapes_and_finiteness(setup):
    cfg, weights, projs = setup
    logits, klat, v = decode_seq(cfg, weights, projs, [1, 2, 3])
    assert logits.shape == (cfg.vocab,)
    assert klat.shape == (cfg.n_layers, cfg.max_seq, cfg.rank)
    assert v.shape == (cfg.n_layers, cfg.max_seq, cfg.kv_dim)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_deterministic(setup):
    cfg, weights, projs = setup
    a, _, _ = decode_seq(cfg, weights, projs, [5, 6, 7, 8])
    b, _, _ = decode_seq(cfg, weights, projs, [5, 6, 7, 8])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_causality_future_cache_slots_ignored(setup):
    # Poisoning cache slots beyond the current position must not change the
    # output (the causal mask + selection must never look there).
    cfg, weights, projs = setup
    tokens = [3, 1, 4]
    logits, klat, v = decode_seq(cfg, weights, projs, tokens)
    klat2, v2 = empty_caches(cfg)
    klat2 = klat2.at[:, len(tokens):, :].set(1e3)
    v2 = v2.at[:, len(tokens):, :].set(-1e3)
    out = None
    for pos, t in enumerate(tokens):
        out, klat2, v2 = m.sals_decode_step(
            cfg, weights, projs, jnp.asarray(t, jnp.int32),
            jnp.asarray(pos, jnp.int32), klat2, v2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(logits), rtol=1e-5, atol=1e-5)


def test_cache_rows_written_at_position(setup):
    cfg, weights, projs = setup
    _, klat, v = decode_seq(cfg, weights, projs, [9, 8, 7])
    # Rows 0..2 non-zero, rows 3.. all zero.
    assert np.any(np.asarray(klat[:, :3, :]) != 0)
    assert np.all(np.asarray(klat[:, 3:, :]) == 0)
    assert np.all(np.asarray(v[:, 3:, :]) == 0)


def test_sals_close_to_dense_when_selection_covers_everything(setup):
    # k_sel >= seq_len and full-rank latent space -> SALS == dense baseline.
    cfg0, weights, _ = setup
    cfg = m.DemoConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
                       d_ff=64, max_seq=64, rank=32, r_star=32, k_sel=16,
                       sink=2, recent=4)
    # Full-rank "projector": identity (kv_dim == rank).
    projs = [jnp.eye(cfg.kv_dim) for _ in range(cfg.n_layers)]
    tokens = [1, 2, 3, 4, 5]
    klat = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.rank))
    v = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.kv_dim))
    kd = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.kv_dim))
    vd = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.kv_dim))
    for pos, t in enumerate(tokens):
        tt, pp = jnp.asarray(t, jnp.int32), jnp.asarray(pos, jnp.int32)
        sl, klat, v = m.sals_decode_step(cfg, weights, projs, tt, pp, klat, v)
        dl, kd, vd = m.dense_decode_step(cfg, weights, tt, pp, kd, vd)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(dl), rtol=1e-3, atol=1e-3)


def test_dense_baseline_shapes(setup):
    cfg, weights, _ = setup
    kd = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.kv_dim))
    vd = jnp.zeros((cfg.n_layers, cfg.max_seq, cfg.kv_dim))
    logits, kd, vd = m.dense_decode_step(
        cfg, weights, jnp.asarray(1, jnp.int32), jnp.asarray(0, jnp.int32), kd, vd)
    assert logits.shape == (cfg.vocab,)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_calibrated_projectors_orthonormal(setup):
    cfg, _, projs = setup
    for u in projs:
        utu = np.asarray(u.T @ u)
        np.testing.assert_allclose(utu, np.eye(cfg.rank), atol=1e-4)

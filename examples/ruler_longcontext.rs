//! Long-context retrieval accuracy across methods and context lengths —
//! the RULER-style scaling story (paper §5.4) on the constructed model.
//!
//! Run: cargo run --release --example ruler_longcontext [--ctx 512] [--trials 8]

use sals::harness::{pct, Experiment, Table};
use sals::model::Method;
use sals::util::cli::Args;
use sals::util::rng::Rng;
use sals::workload::ruler::{generate, RulerTask};
use sals::workload::runner;

fn main() {
    let args = Args::from_env();
    let trials: usize = args.get_or("trials", 8);
    let lengths: Vec<usize> = match args.get("ctx") {
        Some(s) => vec![s.parse().expect("bad --ctx")],
        None => vec![128, 256, 512],
    };

    for ctx in lengths {
        let exp = Experiment::new(ctx, false, 0xE2E ^ ctx as u64);
        let mut rng = Rng::new(ctx as u64);
        let mut suite = Vec::new();
        for _ in 0..trials {
            suite.extend(generate(&exp.rm, RulerTask::S2, ctx, &mut rng));
            suite.extend(generate(&exp.rm, RulerTask::Mk1, ctx, &mut rng));
        }
        let mut table = Table::new(
            &format!("retrieval accuracy at context {ctx} (S2 + MK1, {} trials)", suite.len()),
            &["Method", "accuracy", "mem access vs dense"],
        );
        let mut base_read = 0.0;
        for method in [
            Method::Full,
            Method::Sals25,
            Method::Sals125,
            Method::Quest,
            Method::StreamingLlm,
        ] {
            let factory = exp.factory(method);
            let res = runner::evaluate(&exp.rm, &exp.model, &factory, &suite, 0);
            if method == Method::Full {
                base_read = res.read_bytes as f64;
            }
            table.row(vec![
                method.name().to_string(),
                pct(res.accuracy()),
                format!("{:.2}", res.read_bytes as f64 / base_read),
            ]);
        }
        table.print();
    }
}

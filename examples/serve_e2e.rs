//! END-TO-END driver: the full three-layer stack on a real serving workload.
//!
//! L1 (Pallas kernels, interpret) → lowered inside L2 (JAX decode-step
//! graphs) → AOT HLO artifacts → loaded here by the L3 Rust coordinator,
//! which routes a Poisson request trace across engine replicas and serves
//! batched greedy decoding with both the SALS and the dense (GPT-fast
//! analog) executables, reporting latency + throughput + KV residency.
//!
//! Run after `make artifacts`:  cargo run --release --example serve_e2e
//! Results recorded in EXPERIMENTS.md §E2E.

use sals::coordinator::{Policy, Router, TraceGen, TraceSpec};
use sals::runtime::{ArtifactRuntime, XlaModel, XlaVariant};
use sals::util::stats::Summary;
use std::time::Instant;

fn serve(variant: XlaVariant, label: &str) -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt = ArtifactRuntime::new(&dir)?;
    let probe = XlaModel::new(&mut rt, &dir, variant)?;
    let meta = probe.meta.clone();
    println!("\n--- {label}: platform={} vocab={} L={} max_seq={} ---",
        rt.platform(), meta.vocab, meta.n_layers, meta.max_seq);

    // Request trace: Poisson arrivals, mixed prompt lengths.
    let spec = TraceSpec {
        n_requests: 12,
        rate: 8.0,
        prompt_min: 8,
        prompt_max: 48,
        new_tokens_min: 4,
        new_tokens_max: 12,
        vocab: meta.vocab,
        seed: 99,
    };
    let trace = TraceGen::generate(&spec);

    // Router spreads sequences over 2 replica slots (each slot = one cache
    // set over the shared compiled executable).
    let mut router = Router::new(2, Policy::LeastLoaded);
    let mut replicas: Vec<XlaModel> = (0..2)
        .map(|_| XlaModel::new(&mut rt, &dir, variant).unwrap())
        .collect();

    let t0 = Instant::now();
    let mut total_new = 0usize;
    let mut latencies = Vec::new();
    let mut kv_bytes_peak = 0usize;
    for tr in &trace {
        let r = router.route(&tr.request, None);
        let m = &mut replicas[r];
        // A replica slot serves sequences back-to-back (reset between).
        if m.pos + tr.request.prompt.len() + tr.request.params.max_new_tokens >= m.meta.max_seq {
            m.reset();
        }
        let t_req = Instant::now();
        let out = m.generate(&rt, &tr.request.prompt, tr.request.params.max_new_tokens)?;
        latencies.push(t_req.elapsed().as_secs_f64());
        total_new += out.len();
        kv_bytes_peak = kv_bytes_peak.max(m.kv_bytes_at_len());
        router.complete(r, &tr.request);
    }
    let wall = t0.elapsed().as_secs_f64();
    let lat = Summary::of(&latencies);
    println!("requests: {}   new tokens: {total_new}   wall: {wall:.2}s", trace.len());
    println!("throughput: {:.1} tok/s   latency p50 {:.0}ms p99 {:.0}ms",
        total_new as f64 / wall, lat.p50 * 1e3, lat.p99 * 1e3);
    println!("peak per-seq KV residency: {} KiB ({} keys width)",
        kv_bytes_peak / 1024,
        if variant == XlaVariant::Sals { meta.rank } else { meta.kv_dim() });
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }
    serve(XlaVariant::Dense, "dense decode (GPT-fast analog)")?;
    serve(XlaVariant::Sals, "SALS decode (latent cache + sparse attention)")?;
    println!("\nNOTE: PJRT-CPU with interpret-mode Pallas is a correctness platform; the");
    println!("architecture (python never on the request path) is what this example proves.");
    Ok(())
}

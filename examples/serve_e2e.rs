//! END-TO-END driver: the full serving stack on a real request workload.
//!
//! Part 1 — the replica cluster (pure Rust, no artifacts needed): a
//! `Coordinator` owns 4 `Engine` replicas on worker threads, prices every
//! dispatch in projected `SequenceFootprint` bytes at the decode horizon,
//! bin-packs admissions, re-routes preemptions, and places warm prompts on
//! the replica that published their prefix. A Poisson trace is submitted
//! open-loop at its arrival offsets — the replica workers decode in the
//! background while the driver is still sleeping between arrivals.
//!
//! Part 2 — the artifact path: L1 (Pallas kernels, interpret) → lowered
//! inside L2 (JAX decode-step graphs) → AOT HLO artifacts → loaded by the
//! L3 runtime and served per-variant (SALS vs dense), reporting latency,
//! throughput, and KV residency.
//!
//! Run after `make artifacts`:  cargo run --release --example serve_e2e
//! Results recorded in EXPERIMENTS.md §E2E.

use sals::coordinator::{
    ClusterConfig, Coordinator, EngineConfig, Policy, Router, TraceGen, TraceSpec,
};
use sals::model::{
    calibrate, fit_calibration, make_factory, Method, Model, ModelConfig, SparsityParams, Weights,
};
use sals::runtime::{ArtifactRuntime, XlaModel, XlaVariant};
use sals::util::rng::Rng;
use sals::util::stats::Summary;
use std::sync::Arc;
use std::time::Instant;

/// Part 1: serve a Poisson trace through the 4-replica cluster on the CPU
/// SALS backend. Everything here is the production admission path —
/// footprint pricing, bin-packing, preemption re-route, drift ledger.
fn serve_cluster() -> anyhow::Result<()> {
    let cfg = ModelConfig {
        vocab: 512,
        d_model: 256,
        n_layers: 6,
        n_heads: 8,
        n_kv_heads: 8,
        head_dim: 32,
        d_ff: 512,
        max_seq: 256,
        rope_base: 10_000.0,
        dense_layers: vec![0],
        rms_eps: 1e-5,
    };
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 88)));

    // Calibrate the latent projections once; every replica's backends are
    // built from the same fitted parameters.
    let mut rng = Rng::new(4242);
    let streams: Vec<Vec<usize>> =
        (0..2).map(|_| (0..128).map(|_| rng.below(cfg.vocab)).collect()).collect();
    let fitted = Arc::new(fit_calibration(&cfg, &calibrate(&model, &streams)));
    let sp = SparsityParams::scaled(cfg.max_seq);

    let spec = TraceSpec {
        n_requests: 24,
        rate: 8.0,
        prompt_min: 16,
        prompt_max: 128,
        new_tokens_min: 8,
        new_tokens_max: 32,
        vocab: cfg.vocab,
        seed: 99,
    };
    let trace = TraceGen::generate(&spec);

    let mut cluster = Coordinator::new(
        model,
        make_factory(Method::Sals25, &fitted, sp),
        ClusterConfig {
            replicas: 4,
            engine: EngineConfig {
                max_batch: 8,
                prefill_chunk: 32,
                page_bytes: 4096,
                pool_budget: 8 << 20,
                threads: 1,
                prefix_reuse: true,
                eject_preempted: false, // forced on by the coordinator
            },
            bin_pack_window: 16,
        },
    );

    println!("--- cluster: 4 SALS replicas, footprint routing, open-loop trace ---");
    let t0 = Instant::now();
    for tr in &trace {
        // Open-loop: hold each request until its arrival offset; replicas
        // keep decoding earlier admissions in the background meanwhile.
        let until = std::time::Duration::from_secs_f64(tr.at_s);
        if let Some(wait) = until.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        cluster.submit(tr.request.clone())?;
    }
    let responses = cluster.run_to_completion();
    let wall = t0.elapsed().as_secs_f64();

    let cm = cluster.metrics();
    let agg = cm.aggregate();
    let ttft = agg.ttft_summary();
    let total_new: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let (drift_lo, drift_hi) = cm.drift_bounds();
    println!("requests: {}   new tokens: {total_new}   wall: {wall:.2}s", responses.len());
    println!(
        "throughput: {:.1} tok/s   TTFT p50 {:.0}ms p99 {:.0}ms",
        total_new as f64 / wall,
        ttft.p50 * 1e3,
        ttft.p99 * 1e3
    );
    println!(
        "routing: {} dispatched, {} fcfs bypasses, {} prefix-hint hits, {} preemption re-routes",
        cm.dispatched, cm.fcfs_bypasses, cm.prefix_hint_hits, cm.preemption_reroutes
    );
    println!(
        "footprint drift (actual/projected): mean {:.3} in [{:.3}, {:.3}] over {} requests",
        cm.mean_drift(),
        drift_lo,
        drift_hi,
        cm.drift.len()
    );
    Ok(())
}

/// Part 2: the artifact path — compiled HLO executables served
/// back-to-back per replica slot (each slot = one cache set over the
/// shared executable; no engine, so routing uses the bare token-count
/// `Router` that predates footprint pricing).
fn serve_artifacts(variant: XlaVariant, label: &str) -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt = ArtifactRuntime::new(&dir)?;
    let probe = XlaModel::new(&mut rt, &dir, variant)?;
    let meta = probe.meta.clone();
    println!("\n--- {label}: platform={} vocab={} L={} max_seq={} ---",
        rt.platform(), meta.vocab, meta.n_layers, meta.max_seq);

    let spec = TraceSpec {
        n_requests: 12,
        rate: 8.0,
        prompt_min: 8,
        prompt_max: 48,
        new_tokens_min: 4,
        new_tokens_max: 12,
        vocab: meta.vocab,
        seed: 99,
    };
    let trace = TraceGen::generate(&spec);

    let mut router = Router::new(2, Policy::LeastLoaded);
    let mut replicas: Vec<XlaModel> = (0..2)
        .map(|_| XlaModel::new(&mut rt, &dir, variant).unwrap())
        .collect();

    let t0 = Instant::now();
    let mut total_new = 0usize;
    let mut latencies = Vec::new();
    let mut kv_bytes_peak = 0usize;
    for tr in &trace {
        let r = router.route(&tr.request, None);
        let m = &mut replicas[r];
        if m.pos + tr.request.prompt.len() + tr.request.params.max_new_tokens >= m.meta.max_seq {
            m.reset();
        }
        let t_req = Instant::now();
        let out = m.generate(&rt, &tr.request.prompt, tr.request.params.max_new_tokens)?;
        latencies.push(t_req.elapsed().as_secs_f64());
        total_new += out.len();
        kv_bytes_peak = kv_bytes_peak.max(m.kv_bytes_at_len());
        router.complete(r, &tr.request);
    }
    let wall = t0.elapsed().as_secs_f64();
    let lat = Summary::of(&latencies);
    println!("requests: {}   new tokens: {total_new}   wall: {wall:.2}s", trace.len());
    println!("throughput: {:.1} tok/s   latency p50 {:.0}ms p99 {:.0}ms",
        total_new as f64 / wall, lat.p50 * 1e3, lat.p99 * 1e3);
    println!("peak per-seq KV residency: {} KiB ({} keys width)",
        kv_bytes_peak / 1024,
        if variant == XlaVariant::Sals { meta.rank } else { meta.kv_dim() });
    Ok(())
}

fn main() -> anyhow::Result<()> {
    serve_cluster()?;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.txt").exists() {
        eprintln!("\nartifacts/ missing — skipping the XLA variants (run `make artifacts`)");
        return Ok(());
    }
    serve_artifacts(XlaVariant::Dense, "dense decode (GPT-fast analog)")?;
    serve_artifacts(XlaVariant::Sals, "SALS decode (latent cache + sparse attention)")?;
    println!("\nNOTE: PJRT-CPU with interpret-mode Pallas is a correctness platform; the");
    println!("architecture (python never on the request path) is what this example proves.");
    Ok(())
}

//! Quickstart: the SALS pipeline on synthetic data in ~60 lines of API.
//!
//! 1. Calibrate a latent projector on pre-RoPE keys (§4.2).
//! 2. Build a SALS attention backend and a dense baseline.
//! 3. Stream a 4k-token cache, decode one step, and compare accuracy,
//!    resident cache size, and memory traffic.
//!
//! Run: cargo run --release --example quickstart

use sals::attention::traffic::sals_speedup_model;
use sals::attention::{AttentionBackend, AttnShape, FullAttention, SalsAttention, SalsConfig};
use sals::lowrank::Calibrator;
use sals::util::rng::Rng;

fn main() {
    // LLaMA2-ish layer shape, scaled: 8 heads × 64 dims, 4k context.
    // rope_base raised as in long-context models (LLaMA3 uses 5e5) so the
    // upper half of each head's dims rotates slowly across 4k positions.
    let seq = 4096;
    let mut shape = AttnShape::mha(8, 64, seq + 8);
    shape.rope_base = 1.0e8;
    let kv_dim = shape.kv_dim();
    let mut rng = Rng::new(7);

    // Key generator with genuine low-rank structure (real LLM keys are
    // low-rank in the hidden dimension — the paper's premise). Content
    // lives in the slow-rotating RoPE dims of each head (pairs i ≥ d/4),
    // the mechanism trained models use for content-matching across
    // positions (cf. DESIGN.md §Hardware-Adaptation notes on RoPE).
    let d = shape.head_dim;
    let slow: Vec<usize> = (0..shape.n_kv_heads)
        .flat_map(|h| {
            let base = h * d;
            (d / 4..d / 2).map(move |i| base + i).chain((3 * d / 4..d).map(move |i| base + i))
        })
        .collect();
    let basis: Vec<Vec<f32>> = (0..kv_dim / 8)
        .map(|_| {
            let mut b = vec![0.0f32; kv_dim];
            for &i in &slow {
                b[i] = rng.normal_f32();
            }
            b
        })
        .collect();
    let sample_key = {
        let basis = basis.clone();
        move |rng: &mut Rng| {
            let mut k = vec![0.0f32; kv_dim];
            for b in &basis {
                sals::tensor::ops::axpy(rng.normal_f32(), b, &mut k);
            }
            k
        }
    };

    // 1) Offline calibration: fit U_r from streamed pre-RoPE keys.
    let rank = kv_dim / 4; // SALS-25%
    let mut cal = Calibrator::new(kv_dim);
    for _ in 0..512 {
        let k = sample_key(&mut rng);
        cal.add_key(&k);
    }
    let projector = cal.fit(rank).unwrap();
    println!("calibrated projector: dim={} rank={} captured energy={:.1}%",
        projector.dim, projector.rank, 100.0 * projector.captured_energy());

    // 2) Backends: SALS-25% vs dense.
    let cfg = SalsConfig::sals_25(kv_dim, 16, seq / 8, 64);
    let mut sals = SalsAttention::new(shape, cfg, projector);
    let mut full = FullAttention::new(shape);

    // 3) Stream the cache and decode one step.
    let target = 1234;
    let mut target_key = vec![0.0f32; kv_dim];
    for t in 0..seq {
        let k = sample_key(&mut rng);
        let v = rng.normal_vec(kv_dim, 1.0);
        if t == target {
            target_key.copy_from_slice(&k);
        }
        sals.append(&k, &v);
        full.append(&k, &v);
    }
    // Decode query aligned with a specific cached token (content-dominated
    // attention, as in retrieval-heavy workloads): SALS must find it.
    // Slow-dim content survives the relative rotation, so the pre-RoPE
    // latent ranking and the exact post-RoPE attention agree.
    let mut q = target_key.clone();
    for (qi, ni) in q.iter_mut().zip(sample_key(&mut rng)) {
        *qi = 2.0 * *qi + 0.15 * ni;
    }
    let q_full: Vec<f32> = (0..shape.q_dim()).map(|i| q[i % kv_dim]).collect();
    let mut out_sals = vec![0.0f32; shape.q_dim()];
    let mut out_full = vec![0.0f32; shape.q_dim()];
    let f0 = full.traffic().read;
    full.attend(&q_full, &mut out_full);
    let s0 = sals.traffic().read;
    sals.attend(&q_full, &mut out_sals);

    let cos = sals::util::stats::cosine(&out_sals, &out_full);
    let full_read = full.traffic().read - f0;
    let sals_read = sals.traffic().read - s0;
    println!("\nattention output cosine vs dense: {cos:.4}");
    println!("resident cache:  dense {} KiB  vs  SALS {} KiB  ({:.1}% of dense)",
        full.kv_bytes() / 1024,
        sals.kv_bytes() / 1024,
        100.0 * sals.kv_bytes() as f64 / full.kv_bytes() as f64);
    println!("decode-step cache traffic: dense {} KiB  vs  SALS {} KiB  ({:.1}x less)",
        full_read / 1024,
        sals_read / 1024,
        full_read as f64 / sals_read as f64);
    println!("§4.5 model predicts {:.1}x",
        sals_speedup_model(seq, kv_dim, rank, rank / 2, seq / 8));
}

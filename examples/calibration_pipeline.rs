//! The offline calibration pipeline end to end (§4.2): run a model over
//! calibration streams, collect pre-RoPE keys, fit the joint projector,
//! inspect spectrum/energy/rank, save to disk, reload, verify.
//!
//! Run: cargo run --release --example calibration_pipeline

use sals::linalg::rank_at_energy;
use sals::lowrank::{reconstruction_error, Calibrator, Projector};
use sals::model::{calibrate, Model, ModelConfig, Weights};
use sals::tensor::Mat;
use sals::util::rng::Rng;
use std::sync::Arc;

fn main() {
    // A small LLaMA-shaped model with low-rank key projections.
    let cfg = ModelConfig::tiny_mha(256);
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 5)));
    let mut rng = Rng::new(55);
    let streams: Vec<Vec<usize>> =
        (0..8).map(|_| (0..128).map(|_| rng.below(cfg.vocab)).collect()).collect();

    println!("collecting pre-RoPE keys over {} streams x 128 tokens ...", streams.len());
    let calib = calibrate(&model, &streams);

    let out_dir = std::path::Path::new("artifacts");
    std::fs::create_dir_all(out_dir).ok();
    for (l, lc) in calib.layers.iter().enumerate() {
        let mut cal = Calibrator::new(cfg.kv_dim());
        cal.add_keys(&lc.pre_keys.data);
        let rank = cfg.kv_dim() / 4;
        let proj = cal.fit(rank).unwrap();
        let keys = Mat::from_vec(lc.pre_keys.rows, cfg.kv_dim(), lc.pre_keys.data.clone());
        let err = reconstruction_error(&proj, &keys);
        println!(
            "layer {l}: rank {rank}/{}  energy {:.1}%  rank90 {}  recon rel-err {:.4}",
            cfg.kv_dim(),
            100.0 * proj.captured_energy(),
            rank_at_energy(&proj.spectrum, 90.0),
            err
        );
        let path = out_dir.join(format!("projector_layer{l}.txt"));
        proj.save(&path).unwrap();
        let loaded = Projector::load(&path).unwrap();
        assert_eq!(loaded.rank, proj.rank);
        let err2 = reconstruction_error(&loaded, &keys);
        assert!((err - err2).abs() < 1e-9, "save/load changed the projector");
    }
    println!("projectors saved to artifacts/projector_layer*.txt and verified after reload");
}

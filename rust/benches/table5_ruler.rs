//! Table 5: RULER subtasks (S1..QA2) — baseline vs SALS-25%/12.5%.
//!
//! Paper shape: SALS-25% ≈ baseline everywhere; SALS-12.5% degrades most
//! on MK2 (heavy multi-key interference) while staying stable on FEW/QA.

use sals::harness::{pct, Experiment, Table};
use sals::model::Method;
use sals::util::rng::Rng;
use sals::workload::ruler::{generate, RulerTask};
use sals::workload::runner;

fn main() {
    let ctx = 384;
    let exp = Experiment::new(ctx, true, 515151); // GQA = LLaMA3.1-analog
    let mut rng = Rng::new(1111);
    let tasks = RulerTask::all();
    let suites: Vec<Vec<sals::workload::Trial>> = tasks
        .iter()
        .map(|&t| {
            let mut trials = Vec::new();
            for _ in 0..8 {
                trials.extend(generate(&exp.rm, t, ctx, &mut rng));
            }
            trials
        })
        .collect();

    let mut header: Vec<&str> = vec!["Method", "avg"];
    let names: Vec<String> = tasks.iter().map(|t| t.name().to_string()).collect();
    header.extend(names.iter().map(|s| s.as_str()));
    let mut table = Table::new("Table 5 — RULER proxies (GQA retrieval model)", &header);

    for method in [Method::Full, Method::Sals25, Method::Sals125] {
        let factory = exp.factory(method);
        let mut accs = Vec::new();
        for suite in &suites {
            let res = runner::evaluate(&exp.rm, &exp.model, &factory, suite, 0);
            accs.push(res.accuracy());
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![method.name().to_string(), pct(avg)];
        for a in &accs {
            row.push(pct(*a));
        }
        table.row(row);
    }
    table.print();
    println!("\npaper: baseline 81.60, SALS-25% 80.81 (≈parity), SALS-12.5% 75.86 with MK2 42.2 (worst drop)");
}

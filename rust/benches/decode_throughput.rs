//! Decode throughput: cross-sequence batched decode (`Model::decode_batch`)
//! vs the per-sequence `step()` loop at batch sizes {1, 4, 16}, full vs
//! SALS backends.
//!
//! The model is sized so the per-step weight stream (~58 MB fp32) exceeds
//! typical LLC capacity — decode is then memory-bound on weights, which is
//! exactly the regime where stacking sequences into one (batch, d) matmul
//! pays: the weights stream once per engine step instead of once per
//! sequence. Both paths run single-threaded (`BatchScratch` threads = 1)
//! so the comparison isolates batching (not core count); the engine's
//! threaded decode splits rows across workers and streams weights once
//! per worker block, which this bench deliberately does not measure. The
//! acceptance signal is tokens/sec/sequence at batch 16 beating batch 1
//! on the batched path.
//!
//! Emits `BENCH_decode.json` in the working directory so the decode perf
//! trajectory accumulates across PRs. `SALS_BENCH_QUICK=1` shortens the
//! decode run (same batch grid).

use sals::attention::{AttentionBackend, FullAttention, SalsAttention, SalsConfig};
use sals::harness::Table;
use sals::lowrank::Calibrator;
use sals::model::{BackendFactory, BatchScratch, Model, ModelConfig, Scratch, SequenceState, Weights};
use sals::quant::Bits;
use sals::util::json::Json;
use sals::util::rng::Rng;
use sals::util::timer::time_once;
use std::sync::Arc;

const PROMPT_LEN: usize = 16;
const BATCHES: [usize; 3] = [1, 4, 16];

/// GQA decoder big enough that streaming the weights dominates a decode
/// step (d_model 384, ~14.5M params ≈ 58 MB fp32); attention stays cheap
/// (short sequences), so the measurement isolates the projection matmuls.
fn cfg(max_seq: usize) -> ModelConfig {
    ModelConfig {
        vocab: 4096,
        d_model: 384,
        n_layers: 6,
        n_heads: 6,
        n_kv_heads: 2,
        head_dim: 64,
        d_ff: 1536,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: ModelConfig::default_dense_layers(6),
        rms_eps: 1e-5,
    }
}

fn full_factory(c: &ModelConfig) -> Box<BackendFactory> {
    let shape = c.attn_shape();
    Box::new(move |_| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>)
}

fn sals_factory(c: &ModelConfig) -> Box<BackendFactory> {
    let shape = c.attn_shape();
    let kvd = c.kv_dim();
    // Projector calibrated on a low-rank key family (real keys are
    // low-rank; exactness is irrelevant to throughput).
    let mut rng = Rng::new(11);
    let basis: Vec<Vec<f32>> = (0..kvd / 8).map(|_| rng.normal_vec(kvd, 1.0)).collect();
    let mut cal = Calibrator::new(kvd);
    let mut row = vec![0.0f32; kvd];
    for _ in 0..256 {
        row.fill(0.0);
        for b in &basis {
            sals::tensor::ops::axpy(rng.normal_f32(), b, &mut row);
        }
        cal.add_key(&row);
    }
    let rank = (kvd / 4).max(2);
    let proj = cal.fit(rank).unwrap();
    let sc = SalsConfig {
        rank,
        r_star: (kvd / 8).max(1),
        sink: 4,
        recent: 16,
        critical: 32,
        v_bits: Bits::B4,
        group: 32,
        prefill: None,
    };
    Box::new(move |_| {
        Box::new(SalsAttention::new(shape, sc.clone(), proj.clone())) as Box<dyn AttentionBackend + Send>
    })
}

/// Build `batch` prefilled sequences (identical prompt — decode cost is
/// what's measured).
fn make_states(
    model: &Model,
    factory: &BackendFactory,
    batch: usize,
    prompt: &[usize],
) -> Vec<SequenceState> {
    (0..batch)
        .map(|_| {
            let mut s = SequenceState::new(&model.cfg, factory);
            let mut sc = Scratch::new(&model.cfg);
            model.prefill(&mut s, &mut sc, prompt);
            s
        })
        .collect()
}

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let decode_n = if quick { 12 } else { 32 };

    let max_seq = PROMPT_LEN + decode_n + 4;
    let c = cfg(max_seq);
    let model = Model::new(c.clone(), Arc::new(Weights::random(&c, 99)));
    let mut rng = Rng::new(2025);
    let prompt: Vec<usize> = (0..PROMPT_LEN).map(|_| rng.below(c.vocab)).collect();
    let toks: Vec<usize> = (0..decode_n).map(|_| rng.below(c.vocab)).collect();

    let mut table = Table::new(
        "Decode throughput (tokens/s) — cross-sequence batched decode vs step() loop",
        &["Batch", "Method", "Step-loop tok/s", "Batched tok/s", "Batched tok/s/seq", "Speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut per_seq: Vec<(String, usize, f64)> = Vec::new();

    // Two warmup tokens before each timed run: first-touch page faults and
    // cold weight caches would otherwise land on whichever configuration
    // runs first and could flip the acceptance comparison.
    const WARMUP: usize = 2;
    let wtoks: Vec<usize> = (0..WARMUP).map(|_| rng.below(c.vocab)).collect();

    for (name, factory) in [("full", full_factory(&c)), ("sals-25%", sals_factory(&c))] {
        for &batch in &BATCHES {
            // Per-sequence step() loop — the pre-batched decode path.
            let mut states = make_states(&model, &factory, batch, &prompt);
            let mut scratches: Vec<Scratch> = (0..batch).map(|_| Scratch::new(&c)).collect();
            for &t in &wtoks {
                for (s, sc) in states.iter_mut().zip(scratches.iter_mut()) {
                    model.step(s, sc, t, true);
                }
            }
            let (_, seq_secs) = time_once(|| {
                for &t in &toks {
                    for (s, sc) in states.iter_mut().zip(scratches.iter_mut()) {
                        model.step(s, sc, t, true);
                    }
                }
            });
            let seq_tps = (batch * decode_n) as f64 / seq_secs;

            // One stacked decode_batch per step for the whole batch.
            let mut states = make_states(&model, &factory, batch, &prompt);
            let mut bs = BatchScratch::sized(&c, batch, 1);
            for &t in &wtoks {
                let tokens = vec![t; batch];
                let mut refs: Vec<&mut SequenceState> = states.iter_mut().collect();
                model.decode_batch(&mut refs, &tokens, &mut bs);
            }
            let (_, bat_secs) = time_once(|| {
                for &t in &toks {
                    let tokens = vec![t; batch];
                    let mut refs: Vec<&mut SequenceState> = states.iter_mut().collect();
                    model.decode_batch(&mut refs, &tokens, &mut bs);
                }
            });
            let bat_tps = (batch * decode_n) as f64 / bat_secs;
            let bat_tps_seq = decode_n as f64 / bat_secs;
            let speedup = bat_tps / seq_tps;
            per_seq.push((name.to_string(), batch, bat_tps_seq));

            table.row(vec![
                batch.to_string(),
                name.to_string(),
                format!("{seq_tps:.0}"),
                format!("{bat_tps:.0}"),
                format!("{bat_tps_seq:.0}"),
                format!("{speedup:.2}x"),
            ]);
            rows.push(
                Json::obj()
                    .field("batch", batch)
                    .field("method", name)
                    .field("steploop_tok_s", seq_tps)
                    .field("batched_tok_s", bat_tps)
                    .field("batched_tok_s_per_seq", bat_tps_seq)
                    .field("speedup", speedup),
            );
        }
    }
    table.print();

    // Acceptance: weight-streaming amortization must be measurable — each
    // sequence decodes *faster* inside a batch of 16 than alone.
    let mut amortized = true;
    for method in ["full", "sals-25%"] {
        let at = |b: usize| {
            per_seq
                .iter()
                .find(|(m, bb, _)| m == method && *bb == b)
                .map(|&(_, _, v)| v)
                .unwrap_or(0.0)
        };
        let (b1, b16) = (at(1), at(16));
        let ok = b16 > b1;
        amortized &= ok;
        println!(
            "acceptance[{method}]: batch-16 per-seq {b16:.0} tok/s {} batch-1 {b1:.0} tok/s",
            if ok { ">" } else { "!>" }
        );
    }

    let doc = sals::harness::bench_doc("decode_throughput")
        .field("config", "d_model=384 n_layers=6 n_heads=6 n_kv_heads=2 head_dim=64 vocab=4096")
        .field("prompt_len", PROMPT_LEN)
        .field("decode_tokens", decode_n)
        .field("batch16_per_seq_beats_batch1", amortized)
        .field("rows", Json::Arr(rows));
    let path = sals::harness::bench_artifact_path("BENCH_decode.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_decode.json");
    println!("wrote {}", path.display());
}

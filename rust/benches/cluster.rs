//! Replica cluster vs one fat engine on the SAME total page budget: the
//! serving win of routing by projected footprint instead of queueing
//! strictly FCFS behind one pool.
//!
//! The workload is engineered for head-of-line blocking: a stream of
//! short chats with periodic heavy requests whose decode-horizon
//! footprint nearly fills one replica's pool. The fat single engine
//! admits FCFS — when the front of its queue is a heavy that does not
//! fit, every short behind it waits while the pool drains, collapsing
//! concurrency. The 4-replica cluster prices each request with
//! [`SequenceFootprint`] bytes at the horizon, bin-packs admissions
//! within a window (shorts overtake a heavy that fits nowhere yet), and
//! spreads load across replicas.
//!
//! Acceptance (machine-checked, exit non-zero on failure):
//!   * the cluster achieves strictly higher decode tok/s than the fat
//!     engine on the same total pool + thread budget,
//!   * strictly lower p99 TTFT (the head-of-line tail),
//!   * per-request token streams bit-identical between the two runs.
//!
//! Emits `BENCH_cluster.json` with p50/p99 TTFT, tok/s, preemption
//! re-routes, and projected-vs-actual drift. `SALS_BENCH_QUICK=1`
//! shortens the run.

use sals::attention::FullAttention;
use sals::coordinator::{
    ClusterConfig, Coordinator, Engine, EngineConfig, GenParams, Request,
};
use sals::harness::Table;
use sals::model::{BackendFactory, Model, ModelConfig, SequenceFootprint, Weights};
use sals::util::json::Json;
use sals::util::rng::Rng;
use sals::util::threadpool::num_cpus;
use std::sync::Arc;
use std::time::Instant;

const REPLICAS: usize = 4;

fn factory(cfg: &ModelConfig) -> Box<BackendFactory> {
    let shape = cfg.attn_shape();
    Box::new(move |_| Box::new(FullAttention::new(shape)) as _)
}

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let chunk = if quick { 16 } else { 32 };
    let (heavy_prompt, heavy_new) = if quick { (96, 24) } else { (192, 48) };
    let (short_min, short_max, short_new) = if quick { (16, 32, 8) } else { (24, 48, 8) };
    let n_requests = if quick { 24 } else { 48 };
    // Every 6th request is heavy — frequent enough that the fat engine's
    // FCFS queue repeatedly wedges behind one, sparse enough that the
    // cluster can park heavies on their own replicas while shorts flow.
    let heavy_every = 6;
    let max_seq = heavy_prompt + heavy_new + 8;

    let cfg = ModelConfig {
        vocab: 512,
        d_model: 256,
        n_layers: 6,
        n_heads: 8,
        n_kv_heads: 8,
        head_dim: 32,
        d_ff: 512,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: vec![0],
        rms_eps: 1e-5,
    };
    let weights = Arc::new(Weights::random(&cfg, 88));

    // Per-replica pool: one heavy horizon + ~6% slack, so a heavy consumes
    // a replica almost whole. The fat engine gets exactly REPLICAS× that
    // pool, REPLICAS× the batch cap, and the same total thread budget —
    // identical aggregate resources, different admission structure.
    let fp = SequenceFootprint::of(&cfg, &factory(&cfg));
    let heavy_bytes = fp.bytes_at(heavy_prompt + heavy_new);
    let replica_budget = heavy_bytes + heavy_bytes / 16;
    let replica_threads = (num_cpus() / REPLICAS).max(1);

    let mut rng = Rng::new(20260808);
    let trace: Vec<(Vec<usize>, usize)> = (0..n_requests)
        .map(|i| {
            let heavy = i % heavy_every == 1;
            let plen = if heavy { heavy_prompt } else { rng.range(short_min, short_max + 1) };
            let prompt = (0..plen).map(|_| rng.below(cfg.vocab)).collect();
            (prompt, if heavy { heavy_new } else { short_new })
        })
        .collect();

    fn submit_all(trace: &[(Vec<usize>, usize)], f: &mut dyn FnMut(Request)) {
        for (i, (prompt, max_new)) in trace.iter().enumerate() {
            f(Request::new(
                i as u64,
                prompt.clone(),
                GenParams { max_new_tokens: *max_new, stop_token: None },
            ));
        }
    }

    // --- One fat engine: all pages, all threads, strict FCFS admission.
    let mut single = Engine::new(
        Model::new(cfg.clone(), Arc::clone(&weights)),
        factory(&cfg),
        EngineConfig {
            max_batch: 8 * REPLICAS,
            prefill_chunk: chunk,
            page_bytes: 4096,
            pool_budget: REPLICAS * replica_budget,
            threads: 0, // all cores
            prefix_reuse: false,
            eject_preempted: false,
        },
    );
    let t0 = Instant::now();
    submit_all(&trace, &mut |r| single.submit(r));
    let mut single_resp = single.run_to_completion();
    let single_wall = t0.elapsed().as_secs_f64();
    let single_m = single.metrics.clone();

    // --- The cluster: same totals split four ways, footprint routing.
    let mut cluster = Coordinator::new(
        Model::new(cfg.clone(), Arc::clone(&weights)),
        factory(&cfg),
        ClusterConfig {
            replicas: REPLICAS,
            engine: EngineConfig {
                max_batch: 8,
                prefill_chunk: chunk,
                page_bytes: 4096,
                pool_budget: replica_budget,
                threads: replica_threads,
                prefix_reuse: false,
                eject_preempted: false, // forced on by the coordinator
            },
            bin_pack_window: 16,
        },
    );
    let t0 = Instant::now();
    submit_all(&trace, &mut |r| cluster.submit(r).expect("trace ids are unique"));
    let mut cluster_resp = cluster.run_to_completion();
    let cluster_wall = t0.elapsed().as_secs_f64();
    let cm = cluster.metrics();
    let agg = cm.aggregate();

    assert_eq!(single_resp.len(), n_requests, "fat engine lost requests");
    assert_eq!(cluster_resp.len(), n_requests, "cluster lost requests");
    single_resp.sort_by_key(|r| r.id);
    cluster_resp.sort_by_key(|r| r.id);
    let outputs_match = single_resp
        .iter()
        .zip(cluster_resp.iter())
        .all(|(a, b)| a.id == b.id && a.tokens == b.tokens);

    let tokens_total: usize = single_resp.iter().map(|r| r.tokens.len()).sum();
    let single_tps = tokens_total as f64 / single_wall;
    let cluster_tps = tokens_total as f64 / cluster_wall;
    let single_ttft = single_m.ttft_summary();
    let cluster_ttft = agg.ttft_summary();
    let (drift_min, drift_max) = cm.drift_bounds();

    let ok = cluster_tps > single_tps && cluster_ttft.p99 < single_ttft.p99 && outputs_match;

    let mut table = Table::new(
        "Replica cluster vs one fat engine (same total pool, batch cap, threads)",
        &["Config", "tok/s", "TTFT p50 ms", "TTFT p99 ms", "Preempt", "Re-routes", "Bypasses"],
    );
    table.row(vec![
        "single-fat".to_string(),
        format!("{single_tps:.1}"),
        format!("{:.1}", single_ttft.p50 * 1e3),
        format!("{:.1}", single_ttft.p99 * 1e3),
        single_m.preemptions.to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);
    table.row(vec![
        format!("cluster-{REPLICAS}x"),
        format!("{cluster_tps:.1}"),
        format!("{:.1}", cluster_ttft.p50 * 1e3),
        format!("{:.1}", cluster_ttft.p99 * 1e3),
        agg.preemptions.to_string(),
        cm.preemption_reroutes.to_string(),
        cm.fcfs_bypasses.to_string(),
    ]);
    table.print();
    println!(
        "tok/s {cluster_tps:.1} vs {single_tps:.1} (must be >), p99 TTFT {:.1}ms vs {:.1}ms \
         (must be <), outputs_match={outputs_match}, drift mean {:.3} [{drift_min:.3}, \
         {drift_max:.3}] -> {}",
        cluster_ttft.p99 * 1e3,
        single_ttft.p99 * 1e3,
        cm.mean_drift(),
        if ok { "ok" } else { "FAIL" }
    );

    let doc = sals::harness::bench_doc("cluster")
        .field("config", "d_model=256 n_layers=6 heads=8 head_dim=32 dense_layers=[0]")
        .field("n_requests", n_requests)
        .field("heavy_every", heavy_every)
        .field("heavy_prompt", heavy_prompt)
        .field("heavy_new", heavy_new)
        .field("short_new", short_new)
        .field("prefill_chunk", chunk)
        .field("replicas", REPLICAS)
        .field("replica_pool_bytes", replica_budget)
        .field("single_pool_bytes", REPLICAS * replica_budget)
        .field("replica_threads", replica_threads)
        .field(
            "single",
            Json::obj()
                .field("tokens_per_second", single_tps)
                .field("wall_s", single_wall)
                .field("ttft_p50_s", single_ttft.p50)
                .field("ttft_p99_s", single_ttft.p99)
                .field("preemptions", single_m.preemptions)
                .field("peak_running", single_m.peak_running),
        )
        .field(
            "cluster",
            Json::obj()
                .field("tokens_per_second", cluster_tps)
                .field("wall_s", cluster_wall)
                .field("ttft_p50_s", cluster_ttft.p50)
                .field("ttft_p99_s", cluster_ttft.p99)
                .field("coordinator", cm.to_json()),
        )
        .field("speedup", cluster_tps / single_tps)
        .field("p99_ttft_ratio", cluster_ttft.p99 / single_ttft.p99)
        .field("outputs_match", outputs_match)
        .field("accepted", ok);
    let path = sals::harness::bench_artifact_path("BENCH_cluster.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_cluster.json");
    println!("wrote {}", path.display());
    if !ok {
        std::process::exit(1);
    }
}

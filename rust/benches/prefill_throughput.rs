//! Prefill throughput, two experiments:
//!
//! 1. Batched (chunked `Model::forward_batch`) vs token-at-a-time
//!    (`step()` loop — the pre-batched-prefill engine path) at 1K/4K/16K,
//!    full vs SALS. The PR-2 trajectory table.
//! 2. Dense vs **block-sparse** SALS prefill (PR 7): the chunked causal
//!    kernel vs latent-space block selection (`PrefillSparsity`) at
//!    4K/16K (128K behind non-quick mode), batched path only.
//!
//! Emits `BENCH_prefill.json` in the working directory so the prefill perf
//! trajectory accumulates across PRs. Set `SALS_BENCH_QUICK=1` to skip the
//! 16K batched-vs-sequential row (the sequential 16K pass is O(seq²)
//! attention on one core) and the 128K sparse row.
//!
//! Acceptance (`accepted` in the JSON, non-zero exit on failure):
//! block-sparse prefill ≥2× dense SALS prefill tokens/sec at 16K with
//! τ=0.95, and kernel parity ≤1e-4 against the dense fallback at τ=1.0.

use sals::attention::{
    AttentionBackend, FullAttention, PrefillSparsity, SalsAttention, SalsConfig,
};
use sals::harness::Table;
use sals::lowrank::{Calibrator, Projector};
use sals::model::{
    BackendFactory, Model, ModelConfig, Scratch, SequenceState, SparsityParams, Weights,
};
use sals::quant::Bits;
use sals::util::json::Json;
use sals::util::rng::Rng;
use sals::util::timer::time_once;
use std::sync::Arc;

/// Block size and score-mass threshold of the sparse rows (stamped into
/// the JSON next to `simd_tier`).
const SPARSE_BLOCK: usize = 128;
const SPARSE_TAU: f32 = 0.95;

/// Small decoder shaped for seq² CPU attention at 16K: the point is the
/// batched-vs-sequential ratio, not absolute model scale.
fn cfg(max_seq: usize) -> ModelConfig {
    ModelConfig {
        vocab: 256,
        d_model: 64,
        n_layers: 4,
        n_heads: 4,
        n_kv_heads: 4,
        head_dim: 16,
        d_ff: 128,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: ModelConfig::default_dense_layers(4),
        rms_eps: 1e-5,
    }
}

fn full_factory(c: &ModelConfig) -> Box<BackendFactory> {
    let shape = c.attn_shape();
    Box::new(move |_| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>)
}

/// Projector calibrated on a low-rank key family (real keys are low-rank;
/// exactness is irrelevant to throughput).
fn make_projector(c: &ModelConfig) -> Projector {
    let kvd = c.kv_dim();
    let mut rng = Rng::new(11);
    let basis: Vec<Vec<f32>> = (0..kvd / 8).map(|_| rng.normal_vec(kvd, 1.0)).collect();
    let mut cal = Calibrator::new(kvd);
    let mut row = vec![0.0f32; kvd];
    for _ in 0..256 {
        row.fill(0.0);
        for b in &basis {
            sals::tensor::ops::axpy(rng.normal_f32(), b, &mut row);
        }
        cal.add_key(&row);
    }
    cal.fit((kvd / 4).max(2)).unwrap()
}

fn sals_config(c: &ModelConfig, seq: usize, prefill: Option<PrefillSparsity>) -> SalsConfig {
    let kvd = c.kv_dim();
    let sp = SparsityParams::scaled(seq);
    SalsConfig {
        rank: (kvd / 4).max(2),
        r_star: (kvd / 8).max(1),
        sink: sp.sink,
        recent: sp.recent,
        critical: sp.critical,
        v_bits: Bits::B4,
        group: 32,
        prefill,
    }
}

fn sals_factory(
    c: &ModelConfig,
    seq: usize,
    prefill: Option<PrefillSparsity>,
) -> Box<BackendFactory> {
    let shape = c.attn_shape();
    let proj = make_projector(c);
    let sc = sals_config(c, seq, prefill);
    Box::new(move |_| {
        Box::new(SalsAttention::new(shape, sc.clone(), proj.clone())) as Box<dyn AttentionBackend + Send>
    })
}

/// The sparse configuration measured in experiment 2: τ-mass selection
/// with a top-blocks budget cap (the `PrefillSparsity` fallback) so the
/// measured block set is bounded even on this bench's random tokens,
/// whose latent scores are much flatter than real prompts'.
fn sparse_params(seq: usize) -> PrefillSparsity {
    let nb = seq.div_ceil(SPARSE_BLOCK);
    PrefillSparsity {
        block: SPARSE_BLOCK,
        tau: SPARSE_TAU,
        top_blocks: (nb / 8).max(4),
        ..PrefillSparsity::default()
    }
}

/// Time one full prefill of `tokens`; returns tokens/sec.
fn run_prefill(model: &Model, factory: &BackendFactory, tokens: &[usize], batched: bool) -> f64 {
    let mut state = SequenceState::new(&model.cfg, factory);
    let mut scratch = Scratch::new(&model.cfg);
    let (_, secs) = time_once(|| {
        if batched {
            model.prefill_chunked(&mut state, &mut scratch, tokens, Model::PREFILL_CHUNK);
        } else {
            // The pre-batched engine path: one step() per prompt token.
            for (i, &t) in tokens.iter().enumerate() {
                model.step(&mut state, &mut scratch, t, i + 1 == tokens.len());
            }
        }
    });
    tokens.len() as f64 / secs
}

/// τ=1.0 kernel parity at the attention-backend level: every block
/// selected must reproduce the dense `causal_attend_chunk` fallback.
/// Returns the max elementwise |Δ| over a chunked prefill.
fn sparse_parity_max_diff(c: &ModelConfig, seq: usize) -> f64 {
    let shape = c.attn_shape();
    let kvd = c.kv_dim();
    let qd = shape.q_dim();
    let proj = make_projector(c);
    let all = PrefillSparsity { tau: 1.0, top_blocks: 0, min_len: 0, block: SPARSE_BLOCK };
    let fallback = PrefillSparsity { min_len: usize::MAX, ..all };
    let mut sparse = SalsAttention::new(shape, sals_config(c, seq, Some(all)), proj.clone());
    let mut dense = SalsAttention::new(shape, sals_config(c, seq, Some(fallback)), proj);
    let mut rng = Rng::new(4242);
    let mut worst = 0.0f64;
    let mut i = 0;
    while i < seq {
        let n = Model::PREFILL_CHUNK.min(seq - i);
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut o_sparse = vec![0.0f32; n * qd];
        let mut o_dense = vec![0.0f32; n * qd];
        sparse.forward_batch(&ks, &vs, &qs, n, &mut o_sparse);
        dense.forward_batch(&ks, &vs, &qs, n, &mut o_dense);
        for (a, b) in o_sparse.iter().zip(&o_dense) {
            worst = worst.max((a - b).abs() as f64);
        }
        i += n;
    }
    worst
}

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();

    // ---- experiment 1: batched vs token-at-a-time ----
    let seqs: Vec<usize> = if quick { vec![1024, 4096] } else { vec![1024, 4096, 16384] };
    let mut table = Table::new(
        "Prefill throughput (tokens/s) — batched chunked forward vs token-at-a-time",
        &["Seq", "Method", "Sequential tok/s", "Batched tok/s", "Speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &seq in &seqs {
        let c = cfg(seq + 8);
        let model = Model::new(c.clone(), Arc::new(Weights::random(&c, 99)));
        let mut rng = Rng::new(2024);
        let tokens: Vec<usize> = (0..seq).map(|_| rng.below(c.vocab)).collect();
        for (name, factory) in
            [("full", full_factory(&c)), ("sals-25%", sals_factory(&c, seq, None))]
        {
            let seq_tps = run_prefill(&model, &factory, &tokens, false);
            let bat_tps = run_prefill(&model, &factory, &tokens, true);
            let speedup = bat_tps / seq_tps;
            table.row(vec![
                seq.to_string(),
                name.to_string(),
                format!("{seq_tps:.0}"),
                format!("{bat_tps:.0}"),
                format!("{speedup:.2}x"),
            ]);
            rows.push(
                Json::obj()
                    .field("seq", seq)
                    .field("method", name)
                    .field("sequential_tok_s", seq_tps)
                    .field("batched_tok_s", bat_tps)
                    .field("speedup", speedup),
            );
        }
    }
    table.print();
    println!("\nacceptance: batched ≥3x sequential for full attention at 4K prefill");

    // ---- experiment 2: dense vs block-sparse SALS prefill ----
    let sparse_seqs: Vec<usize> = if quick { vec![4096, 16384] } else { vec![4096, 16384, 131072] };
    let mut table2 = Table::new(
        "Block-sparse prefill (tokens/s) — dense causal kernel vs latent block selection",
        &["Seq", "Dense tok/s", "Sparse tok/s", "Speedup", "Blocks cap"],
    );
    let mut sparse_rows: Vec<Json> = Vec::new();
    let mut speedup_16k = 0.0f64;
    for &seq in &sparse_seqs {
        let c = cfg(seq + 8);
        let model = Model::new(c.clone(), Arc::new(Weights::random(&c, 99)));
        let mut rng = Rng::new(2024);
        let tokens: Vec<usize> = (0..seq).map(|_| rng.below(c.vocab)).collect();
        let ps = sparse_params(seq);
        let dense_f = sals_factory(&c, seq, None);
        let sparse_f = sals_factory(&c, seq, Some(ps));
        let dense_tps = run_prefill(&model, &dense_f, &tokens, true);
        let sparse_tps = run_prefill(&model, &sparse_f, &tokens, true);
        let speedup = sparse_tps / dense_tps;
        if seq == 16384 {
            speedup_16k = speedup;
        }
        table2.row(vec![
            seq.to_string(),
            format!("{dense_tps:.0}"),
            format!("{sparse_tps:.0}"),
            format!("{speedup:.2}x"),
            ps.top_blocks.to_string(),
        ]);
        sparse_rows.push(
            Json::obj()
                .field("seq", seq)
                .field("dense_tok_s", dense_tps)
                .field("sparse_tok_s", sparse_tps)
                .field("speedup", speedup)
                .field("block", ps.block)
                .field("tau", ps.tau as f64)
                .field("top_blocks", ps.top_blocks),
        );
    }
    table2.print();

    // τ=1.0 parity against the dense fallback (kernel contract).
    let parity_seq = 4096usize;
    let parity = sparse_parity_max_diff(&cfg(parity_seq + 8), parity_seq);
    let parity_ok = parity <= 1e-4;
    let speed_ok = speedup_16k >= 2.0;
    let accepted = parity_ok && speed_ok;
    println!(
        "\nacceptance: sparse {speedup_16k:.2}x {} 2x dense at 16K (tau={SPARSE_TAU}); \
         tau=1.0 parity max|Δ| {parity:.2e} {} 1e-4",
        if speed_ok { ">=" } else { "<" },
        if parity_ok { "<=" } else { ">" },
    );

    let doc = sals::harness::bench_doc("prefill_throughput")
        .field("config", "d_model=64 n_layers=4 n_heads=4 head_dim=16")
        .field("chunk", Model::PREFILL_CHUNK)
        .field("quick", quick)
        .field("block", SPARSE_BLOCK)
        .field("tau", SPARSE_TAU as f64)
        .field("sparse_speedup_16k", speedup_16k)
        .field("tau1_parity_max_diff", parity)
        .field("accepted", accepted)
        .field("rows", Json::Arr(rows))
        .field("sparse_rows", Json::Arr(sparse_rows));
    let path = sals::harness::bench_artifact_path("BENCH_prefill.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_prefill.json");
    println!("wrote {}", path.display());
    if !accepted {
        std::process::exit(1);
    }
}

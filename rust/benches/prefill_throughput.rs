//! Prefill throughput: tokens/sec at 1K/4K/16K prompts, full vs SALS,
//! batched (chunked `Model::forward_batch`) vs token-at-a-time (`step()`
//! loop — the pre-batched-prefill engine path).
//!
//! Emits `BENCH_prefill.json` in the working directory so the prefill perf
//! trajectory accumulates across PRs. Set `SALS_BENCH_QUICK=1` to skip the
//! 16K row (the sequential 16K pass is O(seq²) attention on one core).

use sals::attention::{AttentionBackend, FullAttention, SalsAttention, SalsConfig};
use sals::harness::Table;
use sals::lowrank::Calibrator;
use sals::model::{BackendFactory, Model, ModelConfig, Scratch, SequenceState, SparsityParams, Weights};
use sals::quant::Bits;
use sals::util::json::Json;
use sals::util::rng::Rng;
use sals::util::timer::time_once;
use std::sync::Arc;

/// Small decoder shaped for seq² CPU attention at 16K: the point is the
/// batched-vs-sequential ratio, not absolute model scale.
fn cfg(max_seq: usize) -> ModelConfig {
    ModelConfig {
        vocab: 256,
        d_model: 64,
        n_layers: 4,
        n_heads: 4,
        n_kv_heads: 4,
        head_dim: 16,
        d_ff: 128,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: ModelConfig::default_dense_layers(4),
        rms_eps: 1e-5,
    }
}

fn full_factory(c: &ModelConfig) -> Box<BackendFactory> {
    let shape = c.attn_shape();
    Box::new(move |_| Box::new(FullAttention::new(shape)) as Box<dyn AttentionBackend + Send>)
}

fn sals_factory(c: &ModelConfig, seq: usize) -> Box<BackendFactory> {
    let shape = c.attn_shape();
    let kvd = c.kv_dim();
    // Projector calibrated on a low-rank key family (real keys are
    // low-rank; exactness is irrelevant to throughput).
    let mut rng = Rng::new(11);
    let basis: Vec<Vec<f32>> = (0..kvd / 8).map(|_| rng.normal_vec(kvd, 1.0)).collect();
    let mut cal = Calibrator::new(kvd);
    let mut row = vec![0.0f32; kvd];
    for _ in 0..256 {
        row.fill(0.0);
        for b in &basis {
            sals::tensor::ops::axpy(rng.normal_f32(), b, &mut row);
        }
        cal.add_key(&row);
    }
    let proj = cal.fit((kvd / 4).max(2)).unwrap();
    let sp = SparsityParams::scaled(seq);
    let sc = SalsConfig {
        rank: (kvd / 4).max(2),
        r_star: (kvd / 8).max(1),
        sink: sp.sink,
        recent: sp.recent,
        critical: sp.critical,
        v_bits: Bits::B4,
        group: 32,
    };
    Box::new(move |_| {
        Box::new(SalsAttention::new(shape, sc.clone(), proj.clone())) as Box<dyn AttentionBackend + Send>
    })
}

/// Time one full prefill of `tokens`; returns tokens/sec.
fn run_prefill(model: &Model, factory: &BackendFactory, tokens: &[usize], batched: bool) -> f64 {
    let mut state = SequenceState::new(&model.cfg, factory);
    let mut scratch = Scratch::new(&model.cfg);
    let (_, secs) = time_once(|| {
        if batched {
            model.prefill_chunked(&mut state, &mut scratch, tokens, Model::PREFILL_CHUNK);
        } else {
            // The pre-batched engine path: one step() per prompt token.
            for (i, &t) in tokens.iter().enumerate() {
                model.step(&mut state, &mut scratch, t, i + 1 == tokens.len());
            }
        }
    });
    tokens.len() as f64 / secs
}

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let seqs: Vec<usize> = if quick { vec![1024, 4096] } else { vec![1024, 4096, 16384] };

    let mut table = Table::new(
        "Prefill throughput (tokens/s) — batched chunked forward vs token-at-a-time",
        &["Seq", "Method", "Sequential tok/s", "Batched tok/s", "Speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();

    for &seq in &seqs {
        let c = cfg(seq + 8);
        let model = Model::new(c.clone(), Arc::new(Weights::random(&c, 99)));
        let mut rng = Rng::new(2024);
        let tokens: Vec<usize> = (0..seq).map(|_| rng.below(c.vocab)).collect();
        for (name, factory) in
            [("full", full_factory(&c)), ("sals-25%", sals_factory(&c, seq))]
        {
            let seq_tps = run_prefill(&model, &factory, &tokens, false);
            let bat_tps = run_prefill(&model, &factory, &tokens, true);
            let speedup = bat_tps / seq_tps;
            table.row(vec![
                seq.to_string(),
                name.to_string(),
                format!("{seq_tps:.0}"),
                format!("{bat_tps:.0}"),
                format!("{speedup:.2}x"),
            ]);
            rows.push(
                Json::obj()
                    .field("seq", seq)
                    .field("method", name)
                    .field("sequential_tok_s", seq_tps)
                    .field("batched_tok_s", bat_tps)
                    .field("speedup", speedup),
            );
        }
    }
    table.print();
    println!("\nacceptance: batched ≥3x sequential for full attention at 4K prefill");

    let doc = sals::harness::bench_doc("prefill_throughput")
        .field("config", "d_model=64 n_layers=4 n_heads=4 head_dim=16")
        .field("chunk", Model::PREFILL_CHUNK)
        .field("rows", Json::Arr(rows));
    let path = sals::harness::bench_artifact_path("BENCH_prefill.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_prefill.json");
    println!("wrote {}", path.display());
}

//! Table 2: GSM8K/CoQA-proxy accuracy + memory access + compression ratio
//! for baseline, KIVI-4/2, Palu-30/50%, SALS-25/12.5%.
//!
//! Paper shape to reproduce: SALS-25% ≈ baseline accuracy at the lowest
//! memory access; Palu-50% collapses on the chained-recall (GSM8K) suite;
//! KIVI tracks baseline but moves ~3–5× more bytes than SALS.

use sals::harness::{pct, Experiment, Table};
use sals::model::Method;
use sals::util::rng::Rng;
use sals::workload::{longbench, runner};

fn main() {
    let ctx = 256;
    let exp = Experiment::new(ctx, false, 2024);
    let mut rng = Rng::new(777);

    // GSM8K proxy: 4-hop chained recall; CoQA proxy: conversational recall.
    let mut gsm = Vec::new();
    for _ in 0..12 {
        gsm.extend(longbench::gsm8k_chain(&exp.rm, ctx, 4, &mut rng));
    }
    let mut coqa = Vec::new();
    for _ in 0..24 {
        coqa.extend(longbench::coqa_turns(&exp.rm, ctx, 6, &mut rng));
    }

    let mut table = Table::new(
        "Table 2 — GSM8K/CoQA proxies (constructed retrieval model, MHA)",
        &["Method", "GSM8K↑", "CoQA↑", "MemAccess↓", "Comp.ratio↓"],
    );
    let mut base_read = 0.0f64;
    let mut base_kv = 0.0f64;
    for method in Method::accuracy_set() {
        let factory = exp.factory(method);
        let g = runner::evaluate(&exp.rm, &exp.model, &factory, &gsm, 0);
        let c = runner::evaluate(&exp.rm, &exp.model, &factory, &coqa, 0);
        let read = (g.read_bytes + c.read_bytes) as f64;
        let kv = g.kv_bytes + c.kv_bytes;
        if method == Method::Full {
            base_read = read;
            base_kv = kv;
        }
        table.row(vec![
            method.name().to_string(),
            pct(g.accuracy()),
            pct(c.accuracy()),
            format!("{:.2}", read / base_read),
            format!("{:.2}", kv / base_kv),
        ]);
    }
    table.print();
    println!("\npaper: SALS-25% 0.2312/0.5975 @0.13 access; Palu-50% 0.0614 (collapse); KIVI-4 ~baseline @0.31");
}

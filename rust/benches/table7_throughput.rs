//! Table 7: end-to-end generation throughput (tokens/s) — GPT-fast-analog
//! dense engine vs SALS engines, over batched prompts of growing length.
//!
//! Paper shape: parity-ish at short contexts (reconstruction overhead),
//! widening SALS advantage as sequence grows (1.4× @4k → 4.5× @32k on GPU;
//! the crossover + monotone growth is the reproducible signature).

use sals::coordinator::{Engine, EngineConfig, GenParams, Request};
use sals::harness::Table;
use sals::model::{make_factory, Method, Model, ModelConfig, SparsityParams, Weights};
use sals::util::rng::Rng;
use std::sync::Arc;

fn build_engine(cfg: &ModelConfig, method: Method, fitted: &Arc<sals::model::FittedCalibration>, seq: usize) -> Engine {
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(cfg, 88)));
    let sp = SparsityParams::scaled(seq);
    let factory = make_factory(method, fitted, sp);
    Engine::new(
        model,
        factory,
        EngineConfig {
            max_batch: 8,
            prefill_chunk: 256,
            page_bytes: 64 * 1024,
            pool_budget: 1 << 32,
            threads: 0,
            prefix_reuse: false,
            eject_preempted: false,
        },
    )
}

fn main() {
    // Scaled-down LLaMA shape (CPU): 6 layers, d_model 256, 8 heads × 32.
    let mk_cfg = |max_seq: usize| ModelConfig {
        vocab: 512,
        d_model: 256,
        n_layers: 6,
        n_heads: 8,
        n_kv_heads: 8,
        head_dim: 32,
        d_ff: 512,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: ModelConfig::default_dense_layers(6),
        rms_eps: 1e-5,
    };

    let mut table = Table::new(
        "Table 7 — end-to-end decode throughput (tokens/second)",
        &["Bsz", "Seq", "GPT-fast(dense)", "SALS-25%", "SALS-12.5%", "speedup25", "speedup125"],
    );

    for &(bs, seq) in &[(8usize, 256usize), (8, 512), (8, 1024), (4, 2048)] {
        let cfg = mk_cfg(seq + 64);
        // Calibrate once per shape on the dense model.
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 88)));
        let mut rng = Rng::new(4242);
        let streams: Vec<Vec<usize>> =
            (0..2).map(|_| (0..256).map(|_| rng.below(cfg.vocab)).collect()).collect();
        let calib = sals::model::calibrate(&model, &streams);
        let fitted = Arc::new(sals::model::fit_calibration(&cfg, &calib));

        let mut tputs = Vec::new();
        for method in [Method::Full, Method::Sals25, Method::Sals125] {
            let mut engine = build_engine(&cfg, method, &fitted, seq);
            let mut rng = Rng::new(777);
            for i in 0..bs {
                let prompt: Vec<usize> = (0..seq).map(|_| rng.below(cfg.vocab)).collect();
                engine.submit(Request::new(i as u64, prompt, GenParams { max_new_tokens: 8, stop_token: None }));
            }
            engine.run_to_completion();
            tputs.push(engine.metrics.tokens_per_second());
        }
        table.row(vec![
            bs.to_string(),
            seq.to_string(),
            format!("{:.1}", tputs[0]),
            format!("{:.1}", tputs[1]),
            format!("{:.1}", tputs[2]),
            format!("{:.2}x", tputs[1] / tputs[0]),
            format!("{:.2}x", tputs[2] / tputs[0]),
        ]);
    }
    table.print();
    println!("\npaper: 8x4k 118→163.5 (1.4x) ... 8x32k 19.8→89.5 (4.5x); speedup must GROW with seq");
}

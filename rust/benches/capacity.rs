//! Serving capacity under a FIXED KV pool budget — the serving-side
//! analogue of the paper's compression claim (Table 2's 6.4×, Table 7's
//! throughput): backend-aware admission must concurrently admit several
//! times more SALS sequences than dense-fp32 ones from the same pool,
//! with zero preemption churn (honest footprints) and the throughput to
//! match.
//!
//! Emits `BENCH_capacity.json` in the working directory so the capacity
//! trajectory accumulates across PRs. `SALS_BENCH_QUICK=1` shortens the
//! run (shorter prompts, fewer requests).

use sals::coordinator::{Engine, EngineConfig, GenParams, Request};
use sals::harness::Table;
use sals::model::{make_factory, Method, Model, ModelConfig, SequenceFootprint, Weights};
use sals::util::json::Json;
use sals::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let (prompt_len, decode_n, n_requests) = if quick { (96, 8, 8) } else { (256, 16, 12) };
    let max_seq = prompt_len + decode_n + 8;

    // Scaled-down LLaMA shape; only layer 0 dense so the SALS footprint
    // advantage shows up across most of the stack.
    let cfg = ModelConfig {
        vocab: 512,
        d_model: 256,
        n_layers: 6,
        n_heads: 8,
        n_kv_heads: 8,
        head_dim: 32,
        d_ff: 512,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: vec![0],
        rms_eps: 1e-5,
    };

    // Calibrate once on the dense model.
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 88)));
    let mut rng = Rng::new(4242);
    let streams: Vec<Vec<usize>> =
        (0..2).map(|_| (0..128).map(|_| rng.below(cfg.vocab)).collect()).collect();
    let calib = sals::model::calibrate(&model, &streams);
    let fitted = Arc::new(sals::model::fit_calibration(&cfg, &calib));
    let sp = sals::model::SparsityParams::scaled(prompt_len);

    // Pool sized to hold ~4 dense sequences at the full decode horizon:
    // capacity differences then come purely from the per-backend footprint.
    let horizon = prompt_len + decode_n;
    let full_fp = SequenceFootprint::of(&cfg, &make_factory(Method::Full, &fitted, sp));
    let pool_budget = 4 * full_fp.bytes_at(horizon);

    let mut table = Table::new(
        "Serving capacity under a fixed KV pool budget (backend-aware admission)",
        &["Method", "Peak concurrent", "Peak pool pages", "Preemptions", "tok/s", "est bytes/seq"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut peaks: Vec<(Method, usize)> = Vec::new();

    for method in [Method::Full, Method::Sals25, Method::Sals125] {
        let est = SequenceFootprint::of(&cfg, &make_factory(method, &fitted, sp)).bytes_at(horizon);
        let mut engine = Engine::new(
            Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 88))),
            make_factory(method, &fitted, sp),
            EngineConfig {
                max_batch: 16,
                prefill_chunk: 64,
                page_bytes: 4096,
                pool_budget,
                threads: 0,
                prefix_reuse: false,
                eject_preempted: false,
            },
        );
        let mut rng = Rng::new(777);
        for i in 0..n_requests {
            let prompt: Vec<usize> = (0..prompt_len).map(|_| rng.below(cfg.vocab)).collect();
            engine.submit(Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: decode_n, stop_token: None },
            ));
        }
        let responses = engine.run_to_completion();
        assert_eq!(responses.len(), n_requests, "{method:?}: not all requests completed");
        let m = &engine.metrics;
        peaks.push((method, m.peak_running));
        table.row(vec![
            method.name().to_string(),
            m.peak_running.to_string(),
            m.peak_pool_pages.to_string(),
            m.preemptions.to_string(),
            format!("{:.1}", m.tokens_per_second()),
            est.to_string(),
        ]);
        rows.push(
            Json::obj()
                .field("method", method.name())
                .field("peak_running", m.peak_running)
                .field("peak_pool_pages", m.peak_pool_pages)
                .field("preemptions", m.preemptions)
                .field("tokens_per_second", m.tokens_per_second())
                .field("est_bytes_per_seq", est),
        );
    }
    table.print();

    // Acceptance: the same pool must admit strictly more SALS sequences
    // concurrently than dense fp32 — the capacity half of Table 7.
    let peak = |m: Method| peaks.iter().find(|(mm, _)| *mm == m).map(|&(_, p)| p).unwrap_or(0);
    let ok = peak(Method::Sals25) > peak(Method::Full);
    println!(
        "acceptance: SALS-25% peak concurrent {} {} full {}",
        peak(Method::Sals25),
        if ok { ">" } else { "!>" },
        peak(Method::Full)
    );

    let doc = sals::harness::bench_doc("capacity")
        .field("config", "d_model=256 n_layers=6 heads=8 head_dim=32 dense_layers=[0]")
        .field("prompt_len", prompt_len)
        .field("decode_tokens", decode_n)
        .field("n_requests", n_requests)
        .field("pool_budget_bytes", pool_budget)
        .field("sals25_capacity_gt_full", ok)
        .field("rows", Json::Arr(rows));
    let path = sals::harness::bench_artifact_path("BENCH_capacity.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_capacity.json");
    println!("wrote {}", path.display());
}

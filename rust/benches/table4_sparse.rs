//! Table 4: token-sparse methods (Double Sparse, HShare, Loki, Quest,
//! StreamingLLM) vs SALS on the LongBench proxies — same sparsity budget
//! (x=16 sink, y=432 critical, z=64 recent scaled to context).
//!
//! Paper shape: SALS matches/beats the sparse heuristics in accuracy while
//! moving the least memory (its cache is also compressed; theirs are not).

use sals::harness::{pct, Experiment, Table};
use sals::model::Method;
use sals::util::rng::Rng;
use sals::workload::longbench::{generate, LongBenchTask};
use sals::workload::runner;

fn main() {
    let ctx = 256;
    let exp = Experiment::new(ctx, false, 4242);
    let mut rng = Rng::new(999);
    let tasks = LongBenchTask::all();
    let suites: Vec<Vec<sals::workload::Trial>> = tasks
        .iter()
        .map(|&t| {
            let mut trials = Vec::new();
            for _ in 0..6 {
                trials.extend(generate(&exp.rm, t, ctx, &mut rng));
            }
            trials
        })
        .collect();

    let mut header: Vec<&str> = vec!["Method"];
    let names: Vec<String> = tasks.iter().map(|t| t.name().to_string()).collect();
    header.extend(names.iter().map(|s| s.as_str()));
    header.push("Avg");
    header.push("MemAccess↓");
    let mut table = Table::new("Table 4 — token-sparse comparison (LongBench proxies)", &header);

    let methods = [
        Method::Full,
        Method::DoubleSparse,
        Method::HShare,
        Method::Loki,
        Method::Quest,
        Method::StreamingLlm,
        Method::Sals25,
        Method::Sals125,
    ];
    let mut base_read = 0.0f64;
    for method in methods {
        let factory = exp.factory(method);
        let mut row = vec![method.name().to_string()];
        let mut accs = Vec::new();
        let mut read = 0.0f64;
        for suite in &suites {
            let res = runner::evaluate(&exp.rm, &exp.model, &factory, suite, 0);
            accs.push(res.accuracy());
            read += res.read_bytes as f64;
        }
        if method == Method::Full {
            base_read = read;
        }
        for a in &accs {
            row.push(pct(*a));
        }
        row.push(pct(accs.iter().sum::<f64>() / accs.len() as f64));
        row.push(format!("{:.2}", read / base_read));
        table.row(row);
    }
    table.print();
    println!("\npaper: SALS-25% avg 32.26 @0.11 vs DS 31.64 @0.16, HShare 31.83 @0.14, Loki 31.95 @0.19");
}

//! Ablations over SALS's design choices (DESIGN.md §5 footnotes):
//!   A. Lemma 1 — joint multi-head vs per-head projection energy.
//!   B. Scoring rank r* sweep (accuracy vs cheap-score fidelity).
//!   C. Selection budget N_c sweep.
//!   D. Pre-RoPE vs post-RoPE latent space for selection (the §3.1 claim).

use sals::attention::{SalsAttention, SalsConfig};
use sals::harness::{pct, Experiment, Table};
use sals::lowrank::{reconstruction_error, Calibrator, PerHeadProjector, Projector};
use sals::model::Method;
use sals::quant::Bits;
use sals::rope::RopeTable;
use sals::tensor::Mat;
use sals::util::rng::Rng;
use sals::workload::ruler::{generate, RulerTask};
use sals::workload::runner;

fn main() {
    // ---------- A: Lemma 1 ----------
    let exp = Experiment::new(256, false, 121212);
    let mut rng = Rng::new(2222);
    let streams: Vec<Vec<usize>> = (0..4)
        .map(|_| (0..128).map(|_| exp.rm.filler_token(rng.below(exp.rm.spec.n_fill))).collect())
        .collect();
    let calib = sals::model::calibrate(&exp.model, &streams);
    let cfg = &exp.rm.cfg;
    let mut ta = Table::new(
        "Ablation A — Lemma 1: joint vs per-head projection (reconstruction rel-err)",
        &["Layer", "joint", "per-head"],
    );
    for (l, lc) in calib.layers.iter().enumerate().take(3) {
        let rank = cfg.kv_dim() / 4;
        let mut c = Calibrator::new(cfg.kv_dim());
        c.add_keys(&lc.pre_keys.data);
        let joint = c.fit(rank).unwrap();
        let keys = Mat::from_vec(lc.pre_keys.rows, cfg.kv_dim(), lc.pre_keys.data.clone());
        let per_head = PerHeadProjector::fit(&keys, cfg.n_kv_heads, rank - rank % cfg.n_kv_heads).unwrap();
        let je = reconstruction_error(&joint, &keys);
        // per-head error
        let mut lat = vec![0.0; per_head.n_heads * per_head.rank_per_head];
        let mut rec = vec![0.0; cfg.kv_dim()];
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for row in 0..keys.rows {
            per_head.project(keys.row(row), &mut lat);
            per_head.reconstruct(&lat, &mut rec);
            for (a, b) in rec.iter().zip(keys.row(row)) {
                num += ((a - b) as f64).powi(2);
                den += (*b as f64).powi(2);
            }
        }
        ta.row(vec![l.to_string(), format!("{je:.4}"), format!("{:.4}", (num / den).sqrt())]);
    }
    ta.print();

    // ---------- B/C: r* and N_c sweeps on RULER-S2 ----------
    let ctx = 256;
    let mut trials = Vec::new();
    let mut rng = Rng::new(3333);
    for _ in 0..8 {
        trials.extend(generate(&exp.rm, RulerTask::S2, ctx, &mut rng));
    }
    let kvd = cfg.kv_dim();
    let base_rank = kvd / 4;

    let mut tb = Table::new("Ablation B — scoring rank r* sweep (SALS-25%, RULER-S2)", &["r*/r", "accuracy"]);
    for frac in [1.0f64, 0.5, 0.25, 0.125] {
        let r_star = ((base_rank as f64 * frac) as usize).max(1);
        let fitted = exp.fitted.clone();
        let sp = exp.sp;
        let factory: Box<sals::model::BackendFactory> = Box::new(move |layer| {
            let shape = fitted.cfg.attn_shape();
            if fitted.cfg.dense_layers.contains(&layer) {
                return Box::new(sals::attention::FullAttention::new(shape)) as _;
            }
            let p = &fitted.pre_key_proj[layer];
            let mut u = Mat::zeros(p.dim, base_rank);
            for row in 0..p.dim {
                for col in 0..base_rank {
                    u.data[row * base_rank + col] = p.u.data[row * p.rank + col];
                }
            }
            let proj = Projector { dim: p.dim, rank: base_rank, u, spectrum: p.spectrum.clone() };
            let c = SalsConfig {
                rank: base_rank,
                r_star,
                sink: sp.sink,
                recent: sp.recent,
                critical: sp.critical,
                v_bits: Bits::B4,
                group: 32,
                prefill: None,
            };
            Box::new(SalsAttention::new(shape, c, proj)) as _
        });
        let res = runner::evaluate(&exp.rm, &exp.model, &factory, &trials, 0);
        tb.row(vec![format!("{frac}"), pct(res.accuracy())]);
    }
    tb.print();

    let mut tc = Table::new("Ablation C — selection budget sweep (SALS-25%, RULER-S2)", &["N_c/s", "accuracy"]);
    for frac in [4usize, 8, 16, 32] {
        let fitted = exp.fitted.clone();
        let critical = (ctx / frac).max(2);
        let sp = sals::model::SparsityParams { sink: 2, recent: 4, critical };
        let factory = sals::model::make_factory(Method::Sals25, &fitted, sp);
        let res = runner::evaluate(&exp.rm, &exp.model, &factory, &trials, 0);
        tc.row(vec![format!("1/{frac}"), pct(res.accuracy())]);
    }
    tc.print();

    // ---------- D: pre- vs post-RoPE latent selection fidelity ----------
    // Score-ranking agreement with exact attention when the latent space is
    // built pre-RoPE vs post-RoPE (the paper's central §3.1 claim). Uses
    // the LLaMA-shaped model at rope_base 1e4 (the retrieval model's huge
    // base would make RoPE a no-op and hide the effect).
    let dcfg = sals::model::ModelConfig::tiny_mha(256);
    let dmodel = sals::model::Model::new(
        dcfg.clone(),
        std::sync::Arc::new(sals::model::Weights::random_lowrank_keys(&dcfg, 99, dcfg.kv_dim() / 8)),
    );
    let mut drng = Rng::new(4141);
    let dstreams: Vec<Vec<usize>> =
        (0..4).map(|_| (0..128).map(|_| drng.below(dcfg.vocab)).collect()).collect();
    let dcalib = sals::model::calibrate(&dmodel, &dstreams);
    let dkvd = dcfg.kv_dim();
    let mut td = Table::new(
        "Ablation D — selection overlap: pre-RoPE vs post-RoPE latent space",
        &["Layer", "OS pre-RoPE", "OS post-RoPE"],
    );
    // Table must cover the concatenated calibration length (4 × 128 rows).
    let rope = RopeTable::new(dcfg.head_dim, 1024, dcfg.rope_base);
    let (cfg, kvd, calib) = (&dcfg, dkvd, &dcalib);
    for (l, lc) in calib.layers.iter().enumerate().take(3) {
        let rank = kvd / 4;
        let s = lc.pre_keys.rows;
        let mut cpre = Calibrator::new(kvd);
        cpre.add_keys(&lc.pre_keys.data);
        let ppre = cpre.fit(rank).unwrap();
        let mut cpost = Calibrator::new(kvd);
        cpost.add_keys(&lc.post_keys.data);
        let ppost = cpost.fit(rank).unwrap();
        let os_pre = sals::analyze::overlap_by_layer(
            std::slice::from_ref(&ppre),
            std::slice::from_ref(&lc.pre_keys.data),
            cfg.head_dim,
            &rope,
            s / 8,
            0.5,
            8,
            91,
        )[0];
        // Post-RoPE scoring: project *rotated* keys; approximate by scoring
        // in the post-RoPE eigenspace over rotated keys.
        let os_post = sals::analyze::overlap_by_layer(
            std::slice::from_ref(&ppost),
            std::slice::from_ref(&lc.post_keys.data),
            cfg.head_dim,
            &rope,
            s / 8,
            0.5,
            8,
            92,
        )[0];
        td.row(vec![l.to_string(), pct(os_pre), pct(os_post)]);
    }
    td.print();
    println!("\nexpected: joint ≤ per-head error (Lemma 1); accuracy degrades gracefully with r*, N_c;");
    println!("pre-RoPE OS ≥ post-RoPE OS (variance amplification, §3.1)");
}

//! Figure 2: overlap score (OS) of pre-RoPE latent-space token ranking per
//! layer, plus OS as a function of selection budget N_c and scoring rank r*.
//!
//! Paper shape: middle layers hold OS > 90% at modest budgets — the latent
//! space preserves the attention ranking. (The paper's layer-0/1 dip is a
//! property of pretrained LLaMA weights; EXPERIMENTS.md discusses why the
//! synthetic model shows a flatter profile.)

use sals::analyze::overlap_by_layer;
use sals::harness::{pct, Experiment, Table};
use sals::rope::RopeTable;

fn main() {
    let exp = Experiment::new(256, false, 909090);
    let cfg = &exp.rm.cfg;
    let rope = RopeTable::new(cfg.head_dim, cfg.max_seq, cfg.rope_base);

    // Per-layer calibration keys (from the harness's Experiment pipeline we
    // refit here to also get the raw keys).
    let mut rng = sals::util::rng::Rng::new(909090 ^ 0xCA11B);
    let streams: Vec<Vec<usize>> = (0..4)
        .map(|_| {
            (0..128)
                .map(|_| {
                    if rng.below(8) == 0 {
                        exp.rm.needle_token(rng.below(exp.rm.spec.n_keys), rng.below(exp.rm.spec.n_vals))
                    } else {
                        exp.rm.filler_token(rng.below(exp.rm.spec.n_fill))
                    }
                })
                .collect()
        })
        .collect();
    let calib = sals::model::calibrate(&exp.model, &streams);
    let projs: Vec<sals::lowrank::Projector> = (0..cfg.n_layers)
        .map(|l| {
            let mut c = sals::lowrank::Calibrator::new(cfg.kv_dim());
            c.add_keys(&calib.layers[l].pre_keys.data);
            c.fit(cfg.kv_dim() / 4).unwrap()
        })
        .collect();
    let keys: Vec<Vec<f32>> = calib.layers.iter().map(|l| l.pre_keys.data.clone()).collect();

    let mut t1 = Table::new("Figure 2 — overlap score by layer (N_c = s/4, r* = r/2)", &["Layer", "OS"]);
    let s = keys[0].len() / cfg.kv_dim();
    let os = overlap_by_layer(&projs, &keys, cfg.head_dim, &rope, s / 4, 0.5, 8, 42);
    for (l, o) in os.iter().enumerate() {
        t1.row(vec![l.to_string(), pct(*o)]);
    }
    t1.print();

    let mut t2 = Table::new("Figure 2b — OS vs selection budget (layer 3)", &["N_c/s", "OS"]);
    for frac in [2usize, 4, 8, 16] {
        let os = overlap_by_layer(
            &projs[3..4],
            &keys[3..4],
            cfg.head_dim,
            &rope,
            (s / frac).max(1),
            0.5,
            8,
            43,
        );
        t2.row(vec![format!("1/{frac}"), pct(os[0])]);
    }
    t2.print();

    let mut t3 = Table::new("Figure 2c — OS vs scoring rank r* (layer 3, N_c = s/8)", &["r*/r", "OS"]);
    for frac in [1.0, 0.5, 0.25, 0.125] {
        let os = overlap_by_layer(&projs[3..4], &keys[3..4], cfg.head_dim, &rope, s / 8, frac, 8, 44);
        t3.row(vec![format!("{frac}"), pct(os[0])]);
    }
    t3.print();
    println!("\npaper: OS > 90% for layers 2-29; drops when budget or r* shrink too far");
}

//! SALS decode hot-path stage timings: score / select / reconstruct+gather
//! / attend, per token, at 4K and 32K contexts — the bandwidth-exact
//! decode refactor's regression gate.
//!
//! Two implementations of the same pipeline run against identical state:
//!
//! * **packed** — the production path (`SalsAttention::attend_instrumented`):
//!   split-panel unit-stride latent scoring, O(k log k) range-merge
//!   selection, recon matmul that skips recent-ring rows, page-coherent
//!   value gather, packed `sparse_attend` epilogue.
//! * **legacy** — a faithful in-bench replica of the pre-split-panel path:
//!   strided score scan over (len, r) latent rows (touches the full rows
//!   to read the leading r*), O(seq_len) mask-based selection merge
//!   (allocating per call), reconstruction matmul over *all* selected rows
//!   (recent rows computed then overwritten), per-row quant-store `get()`,
//!   and the per-head strided dot/axpy attention with its per-call scores
//!   allocation.
//!
//! The workload is the paper's memory-bound decode regime (long context,
//! small critical budget, SALS-12.5% ranks — r* rows are sub-cache-line,
//! where the strided scan's waste is maximal). Acceptance: ≥1.5× packed
//! vs legacy on the summed four stages at 32K, and the score stage's
//! metered traffic ≈ r*·4 bytes per context token (not r·4).
//!
//! Emits `BENCH_sals_hotpath.json`; CI runs this under `SALS_BENCH_QUICK=1`
//! and fails if `accepted` is false. Quick mode shortens the timing loops
//! (same contexts and shapes).

use sals::attention::{AttentionBackend, SalsAttention, SalsConfig, SalsStageTimes};
use sals::harness::Table;
use sals::lowrank::{Calibrator, Projector};
use sals::quant::{Bits, TokenQuantStore};
use sals::rope::RopeTable;
use sals::tensor::ops::{axpy, dot, matmul, softmax};
use sals::tensor::top_k_indices_into;
use sals::util::json::Json;
use sals::util::rng::Rng;
use std::time::Instant;

const N_HEADS: usize = 4;
const HEAD_DIM: usize = 32;
const RANK: usize = 16; // SALS-12.5% of kvd=128
const R_STAR: usize = 8;
const SINK: usize = 4;
const RECENT: usize = 64;
const V_BITS: Bits = Bits::B2;
const QGROUP: usize = 32;
const CONTEXTS: [usize; 2] = [4096, 32768];

fn kvd() -> usize {
    N_HEADS * HEAD_DIM
}

fn critical_for(ctx: usize) -> usize {
    (ctx / 256).max(32)
}

/// Low-rank key-family projector (real LLM keys are low-rank; exactness is
/// irrelevant to the timing).
fn make_projector(rng: &mut Rng) -> Projector {
    let kvd = kvd();
    let basis: Vec<Vec<f32>> = (0..RANK).map(|_| rng.normal_vec(kvd, 1.0)).collect();
    let mut cal = Calibrator::new(kvd);
    let mut row = vec![0.0f32; kvd];
    for _ in 0..512 {
        row.fill(0.0);
        for b in &basis {
            axpy(rng.normal_f32(), b, &mut row);
        }
        cal.add_key(&row);
    }
    cal.fit(RANK).unwrap()
}

/// The pre-PR decode state + scratch: (len, r) row-major latents, fp32
/// recent-key ring, quantized value store — the layout the split panels
/// replaced.
struct Legacy {
    proj: Projector,
    u_t: Vec<f32>, // (r, kvd)
    rope: RopeTable,
    lat: Vec<f32>, // (len, RANK) row-major
    ring: Vec<f32>,
    recent_cap: usize,
    store: TokenQuantStore,
    len: usize,
    critical: usize,
    // Reused scratch, as the pre-PR backend had:
    qlat: Vec<f32>,
    scores: Vec<f32>,
    idx: Vec<usize>,
    lat_sel: Vec<f32>,
    keys: Vec<f32>,
    vals: Vec<f32>,
    qr: Vec<f32>,
}

impl Legacy {
    fn new(proj: Projector, max_seq: usize, critical: usize) -> Legacy {
        let kvd = kvd();
        let mut u_t = vec![0.0f32; RANK * kvd];
        for i in 0..kvd {
            for j in 0..RANK {
                u_t[j * kvd + i] = proj.u.data[i * proj.rank + j];
            }
        }
        Legacy {
            proj,
            u_t,
            rope: RopeTable::new(HEAD_DIM, max_seq, 10_000.0),
            lat: Vec::new(),
            ring: vec![0.0; RECENT * kvd],
            recent_cap: RECENT,
            store: TokenQuantStore::new(kvd, V_BITS, QGROUP, RECENT.max(QGROUP)),
            len: 0,
            critical,
            qlat: vec![0.0; RANK],
            scores: Vec::new(),
            idx: Vec::new(),
            lat_sel: Vec::new(),
            keys: Vec::new(),
            vals: Vec::new(),
            qr: Vec::new(),
        }
    }

    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = kvd();
        let start = self.lat.len();
        self.lat.resize(start + RANK, 0.0);
        self.proj.project(k, &mut self.lat[start..start + RANK]);
        let slot = self.len % self.recent_cap;
        self.ring[slot * kvd..(slot + 1) * kvd].copy_from_slice(k);
        self.store.append(v);
        self.len += 1;
    }

    /// The pre-PR mask-based O(seq_len) selection merge (allocating).
    fn mask_merge(seq_len: usize, sink: usize, recent: usize, critical: &[usize]) -> Vec<usize> {
        let mut mask = vec![false; seq_len];
        for m in mask.iter_mut().take(sink.min(seq_len)) {
            *m = true;
        }
        for m in mask[seq_len.saturating_sub(recent)..].iter_mut() {
            *m = true;
        }
        for &i in critical {
            if i < seq_len {
                mask[i] = true;
            }
        }
        mask.iter().enumerate().filter_map(|(i, &m)| m.then_some(i)).collect()
    }

    /// One decode attend through the pre-PR pipeline, accumulating
    /// per-stage wall times.
    fn attend(&mut self, q: &[f32], out: &mut [f32], times: &mut SalsStageTimes) {
        let kvd = kvd();
        let t0 = Instant::now();
        // Stage 1 (legacy): strided scan over the (len, r) rows.
        self.proj.project(q, &mut self.qlat); // MHA: pooled query == q
        self.scores.clear();
        self.scores.reserve(self.len);
        let ql = &self.qlat[..R_STAR];
        for j in 0..self.len {
            self.scores.push(dot(ql, &self.lat[j * RANK..j * RANK + R_STAR]));
        }
        let t1 = Instant::now();
        // Stage 2 (legacy): top-k + mask merge.
        top_k_indices_into(&self.scores, self.critical, &mut self.idx);
        let sel = Self::mask_merge(self.len, SINK, RECENT, &self.idx);
        let n_sel = sel.len();
        let t2 = Instant::now();
        // Stage 3 (legacy): gather + reconstruct ALL selected rows (recent
        // rows included, then overwritten), per-row value get().
        self.lat_sel.resize(n_sel * RANK, 0.0);
        self.keys.resize(n_sel * kvd, 0.0);
        self.vals.resize(n_sel * kvd, 0.0);
        for (row, &j) in sel.iter().enumerate() {
            self.lat_sel[row * RANK..(row + 1) * RANK]
                .copy_from_slice(&self.lat[j * RANK..(j + 1) * RANK]);
        }
        matmul(&self.lat_sel, &self.u_t, &mut self.keys, n_sel, RANK, kvd);
        for (row, &j) in sel.iter().enumerate() {
            if j + self.recent_cap >= self.len {
                let slot = j % self.recent_cap;
                self.keys[row * kvd..(row + 1) * kvd]
                    .copy_from_slice(&self.ring[slot * kvd..(slot + 1) * kvd]);
            }
            self.rope.apply_multihead(&mut self.keys[row * kvd..(row + 1) * kvd], j);
            self.store.get(j, &mut self.vals[row * kvd..(row + 1) * kvd]);
        }
        let t3 = Instant::now();
        // Stage 4 (legacy): per-head strided dot/axpy exact attention with
        // the per-call scores allocation.
        self.qr.clear();
        self.qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.qr, self.len - 1);
        let scale = 1.0 / (HEAD_DIM as f32).sqrt();
        let mut s = vec![0.0f32; n_sel];
        out.fill(0.0);
        for h in 0..N_HEADS {
            let qh = &self.qr[h * HEAD_DIM..(h + 1) * HEAD_DIM];
            for (j, sj) in s.iter_mut().enumerate() {
                let krow = &self.keys[j * kvd + h * HEAD_DIM..j * kvd + (h + 1) * HEAD_DIM];
                *sj = dot(qh, krow) * scale;
            }
            softmax(&mut s);
            let oh = &mut out[h * HEAD_DIM..(h + 1) * HEAD_DIM];
            for (j, &p) in s.iter().enumerate() {
                let vrow = &self.vals[j * kvd + h * HEAD_DIM..j * kvd + (h + 1) * HEAD_DIM];
                axpy(p, vrow, oh);
            }
        }
        let t4 = Instant::now();
        times.score += (t1 - t0).as_secs_f64();
        times.select += (t2 - t1).as_secs_f64();
        times.reconstruct += (t3 - t2).as_secs_f64();
        times.attend += (t4 - t3).as_secs_f64();
    }
}

struct CtxResult {
    packed: SalsStageTimes,
    legacy: SalsStageTimes,
    speedup: f64,
    score_bytes_per_ctx_token: f64,
}

fn run_context(ctx: usize, reps: usize, decode_tokens: usize, rng: &mut Rng) -> CtxResult {
    let kvd = kvd();
    let qd = N_HEADS * HEAD_DIM;
    let max_seq = ctx + 8;
    let shape = sals::attention::AttnShape::mha(N_HEADS, HEAD_DIM, max_seq);
    let proj = make_projector(rng);
    let critical = critical_for(ctx);
    let cfg = SalsConfig {
        rank: RANK,
        r_star: R_STAR,
        sink: SINK,
        recent: RECENT,
        critical,
        v_bits: V_BITS,
        group: QGROUP,
    };
    let mut packed = SalsAttention::new(shape, cfg, proj.clone());
    let mut legacy = Legacy::new(proj, max_seq, critical);

    // Prefill both to `ctx` tokens from the same stream (chunked batched
    // appends for the packed path, per-row appends for the legacy one).
    const CHUNK: usize = 1024;
    let mut done = 0;
    while done < ctx {
        let n = CHUNK.min(ctx - done);
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        packed.append_batch(&ks, &vs, n);
        for t in 0..n {
            legacy.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
        done += n;
    }
    packed.end_prefill();

    // Score-stage traffic probe: the panel scan must meter ≈ r*·4 bytes
    // per context token.
    let q = rng.normal_vec(qd, 1.0);
    let before = packed.traffic().read;
    let _ = packed.latent_scores(&q);
    let score_bytes_per_ctx_token = (packed.traffic().read - before) as f64 / ctx as f64;

    // Attends do not mutate cache state, so both paths are timed against
    // the identical frozen context; best-of-`reps` per path.
    let mut out = vec![0.0f32; qd];
    let mut best_packed = SalsStageTimes::default();
    let mut best_legacy = SalsStageTimes::default();
    let (mut best_packed_total, mut best_legacy_total) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..reps {
        let mut tp = SalsStageTimes::default();
        for _ in 0..decode_tokens {
            packed.attend_instrumented(&q, &mut out, &mut tp);
        }
        if tp.total() < best_packed_total {
            best_packed_total = tp.total();
            best_packed = tp;
        }
        let mut tl = SalsStageTimes::default();
        for _ in 0..decode_tokens {
            legacy.attend(&q, &mut out, &mut tl);
        }
        if tl.total() < best_legacy_total {
            best_legacy_total = tl.total();
            best_legacy = tl;
        }
    }
    let scale_to_per_token = |t: SalsStageTimes| SalsStageTimes {
        score: t.score / decode_tokens as f64,
        select: t.select / decode_tokens as f64,
        reconstruct: t.reconstruct / decode_tokens as f64,
        attend: t.attend / decode_tokens as f64,
    };
    let packed_t = scale_to_per_token(best_packed);
    let legacy_t = scale_to_per_token(best_legacy);
    CtxResult {
        packed: packed_t,
        legacy: legacy_t,
        speedup: legacy_t.total() / packed_t.total(),
        score_bytes_per_ctx_token,
    }
}

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let (reps, decode_tokens) = if quick { (3, 5) } else { (3, 10) };
    let mut rng = Rng::new(2026);

    let mut table = Table::new(
        "SALS decode hot path — per-token stage times (µs), packed vs legacy",
        &["Ctx", "Path", "Score", "Select", "Reconstruct", "Attend", "Total", "Speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_32k = 0.0;
    let mut score_bytes_ok = true;
    let rstar_bytes = (R_STAR * 4) as f64;

    for &ctx in &CONTEXTS {
        let res = run_context(ctx, reps, decode_tokens, &mut rng);
        let us = 1e6;
        for (path, t, speed) in [
            ("legacy", res.legacy, String::new()),
            ("packed", res.packed, format!("{:.2}x", res.speedup)),
        ] {
            table.row(vec![
                ctx.to_string(),
                path.to_string(),
                format!("{:.1}", t.score * us),
                format!("{:.1}", t.select * us),
                format!("{:.1}", t.reconstruct * us),
                format!("{:.1}", t.attend * us),
                format!("{:.1}", t.total() * us),
                speed,
            ]);
            rows.push(
                Json::obj()
                    .field("ctx", ctx)
                    .field("path", path)
                    .field("score_us", t.score * us)
                    .field("select_us", t.select * us)
                    .field("reconstruct_us", t.reconstruct * us)
                    .field("attend_us", t.attend * us)
                    .field("total_us", t.total() * us),
            );
        }
        println!(
            "ctx {ctx}: score stage streams {:.1} B/ctx-token (r*·4 = {rstar_bytes}, r·4 = {})",
            res.score_bytes_per_ctx_token,
            RANK * 4
        );
        // The meter must reflect the panel scan: r*·4, not r·4.
        score_bytes_ok &= res.score_bytes_per_ctx_token <= rstar_bytes * 1.01;
        if ctx == 32768 {
            speedup_32k = res.speedup;
        }
    }
    table.print();

    let accepted = speedup_32k >= 1.5 && score_bytes_ok;
    println!(
        "acceptance: 32K attend-operator speedup {speedup_32k:.2}x {} 1.5x, score bytes/ctx-token {} r*·4",
        if speedup_32k >= 1.5 { ">=" } else { "<" },
        if score_bytes_ok { "==" } else { "!=" },
    );

    let doc = Json::obj()
        .field("bench", "sals_hotpath")
        .field(
            "config",
            "mha n_heads=4 head_dim=32 kvd=128 rank=16 r_star=8 v_bits=2 sink=4 recent=64 critical=ctx/256",
        )
        .field("quick", quick)
        .field("decode_tokens", decode_tokens)
        .field("reps", reps)
        .field("speedup_32k", speedup_32k)
        .field("score_bytes_per_ctx_token_ok", score_bytes_ok)
        .field("accepted", accepted)
        .field("rows", Json::Arr(rows));
    std::fs::write("BENCH_sals_hotpath.json", doc.to_string()).expect("write BENCH_sals_hotpath.json");
    println!("wrote BENCH_sals_hotpath.json");
    if !accepted {
        std::process::exit(1);
    }
}

//! SALS decode hot-path stage timings per token at 4K and 32K contexts —
//! the decode-operator regression gate.
//!
//! Four implementations of the same pipeline run against identical state:
//!
//! * **legacy** — a faithful in-bench replica of the pre-split-panel path:
//!   strided score scan over (len, r) latent rows (touches the full rows
//!   to read the leading r*), O(seq_len) mask-based selection merge
//!   (allocating per call), reconstruction matmul over *all* selected rows
//!   (recent rows computed then overwritten), per-row quant-store `get()`,
//!   and the per-head strided dot/axpy attention with its per-call scores
//!   allocation.
//! * **staged** — the PR-4 pipeline (`attend_staged_instrumented`):
//!   split-panel unit-stride latent scoring, O(k log k) range-merge
//!   selection, recon matmul that skips recent-ring rows into a
//!   materialized (n_sel, kvd) key panel, page-coherent value gather,
//!   packed `sparse_attend` epilogue.
//! * **fused** — the production path (`attend_instrumented`, serial
//!   handle): same score/select, then the §4.4 fused
//!   reconstruct·RoPE·QKᵀ kernel — L1-resident per-KV-head tiles +
//!   online softmax; the key panel and full score row never materialize.
//! * **fused ×N** — the fused path on a persistent [`WorkerPool`] handle
//!   of min(num_cpus, 8) workers (`SALS_THREADS` overrides):
//!   token-block-parallel score scan + per-KV-head / split-KV parallel
//!   tile loops (bit-identical output, faster wall clock). The pool is
//!   created ONCE per bench run; per-attend fan-out is a mailbox
//!   handoff, not a thread spawn.
//!
//! Two pool-specific measurements ride along:
//!
//! * **dispatch microbench** — per-call latency of an empty full-width
//!   fan-out on the pool handle vs fresh `std::thread::scope` spawns.
//!   Gate (multicore): pool handoff ≥ 5× cheaper — the margin that lets
//!   the re-derived work guards admit 4K contexts to the parallel
//!   regime.
//! * **split-KV row** — an MQA shape (4 query heads, ONE KV head) at
//!   32K, where the per-KV-head partition has nothing to split and the
//!   flash-decoding-style selection-segment partition is the only
//!   parallelism. Gate (multicore): pooled attend ≥ 1.3× serial, and
//!   the outputs must be bit-identical (fixed segment decomposition +
//!   fixed merge order).
//!
//! The workload is the paper's memory-bound decode regime (long context,
//! small critical budget, SALS-12.5% ranks — r* rows are sub-cache-line,
//! where the strided scan's waste is maximal). Acceptance at 32K:
//! staged ≥ 1.5× legacy on total; fused kernel ≥ 1.2× the staged
//! reconstruct+attend stages (the stages the fusion replaces),
//! single-threaded; the pool=N total not regressing below serial
//! (parity on tolerance in quick mode). At 4K — the mid-context regime
//! the old ~10µs spawn cost forfeited — the pooled total must be
//! strictly faster than serial on multicore. And the score stage's
//! metered traffic ≈ r*·4 bytes per context token (not r·4).
//!
//! A second table times the §Perf L6 SIMD tile kernels against the scalar
//! reference (`tensor::simd::scalar`) at the fused kernel's own shapes: the
//! QK dot tile, the softmax row scan, the PV axpy tile, and the int4 fused
//! dequant-GEMV. On AVX2+FMA hosts the gates are ≥2x on the attend tile
//! kernels (QK, softmax) and ≥1.5x on the int4 dequant-GEMV; other tiers
//! (NEON, or `SALS_SIMD=scalar`) report the columns without gating.
//!
//! Emits `BENCH_sals_hotpath.json` at the repo root; CI runs this under
//! `SALS_BENCH_QUICK=1` and fails if `accepted` is false. Quick mode
//! shortens the timing loops (same contexts and shapes).

use sals::attention::{AttentionBackend, SalsAttention, SalsConfig, SalsStageTimes};
use sals::harness::Table;
use sals::lowrank::{Calibrator, Projector};
use sals::quant::{Bits, TokenQuantStore};
use sals::rope::RopeTable;
use sals::tensor::ops::{axpy, dot, matmul, softmax};
use sals::tensor::simd::{self, SimdTier};
use sals::tensor::top_k_indices_into;
use sals::util::json::Json;
use sals::util::rng::Rng;
use sals::util::threadpool::{resolve_threads, Workers};
use std::time::Instant;

const N_HEADS: usize = 4;
const HEAD_DIM: usize = 32;
const RANK: usize = 16; // SALS-12.5% of kvd=128
const R_STAR: usize = 8;
const SINK: usize = 4;
const RECENT: usize = 64;
const V_BITS: Bits = Bits::B2;
const QGROUP: usize = 32;
const CONTEXTS: [usize; 2] = [4096, 32768];

fn kvd() -> usize {
    N_HEADS * HEAD_DIM
}

fn critical_for(ctx: usize) -> usize {
    (ctx / 256).max(32)
}

/// Low-rank key-family projector (real LLM keys are low-rank; exactness is
/// irrelevant to the timing).
fn make_projector_dims(kvd: usize, rank: usize, rng: &mut Rng) -> Projector {
    let basis: Vec<Vec<f32>> = (0..rank).map(|_| rng.normal_vec(kvd, 1.0)).collect();
    let mut cal = Calibrator::new(kvd);
    let mut row = vec![0.0f32; kvd];
    for _ in 0..512 {
        row.fill(0.0);
        for b in &basis {
            axpy(rng.normal_f32(), b, &mut row);
        }
        cal.add_key(&row);
    }
    cal.fit(rank).unwrap()
}

fn make_projector(rng: &mut Rng) -> Projector {
    make_projector_dims(kvd(), RANK, rng)
}

/// The pre-PR decode state + scratch: (len, r) row-major latents, fp32
/// recent-key ring, quantized value store — the layout the split panels
/// replaced.
struct Legacy {
    proj: Projector,
    u_t: Vec<f32>, // (r, kvd)
    rope: RopeTable,
    lat: Vec<f32>, // (len, RANK) row-major
    ring: Vec<f32>,
    recent_cap: usize,
    store: TokenQuantStore,
    len: usize,
    critical: usize,
    // Reused scratch, as the pre-PR backend had:
    qlat: Vec<f32>,
    scores: Vec<f32>,
    idx: Vec<usize>,
    lat_sel: Vec<f32>,
    keys: Vec<f32>,
    vals: Vec<f32>,
    qr: Vec<f32>,
}

impl Legacy {
    fn new(proj: Projector, max_seq: usize, critical: usize) -> Legacy {
        let kvd = kvd();
        let mut u_t = vec![0.0f32; RANK * kvd];
        for i in 0..kvd {
            for j in 0..RANK {
                u_t[j * kvd + i] = proj.u.data[i * proj.rank + j];
            }
        }
        Legacy {
            proj,
            u_t,
            rope: RopeTable::new(HEAD_DIM, max_seq, 10_000.0),
            lat: Vec::new(),
            ring: vec![0.0; RECENT * kvd],
            recent_cap: RECENT,
            store: TokenQuantStore::new(kvd, V_BITS, QGROUP, RECENT.max(QGROUP)),
            len: 0,
            critical,
            qlat: vec![0.0; RANK],
            scores: Vec::new(),
            idx: Vec::new(),
            lat_sel: Vec::new(),
            keys: Vec::new(),
            vals: Vec::new(),
            qr: Vec::new(),
        }
    }

    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = kvd();
        let start = self.lat.len();
        self.lat.resize(start + RANK, 0.0);
        self.proj.project(k, &mut self.lat[start..start + RANK]);
        let slot = self.len % self.recent_cap;
        self.ring[slot * kvd..(slot + 1) * kvd].copy_from_slice(k);
        self.store.append(v);
        self.len += 1;
    }

    /// The pre-PR mask-based O(seq_len) selection merge (allocating).
    fn mask_merge(seq_len: usize, sink: usize, recent: usize, critical: &[usize]) -> Vec<usize> {
        let mut mask = vec![false; seq_len];
        for m in mask.iter_mut().take(sink.min(seq_len)) {
            *m = true;
        }
        for m in mask[seq_len.saturating_sub(recent)..].iter_mut() {
            *m = true;
        }
        for &i in critical {
            if i < seq_len {
                mask[i] = true;
            }
        }
        mask.iter().enumerate().filter_map(|(i, &m)| m.then_some(i)).collect()
    }

    /// One decode attend through the pre-PR pipeline, accumulating
    /// per-stage wall times.
    fn attend(&mut self, q: &[f32], out: &mut [f32], times: &mut SalsStageTimes) {
        let kvd = kvd();
        let t0 = Instant::now();
        // Stage 1 (legacy): strided scan over the (len, r) rows.
        self.proj.project(q, &mut self.qlat); // MHA: pooled query == q
        self.scores.clear();
        self.scores.reserve(self.len);
        let ql = &self.qlat[..R_STAR];
        for j in 0..self.len {
            self.scores.push(dot(ql, &self.lat[j * RANK..j * RANK + R_STAR]));
        }
        let t1 = Instant::now();
        // Stage 2 (legacy): top-k + mask merge.
        top_k_indices_into(&self.scores, self.critical, &mut self.idx);
        let sel = Self::mask_merge(self.len, SINK, RECENT, &self.idx);
        let n_sel = sel.len();
        let t2 = Instant::now();
        // Stage 3 (legacy): gather + reconstruct ALL selected rows (recent
        // rows included, then overwritten), per-row value get().
        self.lat_sel.resize(n_sel * RANK, 0.0);
        self.keys.resize(n_sel * kvd, 0.0);
        self.vals.resize(n_sel * kvd, 0.0);
        for (row, &j) in sel.iter().enumerate() {
            self.lat_sel[row * RANK..(row + 1) * RANK]
                .copy_from_slice(&self.lat[j * RANK..(j + 1) * RANK]);
        }
        matmul(&self.lat_sel, &self.u_t, &mut self.keys, n_sel, RANK, kvd);
        for (row, &j) in sel.iter().enumerate() {
            if j + self.recent_cap >= self.len {
                let slot = j % self.recent_cap;
                self.keys[row * kvd..(row + 1) * kvd]
                    .copy_from_slice(&self.ring[slot * kvd..(slot + 1) * kvd]);
            }
            self.rope.apply_multihead(&mut self.keys[row * kvd..(row + 1) * kvd], j);
            self.store.get(j, &mut self.vals[row * kvd..(row + 1) * kvd]);
        }
        let t3 = Instant::now();
        // Stage 4 (legacy): per-head strided dot/axpy exact attention with
        // the per-call scores allocation.
        self.qr.clear();
        self.qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.qr, self.len - 1);
        let scale = 1.0 / (HEAD_DIM as f32).sqrt();
        let mut s = vec![0.0f32; n_sel];
        out.fill(0.0);
        for h in 0..N_HEADS {
            let qh = &self.qr[h * HEAD_DIM..(h + 1) * HEAD_DIM];
            for (j, sj) in s.iter_mut().enumerate() {
                let krow = &self.keys[j * kvd + h * HEAD_DIM..j * kvd + (h + 1) * HEAD_DIM];
                *sj = dot(qh, krow) * scale;
            }
            softmax(&mut s);
            let oh = &mut out[h * HEAD_DIM..(h + 1) * HEAD_DIM];
            for (j, &p) in s.iter().enumerate() {
                let vrow = &self.vals[j * kvd + h * HEAD_DIM..j * kvd + (h + 1) * HEAD_DIM];
                axpy(p, vrow, oh);
            }
        }
        let t4 = Instant::now();
        times.score += (t1 - t0).as_secs_f64();
        times.select += (t2 - t1).as_secs_f64();
        times.reconstruct += (t3 - t2).as_secs_f64();
        times.attend += (t4 - t3).as_secs_f64();
    }
}

struct CtxResult {
    legacy: SalsStageTimes,
    staged: SalsStageTimes,
    fused: SalsStageTimes,
    fused_mt: SalsStageTimes,
    /// staged total vs legacy total (the PR-4 gate).
    staged_speedup: f64,
    /// Fused kernel vs the staged stages it replaces:
    /// (staged.reconstruct + staged.attend) / fused.attend.
    fused_kernel_speedup: f64,
    /// fused threads=1 total vs threads=N total.
    mt_speedup: f64,
    score_bytes_per_ctx_token: f64,
}

fn run_context(
    ctx: usize,
    reps: usize,
    decode_tokens: usize,
    pool: &Workers,
    rng: &mut Rng,
) -> CtxResult {
    let kvd = kvd();
    let qd = N_HEADS * HEAD_DIM;
    let max_seq = ctx + 8;
    let shape = sals::attention::AttnShape::mha(N_HEADS, HEAD_DIM, max_seq);
    let proj = make_projector(rng);
    let critical = critical_for(ctx);
    let cfg = SalsConfig {
        rank: RANK,
        r_star: R_STAR,
        sink: SINK,
        recent: RECENT,
        critical,
        v_bits: V_BITS,
        group: QGROUP,
        prefill: None,
    };
    let mut packed = SalsAttention::new(shape, cfg, proj.clone());
    let mut legacy = Legacy::new(proj, max_seq, critical);

    // Prefill both to `ctx` tokens from the same stream (chunked batched
    // appends for the packed path, per-row appends for the legacy one).
    const CHUNK: usize = 1024;
    let mut done = 0;
    while done < ctx {
        let n = CHUNK.min(ctx - done);
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        packed.append_batch(&ks, &vs, n);
        for t in 0..n {
            legacy.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
        done += n;
    }
    packed.end_prefill();

    // Score-stage traffic probe: the panel scan must meter ≈ r*·4 bytes
    // per context token.
    let q = rng.normal_vec(qd, 1.0);
    let before = packed.traffic().read;
    let _ = packed.latent_scores(&q);
    let score_bytes_per_ctx_token = (packed.traffic().read - before) as f64 / ctx as f64;

    // Attends do not mutate cache state, so all four paths are timed
    // against the identical frozen context; best-of-`reps` per path.
    let mut out = vec![0.0f32; qd];
    let mut best = [SalsStageTimes::default(); 4]; // legacy, staged, fused, fused_mt
    let mut best_total = [f64::INFINITY; 4];
    fn keep(
        slot: usize,
        t: SalsStageTimes,
        best: &mut [SalsStageTimes; 4],
        best_total: &mut [f64; 4],
    ) {
        if t.total() < best_total[slot] {
            best_total[slot] = t.total();
            best[slot] = t;
        }
    }
    for _ in 0..reps {
        let mut tl = SalsStageTimes::default();
        for _ in 0..decode_tokens {
            legacy.attend(&q, &mut out, &mut tl);
        }
        keep(0, tl, &mut best, &mut best_total);
        let mut ts = SalsStageTimes::default();
        for _ in 0..decode_tokens {
            packed.attend_staged_instrumented(&q, &mut out, &mut ts);
        }
        keep(1, ts, &mut best, &mut best_total);
        packed.set_workers(&Workers::serial());
        let mut tf = SalsStageTimes::default();
        for _ in 0..decode_tokens {
            packed.attend_instrumented(&q, &mut out, &mut tf);
        }
        keep(2, tf, &mut best, &mut best_total);
        packed.set_workers(pool);
        let mut tm = SalsStageTimes::default();
        for _ in 0..decode_tokens {
            packed.attend_instrumented(&q, &mut out, &mut tm);
        }
        keep(3, tm, &mut best, &mut best_total);
    }
    let scale_to_per_token = |t: SalsStageTimes| SalsStageTimes {
        score: t.score / decode_tokens as f64,
        select: t.select / decode_tokens as f64,
        reconstruct: t.reconstruct / decode_tokens as f64,
        attend: t.attend / decode_tokens as f64,
    };
    let legacy_t = scale_to_per_token(best[0]);
    let staged_t = scale_to_per_token(best[1]);
    let fused_t = scale_to_per_token(best[2]);
    let fused_mt_t = scale_to_per_token(best[3]);
    CtxResult {
        legacy: legacy_t,
        staged: staged_t,
        fused: fused_t,
        fused_mt: fused_mt_t,
        staged_speedup: legacy_t.total() / staged_t.total(),
        fused_kernel_speedup: (staged_t.reconstruct + staged_t.attend) / fused_t.attend,
        mt_speedup: fused_t.total() / fused_mt_t.total(),
        score_bytes_per_ctx_token,
    }
}

struct SplitKvResult {
    serial_us: f64,
    pooled_us: f64,
    /// serial total / pooled total per decode attend.
    speedup: f64,
    /// Pooled output must equal the serial output bit-for-bit.
    bit_identical: bool,
}

/// Split-KV decode attend at an MQA shape: 4 query heads over ONE KV head
/// (kv_dim = 32), where the per-KV-head partition has nothing to split —
/// before the selection-segment decomposition, this shape was pinned
/// serial no matter how many workers the engine offered. At 32K the
/// selection (sink 4 + recent 64 + critical ctx/256) is ~196 rows ≥
/// `SPLIT_KV_MIN_SEL`, so the fused kernel folds fixed 64-row segments on
/// separate workers and merges the online-softmax partials in segment
/// order.
fn run_split_kv(
    ctx: usize,
    reps: usize,
    decode_tokens: usize,
    pool: &Workers,
    rng: &mut Rng,
) -> SplitKvResult {
    let max_seq = ctx + 8;
    let shape = sals::attention::AttnShape::gqa(N_HEADS, 1, HEAD_DIM, max_seq);
    let kvd = shape.kv_dim();
    let qd = shape.q_dim();
    let (rank, r_star) = (8, 4); // SALS-25% of kv_dim=32; r* rows stay sub-cache-line
    let proj = make_projector_dims(kvd, rank, rng);
    let cfg = SalsConfig {
        rank,
        r_star,
        sink: SINK,
        recent: RECENT,
        critical: critical_for(ctx),
        v_bits: V_BITS,
        group: kvd,
        prefill: None,
    };
    let mut packed = SalsAttention::new(shape, cfg, proj);

    const CHUNK: usize = 1024;
    let mut done = 0;
    while done < ctx {
        let n = CHUNK.min(ctx - done);
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        packed.append_batch(&ks, &vs, n);
        done += n;
    }
    packed.end_prefill();

    let q = rng.normal_vec(qd, 1.0);
    let mut out_serial = vec![0.0f32; qd];
    let mut out_pooled = vec![0.0f32; qd];
    let mut best = [f64::INFINITY; 2];
    for _ in 0..reps {
        packed.set_workers(&Workers::serial());
        let t0 = Instant::now();
        for _ in 0..decode_tokens {
            packed.attend(&q, &mut out_serial);
        }
        best[0] = best[0].min(t0.elapsed().as_secs_f64());
        packed.set_workers(pool);
        let t1 = Instant::now();
        for _ in 0..decode_tokens {
            packed.attend(&q, &mut out_pooled);
        }
        best[1] = best[1].min(t1.elapsed().as_secs_f64());
    }
    let per = |secs: f64| secs / decode_tokens as f64 * 1e6;
    SplitKvResult {
        serial_us: per(best[0]),
        pooled_us: per(best[1]),
        speedup: best[0] / best[1],
        bit_identical: out_serial == out_pooled,
    }
}

/// One SIMD-vs-scalar microkernel measurement (per-call nanoseconds, best
/// of the timing passes).
struct MicroRow {
    kernel: &'static str,
    scalar_ns: f64,
    simd_ns: f64,
    /// Acceptance floor enforced when the dispatched tier is AVX2+FMA;
    /// `None` = informational column. The PV axpy tile is informational
    /// because its exact-class kernel keeps multiply and add separate (no
    /// FMA, the scalar bit-parity contract), so its ceiling over the SSE2
    /// auto-vectorized scalar build is too low to gate without flaking.
    gate: Option<f64>,
}

impl MicroRow {
    fn speedup(&self) -> f64 {
        self.scalar_ns / self.simd_ns
    }
}

/// Best-of-`reps` wall time (seconds) of `iters` calls to `f`. The f32
/// checksum flows into `black_box` so the optimizer can't delete the
/// kernel body.
fn time_kernel(reps: usize, iters: usize, mut f: impl FnMut() -> f32) -> f64 {
    let mut best = f64::INFINITY;
    let mut sink = 0.0f32;
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            sink += f();
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(sink);
    best
}

/// Scalar-vs-dispatched timings for the decode tile kernels (§Perf L6).
/// Shapes mirror the fused attend at this bench's config: d=32 head tiles
/// over 64-row panels, a 256-wide softmax row, and an int4 dequant-GEMV
/// over one head's 32-channel column slice of (64, kvd=128) value rows.
fn run_simd_microbench(quick: bool, rng: &mut Rng) -> Vec<MicroRow> {
    let iters = if quick { 4_000 } else { 40_000 };
    let reps = 5;
    let d = HEAD_DIM;
    let t = 64;
    let q = rng.normal_vec(d, 1.0);
    let keys = rng.normal_vec(t * d, 1.0);
    let w = rng.normal_vec(t, 1.0);
    let row0 = rng.normal_vec(256, 1.0);
    let mut row = row0.clone();
    let mut acc = vec![0.0f32; d];
    let kvdim = kvd();
    let mut codes = vec![0u8; t * kvdim / 2];
    for b in codes.iter_mut() {
        *b = rng.below(256) as u8;
    }
    let scale = rng.normal_vec(kvdim, 0.1);
    let zero = rng.normal_vec(kvdim, 0.1);
    let (c0, c1) = (d, 2 * d); // head 1's channel slice: a nonzero packed offset

    let qk_scalar = time_kernel(reps, iters, || {
        let mut s = 0.0;
        for r in 0..t {
            s += simd::scalar::dot(&q, &keys[r * d..(r + 1) * d]);
        }
        s
    });
    let qk_simd = time_kernel(reps, iters, || {
        let mut s = 0.0;
        for r in 0..t {
            s += simd::dot(&q, &keys[r * d..(r + 1) * d]);
        }
        s
    });

    let sm_scalar = time_kernel(reps, iters, || {
        row.copy_from_slice(&row0);
        let m = simd::scalar::max(&row);
        let s = simd::scalar::exp_sum(&mut row, m);
        simd::scalar::scale(&mut row, 1.0 / s);
        row[0]
    });
    let sm_simd = time_kernel(reps, iters, || {
        row.copy_from_slice(&row0);
        let m = simd::max(&row);
        let s = simd::exp_sum(&mut row, m);
        simd::scale(&mut row, 1.0 / s);
        row[0]
    });

    let pv_scalar = time_kernel(reps, iters, || {
        acc.fill(0.0);
        for r in 0..t {
            simd::scalar::axpy(w[r], &keys[r * d..(r + 1) * d], &mut acc);
        }
        acc[0]
    });
    let pv_simd = time_kernel(reps, iters, || {
        acc.fill(0.0);
        for r in 0..t {
            simd::axpy(w[r], &keys[r * d..(r + 1) * d], &mut acc);
        }
        acc[0]
    });

    let dq_scalar = time_kernel(reps, iters, || {
        acc.fill(0.0);
        for r in 0..t {
            simd::scalar::dequant_axpy_b4(
                w[r],
                &codes,
                r * kvdim + c0,
                &scale[c0..c1],
                &zero[c0..c1],
                &mut acc,
            );
        }
        acc[0]
    });
    let dq_simd = time_kernel(reps, iters, || {
        acc.fill(0.0);
        for r in 0..t {
            simd::dequant_axpy_b4(
                w[r],
                &codes,
                r * kvdim + c0,
                &scale[c0..c1],
                &zero[c0..c1],
                &mut acc,
            );
        }
        acc[0]
    });

    let ns = |secs: f64| secs / iters as f64 * 1e9;
    vec![
        MicroRow {
            kernel: "attend_qk (64x d=32 dot)",
            scalar_ns: ns(qk_scalar),
            simd_ns: ns(qk_simd),
            gate: Some(2.0),
        },
        MicroRow {
            kernel: "attend_softmax (256 row)",
            scalar_ns: ns(sm_scalar),
            simd_ns: ns(sm_simd),
            gate: Some(2.0),
        },
        MicroRow {
            kernel: "pv_axpy (64x d=32)",
            scalar_ns: ns(pv_scalar),
            simd_ns: ns(pv_simd),
            gate: None,
        },
        MicroRow {
            kernel: "dequant_gemv_int4 (64x d=32)",
            scalar_ns: ns(dq_scalar),
            simd_ns: ns(dq_simd),
            gate: Some(1.5),
        },
    ]
}

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let (reps, decode_tokens) = if quick { (3, 5) } else { (3, 10) };
    let threads_n = resolve_threads(0).min(8);
    let pool = if threads_n > 1 { Workers::pooled(threads_n) } else { Workers::serial() };
    let mut rng = Rng::new(2026);

    // Dispatch microbench: per-call latency of an empty full-width
    // fan-out. The pool's mailbox handoff must beat fresh scoped spawns
    // by the margin the re-derived work guards assume.
    let pool_dispatch_ns = pool.dispatch_ns();
    let scoped_dispatch_ns = Workers::scoped(threads_n).dispatch_ns();
    let dispatch_speedup = scoped_dispatch_ns / pool_dispatch_ns;
    let dispatch_ok = threads_n <= 1 || dispatch_speedup >= 5.0;
    println!(
        "pool dispatch (width {threads_n}): {pool_dispatch_ns:.0} ns vs scoped spawn \
         {scoped_dispatch_ns:.0} ns — {dispatch_speedup:.1}x"
    );

    let mut table = Table::new(
        "SALS decode hot path — per-token stage times (µs): legacy vs staged vs fused",
        &["Ctx", "Path", "Score", "Select", "Reconstruct", "Attend", "Total", "Speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut staged_speedup_32k = 0.0;
    let mut fused_kernel_speedup_32k = 0.0;
    let mut mt_speedup_32k = 0.0;
    let mut mid_mt_speedup_4k = 0.0;
    let mut score_bytes_ok = true;
    let rstar_bytes = (R_STAR * 4) as f64;

    for &ctx in &CONTEXTS {
        let res = run_context(ctx, reps, decode_tokens, &pool, &mut rng);
        let us = 1e6;
        let fused_mt_label = format!("fused x{threads_n}");
        for (path, t, speed) in [
            ("legacy", res.legacy, String::new()),
            ("staged", res.staged, format!("{:.2}x vs legacy", res.staged_speedup)),
            ("fused", res.fused, format!("{:.2}x kernel vs staged", res.fused_kernel_speedup)),
            (fused_mt_label.as_str(), res.fused_mt, format!("{:.2}x vs fused x1", res.mt_speedup)),
        ] {
            table.row(vec![
                ctx.to_string(),
                path.to_string(),
                format!("{:.1}", t.score * us),
                format!("{:.1}", t.select * us),
                format!("{:.1}", t.reconstruct * us),
                format!("{:.1}", t.attend * us),
                format!("{:.1}", t.total() * us),
                speed,
            ]);
            rows.push(
                Json::obj()
                    .field("ctx", ctx)
                    .field("path", path)
                    .field("score_us", t.score * us)
                    .field("select_us", t.select * us)
                    .field("reconstruct_us", t.reconstruct * us)
                    .field("attend_us", t.attend * us)
                    .field("total_us", t.total() * us),
            );
        }
        println!(
            "ctx {ctx}: score stage streams {:.1} B/ctx-token (r*·4 = {rstar_bytes}, r·4 = {})",
            res.score_bytes_per_ctx_token,
            RANK * 4
        );
        // The meter must reflect the panel scan: r*·4, not r·4.
        score_bytes_ok &= res.score_bytes_per_ctx_token <= rstar_bytes * 1.01;
        if ctx == 4096 {
            mid_mt_speedup_4k = res.mt_speedup;
        }
        if ctx == 32768 {
            staged_speedup_32k = res.staged_speedup;
            fused_kernel_speedup_32k = res.fused_kernel_speedup;
            mt_speedup_32k = res.mt_speedup;
        }
    }

    // Split-KV row: MQA shape where the segment partition is the only
    // available parallelism (see `run_split_kv`).
    let split = run_split_kv(32768, reps, decode_tokens, &pool, &mut rng);
    table.row(vec![
        "32768".to_string(),
        "split-kv mqa".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        format!("{:.1} -> {:.1}", split.serial_us, split.pooled_us),
        format!("{:.1}", split.pooled_us),
        format!("{:.2}x vs serial", split.speedup),
    ]);
    table.print();

    // §Perf L6: scalar-vs-SIMD tile-kernel microbenches. Gates are enforced
    // only when the dispatched tier is AVX2+FMA — under `SALS_SIMD=scalar`
    // (or on a pre-AVX2 host) both columns time the same code and the
    // speedup is ~1x by construction, and NEON hosts report without gating
    // (the gate calibration is x86 CI hardware).
    let tier = simd::tier();
    let gates_enforced = tier == SimdTier::Avx2Fma;
    let micro = run_simd_microbench(quick, &mut rng);
    let mut mtable = Table::new(
        &format!("SIMD microkernels — dispatched tier ({}) vs scalar reference", simd::tier_name()),
        &["Kernel", "Scalar ns", "SIMD ns", "Speedup", "Gate"],
    );
    let mut micro_rows: Vec<Json> = Vec::new();
    let mut simd_gates_ok = true;
    for m in &micro {
        let s = m.speedup();
        if gates_enforced && m.gate.is_some_and(|g| s < g) {
            simd_gates_ok = false;
        }
        mtable.row(vec![
            m.kernel.to_string(),
            format!("{:.1}", m.scalar_ns),
            format!("{:.1}", m.simd_ns),
            format!("{s:.2}x"),
            match m.gate {
                Some(g) if gates_enforced => format!(">= {g}x"),
                Some(g) => format!("({g}x on avx2)"),
                None => "info".to_string(),
            },
        ]);
        micro_rows.push(
            Json::obj()
                .field("kernel", m.kernel)
                .field("scalar_ns", m.scalar_ns)
                .field("simd_ns", m.simd_ns)
                .field("speedup", s)
                .field("gate_min", m.gate.unwrap_or(0.0)),
        );
    }
    mtable.print();
    println!(
        "simd gates ({}): {}",
        simd::tier_name(),
        if !gates_enforced {
            "reported only (non-avx2 tier)"
        } else if simd_gates_ok {
            "pass"
        } else {
            "FAIL"
        },
    );

    // Gates: the PR-4 staged-vs-legacy floor; the fused kernel vs the two
    // staged stages it replaces (reconstruct+attend), single-threaded; on
    // multicore only — the pooled 32K total must not regress below serial
    // (a no-worse floor, NOT a strict-speedup gate: gating strictly above
    // 1.0 on a microsecond-scale measurement would flake; the measured mt
    // speedup is reported in the column/JSON for the trajectory), the
    // pooled 4K total must be STRICTLY faster than serial (the
    // mid-context win the ~10µs spawn cost used to forfeit — at 4K the
    // whole attend is tens of µs, so the sub-µs pool handoff must pay for
    // itself), the pool handoff must be ≥5x cheaper than scoped spawn,
    // and the MQA split-KV attend must be ≥1.3x serial at 32K and
    // bit-identical. Quick mode (CI's 2-vCPU runners, 5-token timing
    // loops) tolerates 5% scheduler noise around the 32K floor.
    let staged_ok = staged_speedup_32k >= 1.5;
    let fused_ok = fused_kernel_speedup_32k >= 1.2;
    let mt_floor = if quick { 0.95 } else { 1.0 };
    let mt_ok = threads_n <= 1 || mt_speedup_32k >= mt_floor;
    let mt4k_ok = threads_n <= 1 || mid_mt_speedup_4k > 1.0;
    let split_ok = split.bit_identical && (threads_n <= 1 || split.speedup >= 1.3);
    let accepted = staged_ok
        && fused_ok
        && mt_ok
        && mt4k_ok
        && dispatch_ok
        && split_ok
        && score_bytes_ok
        && simd_gates_ok;
    println!(
        "acceptance: 32K staged {staged_speedup_32k:.2}x {} 1.5x legacy; fused kernel \
         {fused_kernel_speedup_32k:.2}x {} 1.2x staged recon+attend; pool x{threads_n} \
         {mt_speedup_32k:.2}x {} {mt_floor}x serial at 32K, {mid_mt_speedup_4k:.2}x {} 1x at 4K; \
         dispatch {dispatch_speedup:.1}x {} 5x scoped; split-KV {:.2}x {} 1.3x serial \
         (bit-identical: {}); score bytes/ctx-token {} r*·4",
        if staged_ok { ">=" } else { "<" },
        if fused_ok { ">=" } else { "<" },
        if mt_ok { ">=" } else { "<" },
        if mt4k_ok { ">" } else { "<=" },
        if dispatch_ok { ">=" } else { "<" },
        split.speedup,
        if split.speedup >= 1.3 { ">=" } else { "<" },
        split.bit_identical,
        if score_bytes_ok { "==" } else { "!=" },
    );

    let doc = sals::harness::bench_doc("sals_hotpath")
        .field(
            "config",
            "mha n_heads=4 head_dim=32 kvd=128 rank=16 r_star=8 v_bits=2 sink=4 recent=64 critical=ctx/256",
        )
        .field("quick", quick)
        .field("decode_tokens", decode_tokens)
        .field("reps", reps)
        .field("threads_n", threads_n as i64)
        .field("speedup_32k", staged_speedup_32k)
        .field("fused_kernel_speedup_32k", fused_kernel_speedup_32k)
        .field("fused_mt_speedup_32k", mt_speedup_32k)
        .field("mid_mt_speedup_4k", mid_mt_speedup_4k)
        .field("bench_pool_dispatch_ns", pool_dispatch_ns)
        .field("scoped_dispatch_ns", scoped_dispatch_ns)
        .field("dispatch_speedup", dispatch_speedup)
        .field(
            "split_kv",
            Json::obj()
                .field("serial_us", split.serial_us)
                .field("pooled_us", split.pooled_us)
                .field("speedup_32k", split.speedup)
                .field("bit_identical", split.bit_identical),
        )
        .field("score_bytes_per_ctx_token_ok", score_bytes_ok)
        .field("simd_gates_enforced", gates_enforced)
        .field("simd_gates_ok", simd_gates_ok)
        .field("accepted", accepted)
        .field("simd_rows", Json::Arr(micro_rows))
        .field("rows", Json::Arr(rows));
    let path = sals::harness::bench_artifact_path("BENCH_sals_hotpath.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_sals_hotpath.json");
    println!("wrote {}", path.display());
    if !accepted {
        std::process::exit(1);
    }
}

//! Figure 1(a): naive pre-RoPE low-rank compression (Palu-style full
//! reconstruction) becomes SLOWER than standard attention as sequence grows
//! — the overhead SALS's selective reconstruction eliminates.

use sals::attention::baselines::palu::PaluAttention;
use sals::attention::{AttentionBackend, AttnShape, FullAttention, SalsAttention, SalsConfig};
use sals::harness::{ms_pm, Table};
use sals::lowrank::Calibrator;
use sals::util::rng::Rng;
use sals::util::timer::time_iters;

fn projector(kv_dim: usize, rank: usize, seed: u64) -> sals::lowrank::Projector {
    let mut rng = Rng::new(seed);
    let mut cal = Calibrator::new(kv_dim);
    for _ in 0..192 {
        cal.add_key(&rng.normal_vec(kv_dim, 1.0));
    }
    cal.fit(rank).unwrap()
}

fn main() {
    let mut table = Table::new(
        "Figure 1(a) — decode attention time vs sequence length (ms)",
        &["Seq", "full attention", "low-rank full-reconstruct (Palu)", "SALS selective"],
    );
    for &s in &[1024usize, 2048, 4096, 6144] {
        let sh = AttnShape::mha(8, 64, s + 8);
        let kvd = sh.kv_dim();
        let mut rng = Rng::new(606 + s as u64);
        let reps = 5;

        let mut full = FullAttention::new(sh);
        let kp = projector(kvd, kvd / 4, 1);
        let vp = projector(kvd, kvd / 4, 2);
        let mut palu = PaluAttention::new(sh, kp, vp, kvd / 4, None);
        let p = projector(kvd, kvd / 4, 3);
        let mut sals = SalsAttention::new(sh, SalsConfig::sals_25(kvd, 16, s / 8, 64), p);
        for _ in 0..s {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            full.append(&k, &v);
            palu.append(&k, &v);
            sals.append(&k, &v);
        }
        let q = rng.normal_vec(sh.q_dim(), 1.0);
        let mut out = vec![0.0f32; sh.q_dim()];
        let t_full = time_iters(1, reps, || full.attend(&q, &mut out));
        let t_palu = time_iters(1, reps, || palu.attend(&q, &mut out));
        let t_sals = time_iters(1, reps, || sals.attend(&q, &mut out));
        table.row(vec![s.to_string(), ms_pm(&t_full), ms_pm(&t_palu), ms_pm(&t_sals)]);
    }
    table.print();
    println!("\npaper: low-rank-with-reconstruction crosses ABOVE standard attention by 32k; SALS stays below");
}

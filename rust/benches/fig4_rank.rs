//! Figure 4 / Figure 1(b): RoPE's effect on key geometry — eigenvalue
//! spectra and Rank(90) pre/post RoPE per layer, plus the 2-plane PCA
//! rotation demo.

use sals::analyze::{pca_rope_demo, rank_analysis};
use sals::harness::{Experiment, Table};
use sals::linalg::rank_at_energy;

fn main() {
    // --- Figure 1(b): PCA rotation + scatter under RoPE ---
    let rep = pca_rope_demo(64, 2048, 10_000.0, 7);
    println!("=== Figure 1(b) — PCA under RoPE (head_dim=64, 2048 positions) ===");
    println!("leading eigenvalue   pre {:.3}  post {:.3}", rep.lead_eig_pre, rep.lead_eig_post);
    println!("anisotropy λ1/λ2     pre {:.2}  post {:.2}  (drop = scatter)", rep.anisotropy_pre, rep.anisotropy_post);
    println!("principal-axis |cos| {:.3}  (<1 = rotated away)", rep.principal_cos);
    println!(
        "rank90               pre {}  post {}",
        rank_at_energy(&rep.spectrum_pre, 90.0),
        rank_at_energy(&rep.spectrum_post, 90.0)
    );

    // --- Figure 4: per-layer Rank(90) on model calibration keys ---
    // Uses the LLaMA-shaped model at rope_base 1e4 (the retrieval model's
    // deliberately huge base makes RoPE a near-no-op and hides the effect).
    let cfg = sals::model::ModelConfig::tiny_mha(256);
    let model = sals::model::Model::new(
        cfg.clone(),
        std::sync::Arc::new(sals::model::Weights::random_lowrank_keys(&cfg, 12, cfg.kv_dim() / 8)),
    );
    let mut rng = sals::util::rng::Rng::new(606060 ^ 0xCA11B);
    let streams: Vec<Vec<usize>> = (0..4)
        .map(|_| (0..128).map(|_| rng.below(cfg.vocab)).collect())
        .collect();
    let calib = sals::model::calibrate(&model, &streams);
    let cfg = &cfg;

    let mut table = Table::new(
        "Figure 4(c,d) — Rank_l(90) per layer, pre vs post RoPE",
        &["Layer", "rank90 pre-RoPE", "rank90 post-RoPE", "inflation"],
    );
    for (l, lc) in calib.layers.iter().enumerate() {
        let rep = rank_analysis(l, &lc.pre_keys.data, cfg.kv_dim(), cfg.head_dim, 128, 10_000.0);
        table.row(vec![
            l.to_string(),
            rep.rank90_pre.to_string(),
            rep.rank90_post.to_string(),
            format!("{:.2}x", rep.rank90_post as f64 / rep.rank90_pre.max(1) as f64),
        ]);
    }
    table.print();
    println!("\npaper: post-RoPE consistently needs HIGHER rank for 90% energy, on every layer");
}

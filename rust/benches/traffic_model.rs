//! §4.5 memory-traffic model: closed-form speedup 2sd/(s·r* + 2kr) vs the
//! traffic actually metered by the backends, and the fused-kernel traffic
//! cut (paper: 7.69×–14.28×).

use sals::attention::traffic::{fused_kernel_traffic_cut, sals_speedup_model};
use sals::attention::{AttentionBackend, AttnShape, FullAttention, SalsAttention, SalsConfig};
use sals::harness::Table;
use sals::lowrank::Calibrator;
use sals::util::rng::Rng;

fn main() {
    let mut table = Table::new(
        "§4.5 — modeled vs measured memory-traffic speedup (SALS-25%)",
        &["Seq", "model 2sd/(sr*+2kr)", "measured full/sals bytes"],
    );
    for &s in &[1024usize, 2048, 4096] {
        let sh = AttnShape::mha(8, 64, s + 8);
        let kvd = sh.kv_dim();
        let (r, rs, k) = (kvd / 4, kvd / 8, s / 8);
        let modeled = sals_speedup_model(s, kvd, r, rs, k);

        let mut rng = Rng::new(42 + s as u64);
        let mut cal = Calibrator::new(kvd);
        for _ in 0..128 {
            cal.add_key(&rng.normal_vec(kvd, 1.0));
        }
        let proj = cal.fit(r).unwrap();
        let mut full = FullAttention::new(sh);
        let mut sals = SalsAttention::new(sh, SalsConfig::sals_25(kvd, 16, k, 64), proj);
        for _ in 0..s {
            let kk = rng.normal_vec(kvd, 1.0);
            let vv = rng.normal_vec(kvd, 1.0);
            full.append(&kk, &vv);
            sals.append(&kk, &vv);
        }
        let q = rng.normal_vec(sh.q_dim(), 1.0);
        let mut out = vec![0.0f32; sh.q_dim()];
        let f0 = full.traffic().read;
        full.attend(&q, &mut out);
        let s0 = sals.traffic().read;
        sals.attend(&q, &mut out);
        let measured = (full.traffic().read - f0) as f64 / (sals.traffic().read - s0) as f64;
        table.row(vec![s.to_string(), format!("{modeled:.2}x"), format!("{measured:.2}x")]);
    }
    table.print();

    let mut t2 = Table::new(
        "§4.5 — fused-kernel traffic cut across settings (paper: 7.69–14.28x)",
        &["d_r", "k/s", "cut"],
    );
    let d = 4096;
    for (dr, ks) in [(4usize, 4usize), (4, 8), (8, 8), (8, 16)] {
        let cut = fused_kernel_traffic_cut(4096, d, d / dr, d / (2 * dr), 4096 / ks);
        t2.row(vec![format!("1/{dr}"), format!("1/{ks}"), format!("{cut:.2}x")]);
    }
    t2.print();
}

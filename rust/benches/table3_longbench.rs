//! Table 3: LongBench-proxy categories × KV-compression methods, on both
//! the MHA (LLaMA2-analog) and GQA (Mistral-analog) retrieval models.
//!
//! Paper shape: SALS-25% within noise of baseline at ~0.11 memory access;
//! SALS-12.5% still competitive at ~0.06; Palu degrades hardest on
//! reasoning-heavy categories.

use sals::harness::{pct, Experiment, Table};
use sals::model::Method;
use sals::util::rng::Rng;
use sals::workload::longbench::{generate, LongBenchTask};
use sals::workload::runner;

fn run_variant(gqa: bool, label: &str) {
    let ctx = 256;
    let exp = Experiment::new(ctx, gqa, 31337);
    let mut rng = Rng::new(888);
    let tasks = LongBenchTask::all();
    // Pre-generate per-category suites (shared across methods).
    let suites: Vec<Vec<sals::workload::Trial>> = tasks
        .iter()
        .map(|&t| {
            let mut trials = Vec::new();
            for _ in 0..6 {
                trials.extend(generate(&exp.rm, t, ctx, &mut rng));
            }
            trials
        })
        .collect();

    let mut header: Vec<&str> = vec!["Method"];
    let names: Vec<String> = tasks.iter().map(|t| t.name().to_string()).collect();
    header.extend(names.iter().map(|s| s.as_str()));
    header.push("Avg");
    header.push("MemAccess↓");
    let mut table = Table::new(&format!("Table 3 — LongBench proxies ({label})"), &header);

    let mut base_read = 0.0f64;
    for method in Method::accuracy_set() {
        let factory = exp.factory(method);
        let mut row = vec![method.name().to_string()];
        let mut accs = Vec::new();
        let mut read = 0.0f64;
        for suite in &suites {
            let res = runner::evaluate(&exp.rm, &exp.model, &factory, suite, 0);
            accs.push(res.accuracy());
            read += res.read_bytes as f64;
        }
        if method == Method::Full {
            base_read = read;
        }
        for a in &accs {
            row.push(pct(*a));
        }
        row.push(pct(accs.iter().sum::<f64>() / accs.len() as f64));
        row.push(format!("{:.2}", read / base_read));
        table.row(row);
    }
    table.print();
}

fn main() {
    run_variant(false, "MHA / LLaMA2-analog");
    run_variant(true, "GQA / Mistral-analog");
    println!("\npaper: SALS-25% avg 32.26 vs baseline 32.65 @0.11; SALS-12.5% 31.97 @0.06 (LLaMA2)");
}

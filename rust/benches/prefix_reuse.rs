//! Prefix reuse under 90% shared-prefix traffic: the serving win of
//! giving KV pages identity. A warm, published system prompt lets 90% of
//! requests adopt its panels instead of re-prefilling them, so the bench
//! measures (a) the fraction of prompt tokens never prefilled and (b)
//! concurrent capacity on the SAME pool vs the no-reuse engine — both for
//! dense fp32 and the SALS backend.
//!
//! Acceptance (machine-checked, exit non-zero on failure):
//!   * ≥ 80% of trace prompt tokens avoided at 90% shared traffic,
//!   * strictly higher peak concurrency than no-reuse on the same pool,
//!   * reuse is semantically invisible — every request's tokens are
//!     bit-identical to the cold run (adoption boundaries are chunk
//!     multiples, so both runs execute the same chunk schedule).
//!
//! Emits `BENCH_prefix_reuse.json`. `SALS_BENCH_QUICK=1` shortens the run.

use sals::coordinator::{Engine, EngineConfig, GenParams, Request};
use sals::harness::Table;
use sals::model::{make_factory, Method, Model, ModelConfig, SequenceFootprint, Weights};
use sals::util::json::Json;
use sals::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let quick = std::env::var("SALS_BENCH_QUICK").is_ok();
    let chunk = if quick { 32 } else { 64 };
    // Shared prefix = 5 chunks; every prompt adds a short unique suffix
    // (prefix/prompt = 10/11, so 90% shared traffic can clear the 80%
    // avoided-tokens bar with margin: 0.9 × 10/11 ≈ 82%).
    let (prefix_len, suffix_len, decode_n) = (5 * chunk, chunk / 2, 8);
    let n_requests = if quick { 20 } else { 30 };
    let n_shared = n_requests * 9 / 10; // 90% shared-prefix traffic
    let prompt_len = prefix_len + suffix_len;
    let max_seq = prompt_len + decode_n + 8;

    let cfg = ModelConfig {
        vocab: 512,
        d_model: 256,
        n_layers: 6,
        n_heads: 8,
        n_kv_heads: 8,
        head_dim: 32,
        d_ff: 512,
        max_seq,
        rope_base: 10_000.0,
        dense_layers: vec![0],
        rms_eps: 1e-5,
    };

    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 88)));
    let mut rng = Rng::new(4242);
    let streams: Vec<Vec<usize>> =
        (0..2).map(|_| (0..128).map(|_| rng.below(cfg.vocab)).collect()).collect();
    let calib = sals::model::calibrate(&model, &streams);
    let fitted = Arc::new(sals::model::fit_calibration(&cfg, &calib));
    let sp = sals::model::SparsityParams::scaled(prompt_len);

    // One fixed trace: a priming request carrying the bare shared prefix
    // (publishes it), then the 90/10 mix in arrival order.
    let mut trng = Rng::new(991);
    let shared_prefix: Vec<usize> = (0..prefix_len).map(|_| trng.below(cfg.vocab)).collect();
    let prompts: Vec<Vec<usize>> = (0..n_requests)
        .map(|i| {
            let mut p = if i < n_shared { shared_prefix.clone() } else { Vec::new() };
            while p.len() < prompt_len {
                p.push(trng.below(cfg.vocab));
            }
            p
        })
        .collect();
    let trace_prompt_tokens: usize = prompts.iter().map(|p| p.len()).sum();

    let mut table = Table::new(
        "Prefix reuse at 90% shared-prefix traffic (same pool, reuse off vs on)",
        &["Method", "Reuse", "Avoided tok", "Avoided %", "Peak concurrent", "Adoptions", "tok/s"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut all_ok = true;

    for method in [Method::Full, Method::Sals25] {
        // Pool: ~3 full-horizon reservations of THIS method, so capacity
        // differences within a method come purely from reuse accounting.
        let horizon = prompt_len + decode_n;
        let fp = SequenceFootprint::of(&cfg, &make_factory(method, &fitted, sp));
        let pool_budget = 3 * fp.bytes_at(horizon);

        let run = |reuse: bool| {
            let mut e = Engine::new(
                Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 88))),
                make_factory(method, &fitted, sp),
                EngineConfig {
                    max_batch: 8,
                    prefill_chunk: chunk,
                    page_bytes: 4096,
                    pool_budget,
                    threads: 0,
                    prefix_reuse: reuse,
                    eject_preempted: false,
                },
            );
            // Prime: publish the shared prefix once (models a system
            // prompt the fleet has already seen).
            e.submit(Request::new(
                u64::MAX,
                shared_prefix.clone(),
                GenParams { max_new_tokens: 1, stop_token: None },
            ));
            e.run_to_completion();
            for (i, p) in prompts.iter().enumerate() {
                e.submit(Request::new(
                    i as u64,
                    p.clone(),
                    GenParams { max_new_tokens: decode_n, stop_token: None },
                ));
            }
            let mut responses = e.run_to_completion();
            assert_eq!(responses.len(), n_requests, "{method:?} reuse={reuse}: incomplete");
            responses.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<usize>> = responses.into_iter().map(|r| r.tokens).collect();
            (tokens, e.metrics.clone())
        };

        let (cold_tokens, cold) = run(false);
        let (warm_tokens, warm) = run(true);

        let avoided_frac = warm.prefill_tokens_avoided as f64 / trace_prompt_tokens as f64;
        let outputs_match = cold_tokens == warm_tokens;
        let ok = avoided_frac >= 0.80 && warm.peak_running > cold.peak_running && outputs_match;
        all_ok &= ok;
        println!(
            "{}: avoided {:.1}% (>=80%), peak concurrent {} vs {} (must be >), outputs_match={} -> {}",
            method.name(),
            avoided_frac * 100.0,
            warm.peak_running,
            cold.peak_running,
            outputs_match,
            if ok { "ok" } else { "FAIL" }
        );
        for (label, m) in [("off", &cold), ("on", &warm)] {
            table.row(vec![
                method.name().to_string(),
                label.to_string(),
                m.prefill_tokens_avoided.to_string(),
                format!("{:.1}", 100.0 * m.prefill_tokens_avoided as f64 / trace_prompt_tokens as f64),
                m.peak_running.to_string(),
                m.prefix_adoptions.to_string(),
                format!("{:.1}", m.tokens_per_second()),
            ]);
        }
        rows.push(
            Json::obj()
                .field("method", method.name())
                .field("prefill_tokens_avoided", warm.prefill_tokens_avoided)
                .field("avoided_frac", avoided_frac)
                .field("peak_running_reuse", warm.peak_running)
                .field("peak_running_noreuse", cold.peak_running)
                .field("prefix_adoptions", warm.prefix_adoptions)
                .field("prefix_publications", warm.prefix_publications)
                .field("shared_prefix_evictions", warm.shared_prefix_evictions)
                .field("outputs_match_cold", outputs_match)
                .field("tokens_per_second_reuse", warm.tokens_per_second())
                .field("tokens_per_second_noreuse", cold.tokens_per_second())
                .field("accepted", ok),
        );
    }
    table.print();

    let doc = sals::harness::bench_doc("prefix_reuse")
        .field("config", "d_model=256 n_layers=6 heads=8 head_dim=32 dense_layers=[0]")
        .field("prefix_len", prefix_len)
        .field("suffix_len", suffix_len)
        .field("n_requests", n_requests)
        .field("shared_fraction", n_shared as f64 / n_requests as f64)
        .field("decode_tokens", decode_n)
        .field("prefill_chunk", chunk)
        .field("rows", Json::Arr(rows))
        .field("accepted", all_ok);
    let path = sals::harness::bench_artifact_path("BENCH_prefix_reuse.json");
    std::fs::write(&path, doc.to_string()).expect("write BENCH_prefix_reuse.json");
    println!("wrote {}", path.display());
    if !all_ok {
        std::process::exit(1);
    }
}

//! Table 6: stand-alone attention-operator latency across methods and
//! input configurations (bs ∈ {8,16}, seq ∈ {1k,2k,4k}), mean ± std.
//!
//! Paper shape: dense ("Flash-attn") grows linearly and dominates at 4k;
//! SALS pays a small constant overhead at 1k and wins decisively at 4k;
//! Palu's full-reconstruction variant is the slowest at long contexts.

use sals::attention::baselines::double_sparse::DoubleSparseAttention;
use sals::attention::baselines::hshare::HShareAttention;
use sals::attention::baselines::loki::LokiAttention;
use sals::attention::{AttentionBackend, AttnShape, FullAttention, SalsAttention, SalsConfig};
use sals::harness::{ms_pm, Table};
use sals::lowrank::Calibrator;
use sals::util::rng::Rng;
use sals::util::timer::time_iters;

/// LLaMA2-7B-shaped attention layer scaled to CPU: 8 heads × 64 dims.
fn shape(max_seq: usize) -> AttnShape {
    AttnShape::mha(8, 64, max_seq + 8)
}

fn projector(kv_dim: usize, rank: usize, seed: u64) -> sals::lowrank::Projector {
    // Low-rank key family (real LLM keys are low-rank; see DESIGN.md).
    let mut rng = Rng::new(seed);
    let basis: Vec<Vec<f32>> = (0..rank / 2).map(|_| rng.normal_vec(kv_dim, 1.0)).collect();
    let mut cal = Calibrator::new(kv_dim);
    let mut row = vec![0.0f32; kv_dim];
    for _ in 0..256 {
        row.fill(0.0);
        for b in &basis {
            sals::tensor::ops::axpy(rng.normal_f32(), b, &mut row);
        }
        cal.add_key(&row);
    }
    cal.fit(rank).unwrap()
}

fn fill(b: &mut dyn AttentionBackend, kvd: usize, s: usize, rng: &mut Rng) {
    for _ in 0..s {
        let k = rng.normal_vec(kvd, 1.0);
        let v = rng.normal_vec(kvd, 1.0);
        b.append(&k, &v);
    }
}

fn bench_backend(b: &mut dyn AttentionBackend, qd: usize, bs: usize, reps: usize, rng: &mut Rng) -> Vec<f64> {
    let queries: Vec<Vec<f32>> = (0..bs).map(|_| rng.normal_vec(qd, 1.0)).collect();
    let mut out = vec![0.0f32; qd];
    time_iters(2, reps, || {
        for q in &queries {
            b.attend(q, &mut out);
        }
    })
}

fn main() {
    let reps = 6; // paper uses 1000 on GPU; CPU op is ~1e3× slower per rep
    let mut table = Table::new(
        "Table 6 — attention operator latency (ms, batch total), mean ± std",
        &["Config", "Flash-attn", "Loki", "Double-sparse", "HShare", "SALS-25%", "SALS-12.5%"],
    );
    for &bs in &[8usize, 16] {
        for &s in &[1024usize, 2048, 4096] {
            let sh = shape(s);
            let kvd = sh.kv_dim();
            let mut rng = Rng::new(3131 ^ (bs * s) as u64);
            // Shared sparsity budget: 1/8 of the sequence.
            let critical = s / 8;
            let (sink, recent) = (16, 64);

            let mut full = FullAttention::new(sh);
            fill(&mut full, kvd, s, &mut rng);
            let t_full = bench_backend(&mut full, sh.q_dim(), bs, reps, &mut rng);

            let p_post = projector(kvd, kvd / 4, 77);
            let mut loki = LokiAttention::new(sh, p_post, kvd / 4, sink, recent, critical);
            fill(&mut loki, kvd, s, &mut rng);
            let t_loki = bench_backend(&mut loki, sh.q_dim(), bs, reps, &mut rng);

            let channels: Vec<usize> = (0..kvd / 8).map(|i| i * 8).collect();
            let mut ds = DoubleSparseAttention::new(sh, channels, sink, recent, critical);
            fill(&mut ds, kvd, s, &mut rng);
            let t_ds = bench_backend(&mut ds, sh.q_dim(), bs, reps, &mut rng);

            let mut hs = HShareAttention::new(sh, sink, recent, critical, 4);
            fill(&mut hs, kvd, s, &mut rng);
            let t_hs = bench_backend(&mut hs, sh.q_dim(), bs, reps, &mut rng);

            let p25 = projector(kvd, kvd / 4, 78);
            let mut s25 = SalsAttention::new(sh, SalsConfig::sals_25(kvd, sink, critical, recent), p25);
            fill(&mut s25, kvd, s, &mut rng);
            let t_s25 = bench_backend(&mut s25, sh.q_dim(), bs, reps, &mut rng);

            let p125 = projector(kvd, kvd / 8, 79);
            let mut s125 =
                SalsAttention::new(sh, SalsConfig::sals_125(kvd, sink, critical, recent), p125);
            fill(&mut s125, kvd, s, &mut rng);
            let t_s125 = bench_backend(&mut s125, sh.q_dim(), bs, reps, &mut rng);

            table.row(vec![
                format!("bs={bs}, {}k", s / 1024),
                ms_pm(&t_full),
                ms_pm(&t_loki),
                ms_pm(&t_ds),
                ms_pm(&t_hs),
                ms_pm(&t_s25),
                ms_pm(&t_s125),
            ]);
        }
    }
    table.print();
    println!("\npaper (bs=8,4k): FA2 2.510ms vs SALS-12.5% 0.439ms (5.7x); SALS overhead visible at 1k");
}

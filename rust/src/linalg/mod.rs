//! Dense symmetric linear algebra: covariance, cyclic-Jacobi
//! eigendecomposition, PCA, and the Loki/SALS effective-rank metric.
//!
//! This is the calibration substrate (§4.2): the projector `U_r` is the
//! leading eigenbasis of the empirical key covariance `C = KᵀK`. The
//! Appendix-A metric `Rank_l(v)` (smallest #components retaining v% of
//! variance) is implemented here for the Figure-4 reproduction.

use crate::tensor::Mat;

/// Accumulates `C = Σ kᵀk` over streamed rows without materializing K.
#[derive(Clone, Debug)]
pub struct CovAccumulator {
    pub dim: usize,
    pub count: usize,
    /// (dim, dim) row-major, symmetric.
    pub c: Vec<f64>,
}

impl CovAccumulator {
    pub fn new(dim: usize) -> CovAccumulator {
        CovAccumulator { dim, count: 0, c: vec![0.0; dim * dim] }
    }

    /// Add one row vector k (length dim): C += kᵀk.
    pub fn add_row(&mut self, k: &[f32]) {
        assert_eq!(k.len(), self.dim);
        // Upper triangle only; mirrored in finish().
        for i in 0..self.dim {
            let ki = k[i] as f64;
            if ki == 0.0 {
                continue;
            }
            let row = &mut self.c[i * self.dim..(i + 1) * self.dim];
            for (j, cj) in row.iter_mut().enumerate().skip(i) {
                *cj += ki * k[j] as f64;
            }
        }
        self.count += 1;
    }

    /// Add many rows stored row-major in `ks` ((n, dim)).
    pub fn add_rows(&mut self, ks: &[f32]) {
        assert_eq!(ks.len() % self.dim, 0);
        for row in ks.chunks_exact(self.dim) {
            self.add_row(row);
        }
    }

    /// Finalize into a symmetric f32 covariance matrix (optionally divide by
    /// count for the mean outer product — eigenvectors are scale-invariant
    /// so the paper's plain `KᵀK` and the normalized version coincide).
    pub fn finish(&self, normalize: bool) -> Mat {
        let d = self.dim;
        let scale = if normalize && self.count > 0 { 1.0 / self.count as f64 } else { 1.0 };
        let mut m = Mat::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                let v = (self.c[i * d + j] * scale) as f32;
                m.data[i * d + j] = v;
                m.data[j * d + i] = v;
            }
        }
        m
    }
}

/// Eigendecomposition result, eigenvalues descending.
#[derive(Clone, Debug)]
pub struct Eig {
    /// Descending eigenvalues.
    pub values: Vec<f32>,
    /// Eigenvectors as COLUMNS of a (d, d) matrix: vectors.at(i, j) is
    /// component i of eigenvector j (matching values[j]).
    pub vectors: Mat,
}

/// Cyclic Jacobi eigendecomposition for a symmetric matrix.
///
/// O(d³) per sweep; converges quadratically. Dimensions here are ≤ a few
/// thousand (nd for the joint projector), and calibration is offline, so
/// Jacobi's simplicity and unconditional stability win over QR.
pub fn eig_symmetric(a: &Mat, max_sweeps: usize, tol: f64) -> Eig {
    assert_eq!(a.rows, a.cols, "eig_symmetric needs a square matrix");
    let d = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0f64;
        for i in 0..d {
            for j in (i + 1)..d {
                off += m[i * d + j] * m[i * d + j];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m[p * d + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * d + p];
                let aqq = m[q * d + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Rotate rows/cols p and q of m.
                for k in 0..d {
                    let mkp = m[k * d + p];
                    let mkq = m[k * d + q];
                    m[k * d + p] = c * mkp - s * mkq;
                    m[k * d + q] = s * mkp + c * mkq;
                }
                for k in 0..d {
                    let mpk = m[p * d + k];
                    let mqk = m[q * d + k];
                    m[p * d + k] = c * mpk - s * mqk;
                    m[q * d + k] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..d {
                    let vkp = v[k * d + p];
                    let vkq = v[k * d + q];
                    v[k * d + p] = c * vkp - s * vkq;
                    v[k * d + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract eigenvalues, sort descending, permute eigenvector columns.
    let mut order: Vec<usize> = (0..d).collect();
    let evals: Vec<f64> = (0..d).map(|i| m[i * d + i]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let mut values = Vec::with_capacity(d);
    let mut vectors = Mat::zeros(d, d);
    for (newcol, &oldcol) in order.iter().enumerate() {
        values.push(evals[oldcol] as f32);
        for row in 0..d {
            vectors.data[row * d + newcol] = v[row * d + oldcol] as f32;
        }
    }
    Eig { values, vectors }
}

/// Leading-r eigenvector block as a (d, r) projection matrix U_r.
pub fn leading_eigvecs(eig: &Eig, r: usize) -> Mat {
    let d = eig.vectors.rows;
    assert!(r <= d);
    let mut u = Mat::zeros(d, r);
    for row in 0..d {
        for col in 0..r {
            u.data[row * r + col] = eig.vectors.data[row * d + col];
        }
    }
    u
}

/// Appendix-A / Loki metric: smallest #components whose eigenvalue mass
/// reaches v% of the total. Eigenvalues must be descending; negatives
/// (numerical noise) are clamped to 0.
pub fn rank_at_energy(values: &[f32], v_percent: f64) -> usize {
    let total: f64 = values.iter().map(|&x| (x.max(0.0)) as f64).sum();
    if total <= 0.0 {
        return 0;
    }
    let target = total * v_percent / 100.0;
    let mut acc = 0.0;
    for (i, &x) in values.iter().enumerate() {
        acc += x.max(0.0) as f64;
        if acc >= target {
            return i + 1;
        }
    }
    values.len()
}

/// Fraction of total variance captured by the leading r eigenvalues.
pub fn energy_fraction(values: &[f32], r: usize) -> f64 {
    let total: f64 = values.iter().map(|&x| x.max(0.0) as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    values[..r.min(values.len())].iter().map(|&x| x.max(0.0) as f64).sum::<f64>() / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct(e: &Eig) -> Mat {
        // A = V diag(λ) Vᵀ
        let d = e.vectors.rows;
        let mut scaled = e.vectors.clone(); // columns scaled by λ
        for row in 0..d {
            for col in 0..d {
                scaled.data[row * d + col] *= e.values[col];
            }
        }
        scaled.matmul_t(&e.vectors.clone()) // (V·Λ) @ Vᵀ ... matmul_t computes A@Bᵀ with B=(d,d) rows as vectors
    }

    #[test]
    fn eig_diag_matrix() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = eig_symmetric(&a, 30, 1e-12);
        assert!((e.values[0] - 3.0).abs() < 1e-5);
        assert!((e.values[1] - 2.0).abs() < 1e-5);
        assert!((e.values[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn eig_reconstructs_random_symmetric() {
        let mut rng = Rng::new(21);
        let d = 12;
        let b = Mat::randn(d, d, 1.0, &mut rng);
        let a = {
            // A = B Bᵀ (symmetric PSD)
            b.matmul_t(&b)
        };
        let e = eig_symmetric(&a, 50, 1e-10);
        let rec = reconstruct(&e);
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for (x, y) in rec.data.iter().zip(&a.data) {
            err += ((x - y) as f64).powi(2);
            norm += (*y as f64).powi(2);
        }
        assert!((err / norm).sqrt() < 1e-4, "rel err {}", (err / norm).sqrt());
        // Eigenvalues of a PSD matrix are nonnegative.
        assert!(e.values.iter().all(|&l| l > -1e-3));
    }

    #[test]
    fn eigvecs_orthonormal() {
        let mut rng = Rng::new(23);
        let b = Mat::randn(8, 8, 1.0, &mut rng);
        let a = b.matmul_t(&b);
        let e = eig_symmetric(&a, 50, 1e-10);
        let vtv = e.vectors.transpose().matmul(&e.vectors);
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn covariance_accumulator_matches_direct() {
        let mut rng = Rng::new(25);
        let (n, d) = (40, 6);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let mut acc = CovAccumulator::new(d);
        acc.add_rows(&k.data);
        let c = acc.finish(false);
        let direct = k.transpose().matmul(&k);
        for (x, y) in c.data.iter().zip(&direct.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        assert_eq!(acc.count, n);
    }

    #[test]
    fn rank_at_energy_basics() {
        let vals = [4.0f32, 3.0, 2.0, 1.0]; // total 10
        assert_eq!(rank_at_energy(&vals, 40.0), 1);
        assert_eq!(rank_at_energy(&vals, 69.0), 2);
        assert_eq!(rank_at_energy(&vals, 90.0), 3);
        assert_eq!(rank_at_energy(&vals, 100.0), 4);
        assert_eq!(rank_at_energy(&[], 90.0), 0);
    }

    #[test]
    fn energy_fraction_monotone() {
        let vals = [5.0f32, 3.0, 1.0, 0.5];
        let mut prev = 0.0;
        for r in 0..=4 {
            let e = energy_fraction(&vals, r);
            assert!(e >= prev);
            prev = e;
        }
        assert!((energy_fraction(&vals, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn low_rank_data_detected() {
        // Rows live in a 2-D subspace of R^6 -> rank_90 should be <= 2.
        let mut rng = Rng::new(27);
        let basis = Mat::randn(2, 6, 1.0, &mut rng);
        let mut acc = CovAccumulator::new(6);
        for _ in 0..200 {
            let a = rng.normal_f32();
            let b = rng.normal_f32();
            let row: Vec<f32> =
                (0..6).map(|i| a * basis.at(0, i) + b * basis.at(1, i)).collect();
            acc.add_row(&row);
        }
        let e = eig_symmetric(&acc.finish(true), 50, 1e-10);
        assert!(rank_at_energy(&e.values, 90.0) <= 2);
    }
}

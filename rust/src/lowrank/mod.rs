//! Low-rank latent projection: calibration, fit, save/load (§4.2).
//!
//! The projector `U_r ∈ R^{nd×r}` is the leading-r eigenbasis of the
//! empirical covariance of stacked multi-head **pre-RoPE** keys. Lemma 1:
//! a joint (all heads together) projector captures at least as much energy
//! as any block-diagonal per-head projector at equal total rank — both
//! variants are implemented so the Lemma-1 ablation bench can compare them.

use crate::linalg::{eig_symmetric, leading_eigvecs, rank_at_energy, CovAccumulator, Eig};
use crate::tensor::Mat;
use crate::util::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// A fitted latent projector.
#[derive(Clone, Debug)]
pub struct Projector {
    /// Full input dimension (n_heads * head_dim for joint mode).
    pub dim: usize,
    /// Latent rank r.
    pub rank: usize,
    /// (dim, rank) column-orthonormal projection matrix U_r.
    pub u: Mat,
    /// Eigenvalues of the calibration covariance (descending, full length).
    pub spectrum: Vec<f32>,
}

impl Projector {
    /// Project a single vector: k̃ = U_rᵀ k (length rank).
    pub fn project(&self, k: &[f32], out: &mut [f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(out.len(), self.rank);
        // out = kᵀU: iterate U rows (unit stride) accumulating into out.
        out.fill(0.0);
        for (i, &ki) in k.iter().enumerate() {
            if ki == 0.0 {
                continue;
            }
            let urow = &self.u.data[i * self.rank..(i + 1) * self.rank];
            for (o, &uv) in out.iter_mut().zip(urow) {
                *o += ki * uv;
            }
        }
    }

    /// Reconstruct: k ≈ U_r k̃ (length dim).
    pub fn reconstruct(&self, latent: &[f32], out: &mut [f32]) {
        assert_eq!(latent.len(), self.rank);
        assert_eq!(out.len(), self.dim);
        for (i, o) in out.iter_mut().enumerate() {
            let urow = &self.u.data[i * self.rank..(i + 1) * self.rank];
            *o = crate::tensor::ops::dot(urow, latent);
        }
    }

    /// Project many rows ((n, dim) -> (n, rank)).
    pub fn project_rows(&self, ks: &Mat) -> Mat {
        assert_eq!(ks.cols, self.dim);
        ks.matmul(&self.u)
    }

    /// Reconstruct many rows ((n, rank) -> (n, dim)).
    pub fn reconstruct_rows(&self, latents: &Mat) -> Mat {
        assert_eq!(latents.cols, self.rank);
        latents.matmul_t(&self.u)
    }

    /// Captured-energy fraction of this projector on its calibration data.
    pub fn captured_energy(&self) -> f64 {
        crate::linalg::energy_fraction(&self.spectrum, self.rank)
    }

    /// Appendix-A Rank(v%) of the calibration spectrum.
    pub fn rank_at(&self, v_percent: f64) -> usize {
        rank_at_energy(&self.spectrum, v_percent)
    }

    /// Serialize to a simple text format (portable; also consumed by
    /// `python/compile/aot.py` to bake U_r into the HLO artifacts).
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "sals-projector v1")?;
        writeln!(w, "dim {} rank {}", self.dim, self.rank)?;
        writeln!(w, "spectrum {}", self.spectrum.len())?;
        for v in &self.spectrum {
            writeln!(w, "{v}")?;
        }
        writeln!(w, "u {}", self.u.data.len())?;
        for v in &self.u.data {
            writeln!(w, "{v}")?;
        }
        Ok(())
    }

    /// Load from [`Projector::save`] format.
    pub fn load(path: &Path) -> Result<Projector> {
        let f = std::fs::File::open(path)?;
        let mut lines = BufReader::new(f).lines();
        let mut next = || -> Result<String> {
            lines
                .next()
                .ok_or_else(|| Error::Config("projector file truncated".into()))?
                .map_err(Error::Io)
        };
        let magic = next()?;
        if magic.trim() != "sals-projector v1" {
            return Err(Error::Config(format!("bad projector magic: {magic}")));
        }
        let hdr = next()?;
        let parts: Vec<&str> = hdr.split_whitespace().collect();
        if parts.len() != 4 || parts[0] != "dim" || parts[2] != "rank" {
            return Err(Error::Config(format!("bad projector header: {hdr}")));
        }
        let dim: usize = parts[1].parse().map_err(|_| Error::Config("bad dim".into()))?;
        let rank: usize = parts[3].parse().map_err(|_| Error::Config("bad rank".into()))?;
        let spec_hdr = next()?;
        let spec_n: usize = spec_hdr
            .strip_prefix("spectrum ")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Config("bad spectrum header".into()))?;
        let mut spectrum = Vec::with_capacity(spec_n);
        for _ in 0..spec_n {
            spectrum.push(next()?.trim().parse().map_err(|_| Error::Config("bad spectrum value".into()))?);
        }
        let u_hdr = next()?;
        let u_n: usize = u_hdr
            .strip_prefix("u ")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Config("bad u header".into()))?;
        if u_n != dim * rank {
            return Err(Error::Config("u size mismatch".into()));
        }
        let mut data = Vec::with_capacity(u_n);
        for _ in 0..u_n {
            data.push(next()?.trim().parse().map_err(|_| Error::Config("bad u value".into()))?);
        }
        Ok(Projector { dim, rank, u: Mat::from_vec(dim, rank, data), spectrum })
    }
}

/// Streaming calibration: feed pre-RoPE key rows, then fit.
#[derive(Clone, Debug)]
pub struct Calibrator {
    acc: CovAccumulator,
}

impl Calibrator {
    /// `dim` = n_heads * head_dim for joint multi-head calibration.
    pub fn new(dim: usize) -> Calibrator {
        Calibrator { acc: CovAccumulator::new(dim) }
    }

    /// Add one stacked multi-head key row.
    pub fn add_key(&mut self, k: &[f32]) {
        self.acc.add_row(k);
    }

    /// Add a row-major (n, dim) batch.
    pub fn add_keys(&mut self, ks: &[f32]) {
        self.acc.add_rows(ks);
    }

    pub fn count(&self) -> usize {
        self.acc.count
    }

    /// Eigendecompose the accumulated covariance.
    pub fn decompose(&self) -> Eig {
        eig_symmetric(&self.acc.finish(true), 60, 1e-9)
    }

    /// Fit a rank-r joint projector (§4.2: leading-r eigenvectors of KᵀK).
    pub fn fit(&self, rank: usize) -> Result<Projector> {
        if rank == 0 || rank > self.acc.dim {
            return Err(Error::Config(format!(
                "rank {rank} out of range for dim {}",
                self.acc.dim
            )));
        }
        if self.acc.count == 0 {
            return Err(Error::Config("no calibration data".into()));
        }
        let eig = self.decompose();
        Ok(Projector {
            dim: self.acc.dim,
            rank,
            u: leading_eigvecs(&eig, rank),
            spectrum: eig.values,
        })
    }
}

/// Per-head block-diagonal projector (the Lemma-1 counterpart): each head's
/// (head_dim) slice gets its own rank-r' projector with r' = rank / n_heads.
#[derive(Clone, Debug)]
pub struct PerHeadProjector {
    pub n_heads: usize,
    pub head_dim: usize,
    pub rank_per_head: usize,
    pub heads: Vec<Projector>,
}

impl PerHeadProjector {
    /// Calibrate per-head projectors from stacked multi-head rows.
    pub fn fit(keys: &Mat, n_heads: usize, total_rank: usize) -> Result<PerHeadProjector> {
        if keys.cols % n_heads != 0 {
            return Err(Error::Config("keys dim not divisible by heads".into()));
        }
        if total_rank % n_heads != 0 {
            return Err(Error::Config("rank not divisible by heads".into()));
        }
        let head_dim = keys.cols / n_heads;
        let r = total_rank / n_heads;
        let mut heads = Vec::with_capacity(n_heads);
        for h in 0..n_heads {
            let mut cal = Calibrator::new(head_dim);
            for row in 0..keys.rows {
                cal.add_key(&keys.row(row)[h * head_dim..(h + 1) * head_dim]);
            }
            heads.push(cal.fit(r)?);
        }
        Ok(PerHeadProjector { n_heads, head_dim, rank_per_head: r, heads })
    }

    /// Project a stacked multi-head key (block-diagonal application).
    pub fn project(&self, k: &[f32], out: &mut [f32]) {
        assert_eq!(k.len(), self.n_heads * self.head_dim);
        assert_eq!(out.len(), self.n_heads * self.rank_per_head);
        for h in 0..self.n_heads {
            self.heads[h].project(
                &k[h * self.head_dim..(h + 1) * self.head_dim],
                &mut out[h * self.rank_per_head..(h + 1) * self.rank_per_head],
            );
        }
    }

    /// Reconstruct a stacked multi-head key.
    pub fn reconstruct(&self, latent: &[f32], out: &mut [f32]) {
        for h in 0..self.n_heads {
            self.heads[h].reconstruct(
                &latent[h * self.rank_per_head..(h + 1) * self.rank_per_head],
                &mut out[h * self.head_dim..(h + 1) * self.head_dim],
            );
        }
    }

    /// Mean captured energy across heads (for the Lemma-1 comparison).
    pub fn captured_energy(&self) -> f64 {
        self.heads.iter().map(|p| p.captured_energy()).sum::<f64>() / self.n_heads as f64
    }
}

/// Reconstruction relative error of a projector on a batch of keys —
/// the calibration-quality metric reported in EXPERIMENTS.md.
pub fn reconstruction_error(p: &Projector, keys: &Mat) -> f64 {
    let latent = p.project_rows(keys);
    let rec = p.reconstruct_rows(&latent);
    crate::util::stats::rel_l2(&rec.data, &keys.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Keys drawn from a rank-`true_rank` subspace + small noise.
    fn low_rank_keys(n: usize, dim: usize, true_rank: usize, noise: f32, rng: &mut Rng) -> Mat {
        let basis = Mat::randn(true_rank, dim, 1.0, rng);
        let mut keys = Mat::zeros(n, dim);
        for i in 0..n {
            let coef = rng.normal_vec(true_rank, 1.0);
            for (j, b) in basis.data.chunks_exact(dim).enumerate() {
                crate::tensor::ops::axpy(coef[j], b, keys.row_mut(i));
            }
            for v in keys.row_mut(i) {
                *v += rng.normal_f32() * noise;
            }
        }
        keys
    }

    #[test]
    fn projector_recovers_low_rank_structure() {
        let mut rng = Rng::new(41);
        let keys = low_rank_keys(300, 16, 4, 0.01, &mut rng);
        let mut cal = Calibrator::new(16);
        cal.add_keys(&keys.data);
        let p = cal.fit(4).unwrap();
        assert!(p.captured_energy() > 0.99);
        assert!(reconstruction_error(&p, &keys) < 0.05);
    }

    #[test]
    fn projector_orthonormal_columns() {
        let mut rng = Rng::new(43);
        let keys = low_rank_keys(200, 12, 6, 0.1, &mut rng);
        let mut cal = Calibrator::new(12);
        cal.add_keys(&keys.data);
        let p = cal.fit(6).unwrap();
        let utu = p.u.transpose().matmul(&p.u);
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - expect).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn project_reconstruct_single_matches_rows() {
        let mut rng = Rng::new(45);
        let keys = low_rank_keys(50, 8, 3, 0.05, &mut rng);
        let mut cal = Calibrator::new(8);
        cal.add_keys(&keys.data);
        let p = cal.fit(3).unwrap();
        let rows = p.project_rows(&keys);
        let mut single = vec![0.0; 3];
        p.project(keys.row(7), &mut single);
        for (a, b) in single.iter().zip(rows.row(7)) {
            assert!((a - b).abs() < 1e-4);
        }
        let recs = p.reconstruct_rows(&rows);
        let mut rec1 = vec![0.0; 8];
        p.reconstruct(rows.row(7), &mut rec1);
        for (a, b) in rec1.iter().zip(recs.row(7)) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lemma1_joint_beats_per_head() {
        // Correlated heads: joint projector must capture >= energy.
        let mut rng = Rng::new(47);
        let n_heads = 4;
        let head_dim = 8;
        let dim = n_heads * head_dim;
        // Global low-rank structure spanning across heads.
        let keys = low_rank_keys(400, dim, 6, 0.05, &mut rng);
        let total_rank = 8;
        let mut cal = Calibrator::new(dim);
        cal.add_keys(&keys.data);
        let joint = cal.fit(total_rank).unwrap();
        let per_head = PerHeadProjector::fit(&keys, n_heads, total_rank).unwrap();
        // Compare reconstruction error (lower = more energy captured).
        let joint_err = reconstruction_error(&joint, &keys);
        let mut ph_lat = vec![0.0; total_rank];
        let mut ph_rec = vec![0.0; dim];
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for row in 0..keys.rows {
            per_head.project(keys.row(row), &mut ph_lat);
            per_head.reconstruct(&ph_lat, &mut ph_rec);
            for (a, b) in ph_rec.iter().zip(keys.row(row)) {
                num += ((a - b) as f64).powi(2);
                den += (*b as f64).powi(2);
            }
        }
        let ph_err = (num / den).sqrt();
        assert!(
            joint_err <= ph_err + 1e-6,
            "Lemma 1 violated: joint {joint_err} vs per-head {ph_err}"
        );
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(49);
        let keys = low_rank_keys(100, 10, 4, 0.05, &mut rng);
        let mut cal = Calibrator::new(10);
        cal.add_keys(&keys.data);
        let p = cal.fit(4).unwrap();
        let dir = std::env::temp_dir().join("sals_test_projector.txt");
        p.save(&dir).unwrap();
        let q = Projector::load(&dir).unwrap();
        assert_eq!(p.dim, q.dim);
        assert_eq!(p.rank, q.rank);
        for (a, b) in p.u.data.iter().zip(&q.u.data) {
            assert!((a - b).abs() < 1e-6);
        }
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn fit_errors() {
        let cal = Calibrator::new(4);
        assert!(cal.fit(0).is_err());
        assert!(cal.fit(5).is_err());
        assert!(cal.fit(2).is_err()); // no data
    }
}

//! Rotary Position Embedding (RoPE, Su et al. 2021) — LLaMA convention.
//!
//! LLaMA/HF rotate-half layout: a head vector x of dim d is split into two
//! halves (x1 = x[..d/2], x2 = x[d/2..]); dimension pair (i, i+d/2) is
//! rotated by angle θ_i·pos with θ_i = base^(-2i/d).
//!
//! The paper's central observation (§3.1, Appendix A) is that applying this
//! rotation to keys *increases the variance / effective rank* of the key
//! distribution, which is why SALS compresses keys **pre-RoPE** and applies
//! RoPE only to the small reconstructed subset (§4.4, Algorithm 1 line 7).

/// Precomputed cos/sin tables for one head dimension.
#[derive(Clone, Debug)]
pub struct RopeTable {
    pub head_dim: usize,
    pub max_pos: usize,
    /// (max_pos, head_dim/2) row-major
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl RopeTable {
    /// Build tables for positions [0, max_pos) with the given base
    /// (10_000.0 for LLaMA2/Mistral; 500_000.0 for LLaMA3).
    pub fn new(head_dim: usize, max_pos: usize, base: f32) -> RopeTable {
        assert!(head_dim % 2 == 0, "RoPE head_dim must be even");
        let half = head_dim / 2;
        let mut cos = vec![0.0; max_pos * half];
        let mut sin = vec![0.0; max_pos * half];
        for pos in 0..max_pos {
            for i in 0..half {
                let theta = (pos as f64) * (base as f64).powf(-2.0 * i as f64 / head_dim as f64);
                cos[pos * half + i] = theta.cos() as f32;
                sin[pos * half + i] = theta.sin() as f32;
            }
        }
        RopeTable { head_dim, max_pos, cos, sin }
    }

    /// Rotate a single head vector in place for position `pos`.
    #[inline]
    pub fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.head_dim);
        assert!(pos < self.max_pos, "RoPE position {pos} >= max {}", self.max_pos);
        let half = self.head_dim / 2;
        let cos = &self.cos[pos * half..(pos + 1) * half];
        let sin = &self.sin[pos * half..(pos + 1) * half];
        for i in 0..half {
            let a = x[i];
            let b = x[i + half];
            x[i] = a * cos[i] - b * sin[i];
            x[i + half] = b * cos[i] + a * sin[i];
        }
    }

    /// Rotate every head slice of a multi-head vector (n_heads × head_dim,
    /// concatenated) in place for position `pos`.
    pub fn apply_multihead(&self, x: &mut [f32], pos: usize) {
        assert_eq!(x.len() % self.head_dim, 0);
        for h in 0..x.len() / self.head_dim {
            self.apply(&mut x[h * self.head_dim..(h + 1) * self.head_dim], pos);
        }
    }

    /// Rotate row `t` of a (n, n_heads*head_dim) buffer for position
    /// `positions[t]`, for all rows — the **gathered** (non-consecutive)
    /// positions form the fused decode kernel needs: a tile of selected
    /// rows carries its original token positions, so each row rotates at
    /// its own `positions[t]` (Algorithm 1 line 7). `row_dim` may be a
    /// single head (`head_dim`, the fused kernel's per-KV-head tiles) or
    /// any multiple of it.
    pub fn apply_rows_at(&self, buf: &mut [f32], row_dim: usize, positions: &[usize]) {
        assert_eq!(buf.len(), row_dim * positions.len());
        for (t, &pos) in positions.iter().enumerate() {
            self.apply_multihead(&mut buf[t * row_dim..(t + 1) * row_dim], pos);
        }
    }

    /// Rotate row `t` of a (n, n_heads*head_dim) buffer for position
    /// `start + t` — the batched-prefill convention where a chunk occupies
    /// consecutive positions. Avoids materializing a positions slice.
    pub fn apply_rows_offset(&self, buf: &mut [f32], row_dim: usize, start: usize) {
        assert_eq!(buf.len() % row_dim, 0);
        for (t, row) in buf.chunks_exact_mut(row_dim).enumerate() {
            self.apply_multihead(row, start + t);
        }
    }

    /// Inverse rotation (rotate by -pos). Used in tests and in the
    /// Figure-1(b)/Figure-4 analyses.
    pub fn apply_inverse(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.head_dim);
        let half = self.head_dim / 2;
        let cos = &self.cos[pos * half..(pos + 1) * half];
        let sin = &self.sin[pos * half..(pos + 1) * half];
        for i in 0..half {
            let a = x[i];
            let b = x[i + half];
            x[i] = a * cos[i] + b * sin[i];
            x[i + half] = b * cos[i] - a * sin[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn position_zero_is_identity() {
        let t = RopeTable::new(8, 16, 10_000.0);
        let mut x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let orig = x.clone();
        t.apply(&mut x, 0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rotation_preserves_norm() {
        let t = RopeTable::new(64, 128, 10_000.0);
        let mut rng = Rng::new(4);
        let mut x = rng.normal_vec(64, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum();
        t.apply(&mut x, 77);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
    }

    #[test]
    fn inverse_undoes_rotation() {
        let t = RopeTable::new(32, 64, 10_000.0);
        let mut rng = Rng::new(6);
        let mut x = rng.normal_vec(32, 1.0);
        let orig = x.clone();
        t.apply(&mut x, 33);
        t.apply_inverse(&mut x, 33);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn relative_position_property() {
        // <RoPE(q, i), RoPE(k, j)> must depend only on i - j.
        let t = RopeTable::new(16, 256, 10_000.0);
        let mut rng = Rng::new(8);
        let q = rng.normal_vec(16, 1.0);
        let k = rng.normal_vec(16, 1.0);
        let score = |i: usize, j: usize| {
            let mut qa = q.clone();
            let mut ka = k.clone();
            t.apply(&mut qa, i);
            t.apply(&mut ka, j);
            crate::tensor::ops::dot(&qa, &ka)
        };
        let s1 = score(10, 3);
        let s2 = score(107, 100);
        assert!((s1 - s2).abs() < 1e-3, "{s1} vs {s2}");
    }

    #[test]
    fn rows_offset_matches_per_row() {
        let t = RopeTable::new(8, 64, 10_000.0);
        let mut rng = Rng::new(12);
        let rows = 5;
        let mut buf = rng.normal_vec(rows * 16, 1.0); // 2 heads × dim 8
        let mut expect = buf.clone();
        t.apply_rows_offset(&mut buf, 16, 7);
        for (i, row) in expect.chunks_exact_mut(16).enumerate() {
            t.apply_multihead(row, 7 + i);
        }
        assert_eq!(buf, expect);
    }

    #[test]
    fn rows_at_matches_per_row_for_gathered_positions() {
        // Non-consecutive, unordered positions — the fused-kernel tile
        // shape — must rotate each row exactly as a per-row apply would,
        // including single-head rows (row_dim == head_dim).
        let t = RopeTable::new(8, 128, 10_000.0);
        let mut rng = Rng::new(14);
        let positions = [0usize, 97, 3, 41, 40, 3];
        let mut single = rng.normal_vec(positions.len() * 8, 1.0);
        let mut expect = single.clone();
        t.apply_rows_at(&mut single, 8, &positions);
        for (row, &pos) in expect.chunks_exact_mut(8).zip(&positions) {
            t.apply(row, pos);
        }
        assert_eq!(single, expect);
        // Multi-head rows too.
        let mut multi = rng.normal_vec(positions.len() * 16, 1.0);
        let mut expect = multi.clone();
        t.apply_rows_at(&mut multi, 16, &positions);
        for (row, &pos) in expect.chunks_exact_mut(16).zip(&positions) {
            t.apply_multihead(row, pos);
        }
        assert_eq!(multi, expect);
    }

    #[test]
    fn multihead_rotates_each_head() {
        let t = RopeTable::new(4, 8, 10_000.0);
        let mut rng = Rng::new(10);
        let head = rng.normal_vec(4, 1.0);
        let mut two_heads = [head.clone(), head.clone()].concat();
        t.apply_multihead(&mut two_heads, 5);
        // Both heads must have received the identical rotation.
        assert_eq!(&two_heads[..4], &two_heads[4..]);
        let mut single = head;
        t.apply(&mut single, 5);
        assert_eq!(&two_heads[..4], single.as_slice());
    }
}

//! Replica worker: the thread-side half of the serving cluster.
//!
//! Each worker owns ONE [`Engine`] for its whole lifetime — the engine is
//! built *inside* the spawned thread and never crosses a thread boundary,
//! so nothing about the engine's internals (backend boxes, worker-pool
//! handles, scratch) needs to be `Sync`. The coordinator talks to a worker
//! over a per-replica [`Command`] channel and every worker reports on one
//! shared `(ReplicaId, Event)` channel, so the coordinator's event loop is
//! a single `recv`.
//!
//! The loop discipline keeps workers cheap when idle and responsive when
//! busy: with nothing outstanding the worker **blocks** on its command
//! channel (zero spin); with work in flight it drains pending commands
//! without blocking, steps the engine once, and flushes the step's
//! products (completions, ejected preemptions, prefix publications) as
//! events. Engine panics — the loud-failure asserts like "request can
//! never fit" — are caught and forwarded as [`Event::Died`] so the
//! coordinator can re-raise them on the caller's thread instead of
//! hanging on a channel whose worker silently unwound.

use super::engine::{Engine, PrefixEvent};
use super::metrics::Metrics;
use super::request::{Request, Response};
use super::router::ReplicaId;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::time::Instant;

/// Commands the coordinator sends a replica worker.
pub enum Command {
    /// Enqueue a request into the replica engine's admission queue.
    Submit(Request),
    /// Snapshot the engine's [`Metrics`] and send them back on the
    /// provided one-shot channel.
    Sync(Sender<Metrics>),
    /// Stop immediately (any in-flight work is abandoned; the coordinator
    /// only shuts down after draining or when itself dropped mid-run).
    Shutdown,
}

/// Events a replica worker reports on the shared channel (tagged with the
/// worker's [`ReplicaId`] by construction of the tuple it sends).
pub enum Event {
    /// A request completed; the coordinator drains the routing ledger and
    /// records projected-vs-actual drift from the response.
    Done(Response),
    /// A request was preempted and ejected (`eject_preempted` mode); the
    /// coordinator re-routes it to the least-loaded replica.
    Preempted(Request),
    /// The engine published or retired a shared prefix; the coordinator
    /// updates its replica-placement index.
    Prefix(PrefixEvent),
    /// The engine panicked or stalled; the coordinator re-raises this as
    /// a panic so cluster failure semantics match single-engine ones.
    Died(String),
}

/// Consecutive zero-progress rounds (work outstanding, no commands
/// arriving, no sequence stepped) before the worker declares itself
/// stuck. Mirrors the stall guard in [`Engine::run_to_completion`]: a
/// long run of zeros with requests outstanding means a pool-gated queue
/// that can never drain, not slow progress.
const STALL_LIMIT: usize = 1000;

/// The worker body: build-and-own loop for one replica. Returns when
/// told to shut down or when the coordinator side hangs up.
pub(crate) fn run(
    id: ReplicaId,
    mut engine: Engine,
    commands: Receiver<Command>,
    events: Sender<(ReplicaId, Event)>,
) {
    let mut stall = 0usize;
    loop {
        // Idle: block until the coordinator has something for us. The
        // stall guard resets — a quiet cluster is not a stuck one.
        if engine.outstanding() == 0 {
            stall = 0;
            match commands.recv() {
                Ok(cmd) => {
                    if apply(&mut engine, cmd) {
                        return;
                    }
                }
                Err(_) => return, // coordinator dropped
            }
        }
        // Busy (or just woken): drain whatever else is queued without
        // blocking, so a burst of submissions lands before the next step
        // and batches together.
        let mut drained = 0usize;
        loop {
            match commands.try_recv() {
                Ok(cmd) => {
                    drained += 1;
                    if apply(&mut engine, cmd) {
                        return;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return,
            }
        }
        let mut stepped = 0usize;
        if engine.outstanding() > 0 {
            // Wall accounting: replica workers drive step() directly (not
            // run_to_completion), so busy time is accumulated here — each
            // replica's wall_s is its busy seconds, and the cluster
            // aggregate takes the max (see Metrics::absorb).
            let t0 = Instant::now();
            let r = catch_unwind(AssertUnwindSafe(|| engine.step()));
            engine.metrics.wall_s += t0.elapsed().as_secs_f64();
            match r {
                Ok(n) => stepped = n,
                Err(payload) => {
                    let msg = panic_message(payload.as_ref());
                    let _ = events.send((id, Event::Died(msg)));
                    return;
                }
            }
        }
        // Flush the step's products. Completions first: the coordinator's
        // ledger should see a finished request before any preemption the
        // same step caused elsewhere in the running set.
        for resp in engine.take_done() {
            if events.send((id, Event::Done(resp))).is_err() {
                return;
            }
        }
        for req in engine.take_preempted() {
            if events.send((id, Event::Preempted(req))).is_err() {
                return;
            }
        }
        for ev in engine.take_prefix_events() {
            if events.send((id, Event::Prefix(ev))).is_err() {
                return;
            }
        }
        if engine.outstanding() > 0 && stepped == 0 && drained == 0 {
            stall += 1;
            if stall >= STALL_LIMIT {
                let _ = events.send((
                    id,
                    Event::Died(format!(
                        "replica stalled: {} request(s) outstanding, none can be admitted",
                        engine.outstanding()
                    )),
                ));
                return;
            }
        } else {
            stall = 0;
        }
    }
}

/// Apply one command; returns true on shutdown.
fn apply(engine: &mut Engine, cmd: Command) -> bool {
    match cmd {
        Command::Submit(req) => {
            engine.submit(req);
            false
        }
        Command::Sync(reply) => {
            let _ = reply.send(engine.metrics.clone());
            false
        }
        Command::Shutdown => true,
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "engine panicked".to_string()
    }
}

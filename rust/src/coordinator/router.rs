//! Request router: spreads incoming requests over engine replicas.
//!
//! Mirrors the vllm-project/router design point: a stateless-ish front
//! that tracks per-replica outstanding load and routes each request to the
//! least-loaded replica (power-of-one-choice with exact load here, since
//! replicas are in-process). Session affinity is supported so multi-turn
//! requests can reuse a replica's warm cache.
//!
//! Two usage tiers:
//!
//! * **Bare router** ([`Router::route`]): the router both picks the
//!   replica and charges its ledger — the original standalone contract,
//!   kept for drivers that hold replicas directly. Without an installed
//!   footprint it falls back to pricing in tokens.
//! * **Cluster ledger** ([`Router::assign`] + accessors): the
//!   [`super::Coordinator`] picks the replica itself (affinity → prefix
//!   placement → least loaded, with horizon bin-packing) and uses the
//!   router purely as the load ledger + affinity map. The cluster tier
//!   always installs a [`SequenceFootprint`], so the token-count fallback
//!   of [`Router::dispatch_cost`] is retired there — cluster load is
//!   projected bytes, the same currency replicas reserve at admit.

use super::request::Request;
use crate::kvcache::SeqId;
use crate::model::SequenceFootprint;
use std::collections::HashMap;

/// Routing decisions are replica indices.
pub type ReplicaId = usize;

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Strict round-robin.
    RoundRobin,
    /// Route to the replica with the fewest outstanding tokens.
    LeastLoaded,
}

/// The router: tracks load, routes requests, supports session affinity.
pub struct Router {
    policy: Policy,
    /// Outstanding load estimate per replica — projected KV bytes when a
    /// footprint is installed, tokens otherwise.
    load: Vec<usize>,
    rr_next: usize,
    /// Session -> replica affinity map.
    affinity: HashMap<SeqId, ReplicaId>,
    /// Projected per-sequence cache growth of the backend the replicas
    /// run. When set, [`Router::dispatch_cost`] prices requests in
    /// projected bytes at the decode horizon — what the replicas actually
    /// reserve at admit — instead of assuming token-proportional cost.
    footprint: Option<SequenceFootprint>,
}

impl Router {
    pub fn new(replicas: usize, policy: Policy) -> Router {
        assert!(replicas > 0);
        Router {
            policy,
            load: vec![0; replicas],
            rr_next: 0,
            affinity: HashMap::new(),
            footprint: None,
        }
    }

    /// A router that prices load by the replicas' projected
    /// [`SequenceFootprint`] bytes instead of token counts. Compressed
    /// backends (SALS, quantized) legitimately hold more concurrent
    /// sequences per replica; byte pricing lets LeastLoaded see that.
    pub fn with_footprint(replicas: usize, policy: Policy, fp: SequenceFootprint) -> Router {
        let mut r = Router::new(replicas, policy);
        r.footprint = Some(fp);
        r
    }

    pub fn replicas(&self) -> usize {
        self.load.len()
    }

    pub fn load_of(&self, r: ReplicaId) -> usize {
        self.load[r]
    }

    /// Replica currently carrying the least outstanding load (lowest
    /// index wins ties).
    pub fn least_loaded(&self) -> ReplicaId {
        self.load.iter().enumerate().min_by_key(|(_, &l)| l).map(|(i, _)| i).unwrap()
    }

    /// Replica a session is pinned to, if any.
    pub fn session_replica(&self, session: SeqId) -> Option<ReplicaId> {
        self.affinity.get(&session).copied()
    }

    /// Route a request; `session` pins follow-ups to the same replica.
    pub fn route(&mut self, req: &Request, session: Option<SeqId>) -> ReplicaId {
        if let Some(sid) = session {
            if let Some(&r) = self.affinity.get(&sid) {
                self.note_dispatch(r, req);
                return r;
            }
        }
        let r = match self.policy {
            Policy::RoundRobin => {
                let r = self.rr_next % self.load.len();
                self.rr_next += 1;
                r
            }
            Policy::LeastLoaded => self.least_loaded(),
        };
        if let Some(sid) = session {
            self.affinity.insert(sid, r);
        }
        self.note_dispatch(r, req);
        r
    }

    /// Directed dispatch: the caller (the cluster [`super::Coordinator`])
    /// picked `r` itself — by affinity, prefix placement, or bin-packing —
    /// and the router records the consequences: the request's
    /// [`Router::dispatch_cost`] lands on `r`'s ledger and `session` (re-)
    /// pins to `r`. Re-pinning is deliberate: a preemption re-route moves
    /// a session's affinity to wherever the request actually went, so the
    /// next turn follows the cache that is now warm.
    pub fn assign(&mut self, r: ReplicaId, req: &Request, session: Option<SeqId>) {
        assert!(r < self.load.len(), "replica {r} out of range");
        if let Some(sid) = session {
            self.affinity.insert(sid, r);
        }
        self.note_dispatch(r, req);
    }

    /// Cost estimate of one request — what [`Router::route`] adds to the
    /// chosen replica and what [`Router::complete`]/
    /// [`Router::note_preemption`] must drain. With a footprint installed
    /// this is the projected cache bytes at the decode horizon
    /// (`prompt + max_new` tokens, the same horizon the engine prices
    /// admission at); without one it falls back to the token count.
    pub fn dispatch_cost(&self, req: &Request) -> usize {
        let horizon = req.prompt.len() + req.params.max_new_tokens;
        match &self.footprint {
            Some(fp) => fp.bytes_at(horizon),
            None => horizon,
        }
    }

    fn note_dispatch(&mut self, r: ReplicaId, req: &Request) {
        self.load[r] += self.dispatch_cost(req);
    }

    /// Report completion so load drains. Takes the request itself — the
    /// router owns the cost model ([`Router::dispatch_cost`]), so callers
    /// can no longer drain a number that disagrees with what `route`
    /// charged (the old `complete(replica, cost)` contract silently
    /// leaked load whenever the two cost formulas drifted).
    pub fn complete(&mut self, r: ReplicaId, req: &Request) {
        let cost = self.dispatch_cost(req);
        self.load[r] = self.load[r].saturating_sub(cost);
    }

    /// Drain exactly `bytes` previously charged to `r` — the cluster
    /// coordinator's completion path. Completion events carry the
    /// [`super::Response`], not the [`Request`], so the coordinator cannot
    /// re-price via [`Router::complete`]; instead it records the charged
    /// [`Router::dispatch_cost`] in its in-flight table at dispatch time
    /// and drains that exact number here, keeping charge/drain symmetric
    /// by construction (the same leak-proofing `complete` provides for
    /// callers that still hold the request).
    pub fn drain(&mut self, r: ReplicaId, bytes: usize) {
        self.load[r] = self.load[r].saturating_sub(bytes);
    }

    /// A replica preempted (re-queued) this request: drain the dispatch
    /// cost so the load estimate does not leak. Without this, a preempted
    /// request's cost stayed on the replica forever — `complete` only
    /// fires at completion, which a preempted-and-rerouted request never
    /// reaches on the original replica — skewing every later LeastLoaded
    /// decision toward the other replicas. The caller re-`route`s the
    /// request (session affinity, if any, still pins it).
    pub fn note_preemption(&mut self, r: ReplicaId, req: &Request) {
        self.complete(r, req);
    }

    /// Drop a session's affinity (conversation ended).
    pub fn end_session(&mut self, session: SeqId) {
        self.affinity.remove(&session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn req(id: u64, plen: usize) -> Request {
        Request::new(id, vec![0; plen], GenParams { max_new_tokens: 4, stop_token: None })
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 2), None)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_uneven_requests() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let a = r.route(&req(0, 100), None); // heavy
        let b = r.route(&req(1, 1), None); // goes to the other replica
        assert_ne!(a, b);
        let c = r.route(&req(2, 1), None); // still lighter side
        assert_eq!(b, c);
    }

    #[test]
    fn affinity_pins_sessions() {
        let mut r = Router::new(4, Policy::LeastLoaded);
        let first = r.route(&req(0, 5), Some(99));
        for i in 1..5 {
            assert_eq!(r.route(&req(i, 5), Some(99)), first);
        }
        r.end_session(99);
        // After ending, the session may move (no assertion on where).
        let _ = r.route(&req(9, 5), Some(99));
    }

    #[test]
    fn complete_drains_load() {
        let mut r = Router::new(1, Policy::LeastLoaded);
        let request = req(0, 10);
        r.route(&request, None);
        assert_eq!(r.load_of(0), 14);
        r.complete(0, &request);
        assert_eq!(r.load_of(0), 0);
        r.complete(0, &request); // over-drain saturates
        assert_eq!(r.load_of(0), 0);
    }

    #[test]
    fn preemption_drains_dispatch_cost() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let heavy = req(0, 100); // cost 104
        let a = r.route(&heavy, None);
        assert_eq!(r.load_of(a), 104);
        // Replica preempts + re-queues the request: its cost must leave
        // the replica so it can be re-routed with honest loads.
        r.note_preemption(a, &heavy);
        assert_eq!(r.load_of(a), 0, "preempted cost must not leak");
        // Re-route lands wherever is lightest again, and completion after
        // a preempt+re-route cycle drains to exactly zero (no double
        // counting, saturating on over-drain).
        let b = r.route(&heavy, None);
        r.complete(b, &heavy);
        assert_eq!(r.load_of(b), 0);
        r.note_preemption(b, &heavy); // over-drain saturates
        assert_eq!(r.load_of(b), 0);
    }

    #[test]
    fn footprint_pricing_routes_sals_cheaper_than_dense() {
        use crate::attention::{
            AttentionBackend, AttnShape, FullAttention, SalsAttention, SalsConfig,
        };
        use crate::lowrank::Calibrator;
        use crate::quant::Bits;

        let shape = AttnShape::mha(4, 16, 512);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(5);
        let mut cal = Calibrator::new(kvd);
        for _ in 0..4 * kvd {
            cal.add_key(&rng.normal_vec(kvd, 1.0));
        }
        let proj = cal.fit(kvd / 4).unwrap();
        let cfg = SalsConfig {
            rank: kvd / 4,
            r_star: kvd / 8,
            sink: 2,
            recent: 8,
            critical: 16,
            v_bits: Bits::B4,
            group: 8,
            prefill: None,
        };
        let n_layers = 4;
        let dense_fp = SequenceFootprint::from_layers(vec![
            FullAttention::new(shape).footprint();
            n_layers
        ]);
        let sals_fp = SequenceFootprint::from_layers(vec![
            SalsAttention::new(shape, cfg, proj).footprint();
            n_layers
        ]);

        let request = req(0, 256);
        let mut dense_router = Router::with_footprint(2, Policy::LeastLoaded, dense_fp);
        let mut sals_router = Router::with_footprint(2, Policy::LeastLoaded, sals_fp);
        let dense_cost = dense_router.dispatch_cost(&request);
        let sals_cost = sals_router.dispatch_cost(&request);
        assert!(
            sals_cost < dense_cost,
            "a SALS request must price cheaper than the dense equal-length \
             request: {sals_cost} vs {dense_cost} bytes"
        );
        // The byte cost is what actually lands on the chosen replica.
        let a = dense_router.route(&request, None);
        assert_eq!(dense_router.load_of(a), dense_cost);
        let b = sals_router.route(&request, None);
        assert_eq!(sals_router.load_of(b), sals_cost);
        // Without a footprint the router still prices in tokens.
        let bare = Router::new(1, Policy::LeastLoaded);
        assert_eq!(bare.dispatch_cost(&request), 256 + 4);
    }

    #[test]
    fn assign_charges_and_repins() {
        let mut r = Router::new(3, Policy::LeastLoaded);
        let request = req(0, 10); // token-fallback cost 14
        r.assign(2, &request, Some(7));
        assert_eq!(r.load_of(2), 14);
        assert_eq!(r.session_replica(7), Some(2));
        // A re-route re-pins the session to the new replica and the old
        // ledger is drained by the caller via note_preemption.
        r.note_preemption(2, &request);
        r.assign(0, &request, Some(7));
        assert_eq!(r.session_replica(7), Some(0));
        assert_eq!((r.load_of(0), r.load_of(2)), (14, 0));
        assert_eq!(r.least_loaded(), 1);
        r.complete(0, &request);
        assert_eq!(r.load_of(0), 0);
        assert_eq!(r.session_replica(99), None);
    }

    #[test]
    fn property_least_loaded_never_picks_strictly_heavier() {
        prop::check(
            "router-least-loaded",
            300,
            |rng: &mut Rng| (0..rng.range(1, 30)).map(|_| rng.range(1, 50)).collect::<Vec<usize>>(),
            |plens| {
                let mut r = Router::new(4, Policy::LeastLoaded);
                for (i, &p) in plens.iter().enumerate() {
                    let loads_before: Vec<usize> = (0..4).map(|k| r.load_of(k)).collect();
                    let pick = r.route(&req(i as u64, p), None);
                    let min = *loads_before.iter().min().unwrap();
                    if loads_before[pick] != min {
                        return false;
                    }
                }
                true
            },
        );
    }
}

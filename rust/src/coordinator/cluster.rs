//! The serving cluster: a [`Coordinator`] that owns N [`Engine`] replicas
//! on long-lived worker threads and fronts them with one admission queue.
//!
//! Ownership model — three layers, each with a single owner:
//!
//! * **Model weights** are shared: every replica's [`Model`] holds the
//!   same `Arc<Weights>`, so N replicas cost N KV pools + N scratch sets,
//!   not N weight copies. The backend factory is `Arc`-shared the same
//!   way.
//! * **Each engine** is owned by exactly one worker thread (built inside
//!   the spawn, never crossing threads — see [`super::replica`]), with its
//!   own [`crate::kvcache::PagePool`] and prefix cache. Pools are
//!   deliberately NOT shared: page accounting stays single-threaded and a
//!   replica's admission decisions never contend on a lock.
//! * **The coordinator** (caller's thread) owns the cluster queue, the
//!   [`Router`] load/affinity ledger, the published-prefix placement
//!   index, and the in-flight table. All routing state mutates on one
//!   thread; replicas talk back over a single event channel.
//!
//! Routing: placement is a three-step hierarchy, priced in projected
//! [`crate::model::SequenceFootprint`] bytes at the decode horizon (the
//! cluster always installs a footprint — the router's token-count
//! fallback is retired here, because byte pricing is what lets a
//! compressed-cache replica legitimately accept more work):
//!
//! 1. **Session affinity**: a request tagged with a session goes to the
//!    replica its session is pinned to (warm cache), *waiting* for
//!    headroom there rather than migrating cold.
//! 2. **Prefix placement**: an unpinned request whose prompt starts with
//!    a prefix some replica has published goes to that replica, longest
//!    match first — adoption skips the shared prefill entirely, which is
//!    worth more than perfect load spread.
//! 3. **Least loaded**: otherwise, the lightest ledger wins.
//!
//! Admission is *bin-packing over a window*, not strict FCFS: if the
//! queue's front request fits no replica right now, up to
//! `bin_pack_window` younger requests are allowed to overtake it (a
//! short request should not wait behind a giant one that needs a whole
//! pool to drain first). The front request can never starve: every
//! completion shrinks some ledger, and an idle replica (load 0) accepts
//! anything — so the moment its pinned/placed replica drains, the front
//! dispatches.
//!
//! Preemption re-routing: a replica that ejects a preempted request
//! ([`super::engine::EngineConfig::eject_preempted`]) hands it back as an
//! event; the coordinator drains the origin's ledger
//! ([`Router::note_preemption`]), re-routes to the least-loaded replica
//! (ignoring the old placement — the cache there is already dropped), and
//! re-pins the session to wherever it lands. Completions drain the exact
//! projected bytes charged at dispatch and record a
//! [`DriftRecord`] (projected vs the response's actual peak KV bytes) —
//! the estimator-quality signal the cluster reports per request.

use super::engine::{Engine, EngineConfig};
use super::metrics::{ClusterMetrics, DriftRecord};
use super::replica::{run, Command, Event};
use super::request::{Request, Response};
use super::router::{Policy, ReplicaId, Router};
use crate::kvcache::{prefix_hashes, SeqId};
use crate::model::{BackendFactory, Model, SequenceFootprint};
use crate::util::error::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Cluster configuration: replica count + the per-replica engine config.
#[derive(Clone)]
pub struct ClusterConfig {
    /// Number of engine replicas (worker threads).
    pub replicas: usize,
    /// Per-replica engine configuration. `pool_budget` is PER REPLICA:
    /// a 4-replica cluster holds 4× these pages in total.
    /// `eject_preempted` is forced on — the coordinator owns re-routing.
    pub engine: EngineConfig,
    /// How many queued requests may overtake a front request that
    /// currently fits no replica (1 = strict FCFS).
    pub bin_pack_window: usize,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig { replicas: 2, engine: EngineConfig::default(), bin_pack_window: 8 }
    }
}

struct ReplicaHandle {
    commands: Sender<Command>,
    join: Option<JoinHandle<()>>,
}

/// Coordinator-side record of a dispatched request.
struct InFlight {
    replica: ReplicaId,
    /// Exact bytes charged to the replica's ledger at dispatch — drained
    /// verbatim on completion (see [`Router::drain`]) and reported as the
    /// projected side of the drift record. Constant across preemption
    /// re-routes (the horizon does not change).
    projected: usize,
}

/// The cluster front: owns the queue, the routing ledger, the prefix
/// placement index, and N replica worker threads.
pub struct Coordinator {
    cfg: ClusterConfig,
    router: Router,
    replicas: Vec<ReplicaHandle>,
    events: Receiver<(ReplicaId, Event)>,
    queue: VecDeque<Request>,
    in_flight: HashMap<SeqId, InFlight>,
    /// Published-prefix placement index: prefix hash (as computed by
    /// [`crate::kvcache::prefix_hash`]) -> (replica that published it,
    /// prefix length in tokens). First publisher wins; retirement events
    /// from the owning replica remove entries. The index is a placement
    /// HINT — staleness costs a cold prefill, never correctness.
    prefix_index: HashMap<u64, (ReplicaId, usize)>,
    /// Per-replica pool capacity in bytes (whole pages) — the headroom
    /// ceiling for projected-load placement.
    capacity: usize,
    /// Chunk granularity prefixes are published at (the engines'
    /// `prefill_chunk`) — what the placement lookup hashes prompts with.
    chunk: usize,
    done: Vec<Response>,
    dispatched: usize,
    preemption_reroutes: usize,
    prefix_hint_hits: usize,
    fcfs_bypasses: usize,
    duplicates_rejected: usize,
    drift: Vec<DriftRecord>,
}

impl Coordinator {
    /// Build the cluster: derive the routing footprint from the factory,
    /// spawn one worker thread per replica (each constructs its own
    /// engine from a shared-weights model clone), and wire the channels.
    pub fn new(model: Model, factory: Box<BackendFactory>, cfg: ClusterConfig) -> Coordinator {
        assert!(cfg.replicas > 0, "cluster needs at least one replica");
        assert!(cfg.engine.page_bytes > 0);
        let factory: Arc<BackendFactory> = Arc::from(factory);
        let footprint = SequenceFootprint::of(&model.cfg, &*factory);
        let router = Router::with_footprint(cfg.replicas, Policy::LeastLoaded, footprint);
        let capacity = (cfg.engine.pool_budget / cfg.engine.page_bytes) * cfg.engine.page_bytes;
        let chunk = cfg.engine.prefill_chunk.max(1);
        let (event_tx, events) = channel();
        let mut replicas = Vec::with_capacity(cfg.replicas);
        for r in 0..cfg.replicas {
            let (command_tx, command_rx) = channel();
            let events = event_tx.clone();
            let fac = Arc::clone(&factory);
            let replica_factory: Box<BackendFactory> = Box::new(move |layer| fac(layer));
            let replica_model =
                Model { cfg: model.cfg.clone(), weights: Arc::clone(&model.weights) };
            let mut engine_cfg = cfg.engine.clone();
            engine_cfg.eject_preempted = true;
            let join = std::thread::Builder::new()
                .name(format!("sals-replica-{r}"))
                .spawn(move || {
                    let engine = Engine::new(replica_model, replica_factory, engine_cfg);
                    run(r, engine, command_rx, events);
                })
                .expect("spawn replica worker");
            replicas.push(ReplicaHandle { commands: command_tx, join: Some(join) });
        }
        Coordinator {
            cfg,
            router,
            replicas,
            events,
            queue: VecDeque::new(),
            in_flight: HashMap::new(),
            prefix_index: HashMap::new(),
            capacity,
            chunk,
            done: Vec::new(),
            dispatched: 0,
            preemption_reroutes: 0,
            prefix_hint_hits: 0,
            fcfs_bypasses: 0,
            duplicates_rejected: 0,
            drift: Vec::new(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Requests accepted but not yet completed (queued + dispatched).
    pub fn outstanding(&self) -> usize {
        self.queue.len() + self.in_flight.len()
    }

    /// Current projected-bytes ledger per replica.
    pub fn loads(&self) -> Vec<usize> {
        (0..self.replicas.len()).map(|r| self.router.load_of(r)).collect()
    }

    /// Replica a session is currently pinned to, if any.
    pub fn session_replica(&self, session: SeqId) -> Option<ReplicaId> {
        self.router.session_replica(session)
    }

    /// Drop a session's replica affinity (conversation ended). The next
    /// turn is placed fresh — by prefix index or load.
    pub fn end_session(&mut self, session: SeqId) {
        self.router.end_session(session);
    }

    /// Accept a request into the cluster queue. Rejects an id already
    /// queued or in flight anywhere in the cluster — ids key the page-pool
    /// ledgers, and the per-engine duplicate assert cannot see across
    /// replicas, so the cluster must enforce uniqueness at its own door.
    pub fn submit(&mut self, mut req: Request) -> Result<()> {
        if self.in_flight.contains_key(&req.id) || self.queue.iter().any(|q| q.id == req.id) {
            self.duplicates_rejected += 1;
            return Err(Error::Coordinator(format!(
                "duplicate in-flight request id {} rejected at cluster admission",
                req.id
            )));
        }
        req.arrival.get_or_insert_with(Instant::now);
        self.queue.push_back(req);
        self.pump();
        Ok(())
    }

    /// Headroom rule: an idle replica accepts anything (so oversized
    /// requests cannot starve — the engine's own best-effort admission
    /// governs them from there); a busy one must fit the projected bytes
    /// under its pool capacity.
    fn has_headroom(&self, r: ReplicaId, cost: usize) -> bool {
        let load = self.router.load_of(r);
        load == 0 || load + cost <= self.capacity
    }

    /// Pick a replica for a queued request, or None if nothing can take
    /// it right now. Returns (replica, placed_by_prefix_hint).
    fn place(&self, req: &Request) -> Option<(ReplicaId, bool)> {
        let cost = self.router.dispatch_cost(req);
        if let Some(sid) = req.session {
            if let Some(r) = self.router.session_replica(sid) {
                // Pinned sessions WAIT for their replica rather than
                // migrating: the whole point of affinity is the warm
                // prefix cache sitting on that replica.
                return if self.has_headroom(r, cost) { Some((r, false)) } else { None };
            }
        }
        // Longest published prefix wins; a shorter match on a replica
        // with headroom still beats a cold least-loaded placement.
        for &(_, hash) in prefix_hashes(&req.prompt, self.chunk).iter().rev() {
            if let Some(&(r, _)) = self.prefix_index.get(&hash) {
                if self.has_headroom(r, cost) {
                    return Some((r, true));
                }
            }
        }
        let r = self.router.least_loaded();
        if self.has_headroom(r, cost) {
            Some((r, false))
        } else {
            None
        }
    }

    /// Dispatch every queued request that fits somewhere, scanning up to
    /// `bin_pack_window` deep past a front request that fits nowhere.
    fn pump(&mut self) {
        loop {
            let window = self.cfg.bin_pack_window.max(1).min(self.queue.len());
            let mut chosen = None;
            for qi in 0..window {
                if let Some((r, hint)) = self.place(&self.queue[qi]) {
                    chosen = Some((qi, r, hint));
                    break;
                }
            }
            let Some((qi, r, hint)) = chosen else { break };
            if qi > 0 {
                self.fcfs_bypasses += 1;
            }
            if hint {
                self.prefix_hint_hits += 1;
            }
            let req = self.queue.remove(qi).expect("scanned index in bounds");
            let projected = self.router.dispatch_cost(&req);
            self.router.assign(r, &req, req.session);
            self.in_flight.insert(req.id, InFlight { replica: r, projected });
            self.dispatched += 1;
            self.replicas[r]
                .commands
                .send(Command::Submit(req))
                .expect("replica worker hung up");
        }
    }

    fn handle_event(&mut self, origin: ReplicaId, event: Event) {
        match event {
            Event::Done(resp) => {
                let fl = self
                    .in_flight
                    .remove(&resp.id)
                    .expect("completion for a request the cluster never dispatched");
                debug_assert_eq!(fl.replica, origin, "completion from the wrong replica");
                self.router.drain(fl.replica, fl.projected);
                self.drift.push(DriftRecord {
                    id: resp.id,
                    projected_bytes: fl.projected,
                    actual_bytes: resp.peak_kv_bytes,
                });
                self.done.push(resp);
            }
            Event::Preempted(req) => {
                let fl = self
                    .in_flight
                    .get_mut(&req.id)
                    .expect("preemption for a request the cluster never dispatched");
                debug_assert_eq!(fl.replica, origin, "preemption from the wrong replica");
                // Drain the origin's ledger, then re-route by CURRENT
                // load — the origin's cache for this request is already
                // dropped, so the old placement has no residual value and
                // affinity deliberately does not apply. assign() re-pins
                // the session to wherever the request lands, so the next
                // turn follows the cache that will now be warm.
                self.router.note_preemption(origin, &req);
                let target = self.router.least_loaded();
                self.router.assign(target, &req, req.session);
                fl.replica = target;
                self.preemption_reroutes += 1;
                self.replicas[target]
                    .commands
                    .send(Command::Submit(req))
                    .expect("replica worker hung up");
            }
            Event::Prefix(ev) => {
                if ev.published {
                    // Keep-first: two replicas may publish the same
                    // prefix; the index answers "where is it warm", and
                    // the first answer stays valid.
                    self.prefix_index.entry(ev.hash).or_insert((origin, ev.tokens));
                } else if let Some(&(owner, _)) = self.prefix_index.get(&ev.hash) {
                    // Only the indexed owner's retirement removes the
                    // entry — another replica evicting its duplicate
                    // copy must not un-index the surviving one.
                    if owner == origin {
                        self.prefix_index.remove(&ev.hash);
                    }
                }
            }
            Event::Died(msg) => {
                // Re-raise on the caller's thread: cluster failure
                // semantics match the single engine's loud asserts
                // ("request can never fit", stall guard).
                panic!("replica {origin} died: {msg}");
            }
        }
    }

    /// Drive until every accepted request completes; returns responses in
    /// completion order. Re-entrant: submit more and call again.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        while self.outstanding() > 0 {
            self.pump();
            if self.in_flight.is_empty() {
                // Queue non-empty but nothing dispatched in flight: all
                // ledgers are zero (drains are symmetric), so pump() is
                // guaranteed to have dispatched — loop back to it.
                continue;
            }
            let (r, ev) = self.events.recv().expect("all replica workers hung up");
            self.handle_event(r, ev);
            // Drain whatever else already arrived before re-pumping, so
            // one pump sees the fullest picture of freed capacity.
            while let Ok((r, ev)) = self.events.try_recv() {
                self.handle_event(r, ev);
            }
        }
        std::mem::take(&mut self.done)
    }

    /// Snapshot the cluster view: per-replica engine metrics (synced over
    /// the command channels) + the coordinator's own routing counters and
    /// the per-request drift ledger.
    pub fn metrics(&self) -> ClusterMetrics {
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        for h in &self.replicas {
            let (tx, rx) = channel();
            h.commands.send(Command::Sync(tx)).expect("replica worker hung up");
            per_replica.push(rx.recv().expect("replica worker died during sync"));
        }
        ClusterMetrics {
            per_replica,
            dispatched: self.dispatched,
            preemption_reroutes: self.preemption_reroutes,
            prefix_hint_hits: self.prefix_hint_hits,
            fcfs_bypasses: self.fcfs_bypasses,
            duplicates_rejected: self.duplicates_rejected,
            drift: self.drift.clone(),
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for h in &self.replicas {
            // A worker that already died (panic forwarded as an event)
            // has dropped its receiver — ignore the send failure.
            let _ = h.commands.send(Command::Shutdown);
        }
        for h in &mut self.replicas {
            if let Some(join) = h.join.take() {
                let _ = join.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::{HalvedFootprint, LyingFootprint};
    use super::super::request::GenParams;
    use super::*;
    use crate::attention::FullAttention;
    use crate::model::{ModelConfig, Scratch, SequenceState, Weights};
    use crate::util::prop;
    use crate::util::rng::Rng;

    const SEED: u64 = 37;

    fn tiny_model() -> Model {
        let cfg = ModelConfig::tiny_mha(128);
        Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, SEED)))
    }

    fn full_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_| Box::new(FullAttention::new(shape)) as _)
    }

    fn halved_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_| Box::new(HalvedFootprint(FullAttention::new(shape))) as _)
    }

    fn engine_cfg(pool_pages: usize) -> EngineConfig {
        EngineConfig {
            max_batch: 4,
            prefill_chunk: 8,
            page_bytes: 4096,
            pool_budget: pool_pages * 4096,
            threads: 1,
            prefix_reuse: false,
            eject_preempted: false, // forced on by the coordinator anyway
        }
    }

    fn cluster(replicas: usize, pool_pages: usize) -> Coordinator {
        let model = tiny_model();
        let factory = full_factory(&model.cfg);
        Coordinator::new(
            model,
            factory,
            ClusterConfig { replicas, engine: engine_cfg(pool_pages), bin_pack_window: 8 },
        )
    }

    fn request(id: u64, prompt: Vec<usize>, max_new: usize) -> Request {
        Request::new(id, prompt, GenParams { max_new_tokens: max_new, stop_token: None })
    }

    /// The tentpole invariant: per-request token streams are bit-identical
    /// to a single-engine run regardless of replica count — placement and
    /// cross-replica batching are semantically invisible.
    #[test]
    fn token_streams_identical_across_replica_counts() {
        let prompts: Vec<Vec<usize>> =
            vec![vec![5, 6, 7], vec![9, 10, 11, 12], vec![42], vec![1, 2, 3, 4, 5], vec![33, 7]];
        // Ground truth: direct greedy generation, no serving layer at all.
        let model = tiny_model();
        let factory = full_factory(&model.cfg);
        let mut expected = Vec::new();
        for p in &prompts {
            let mut state = SequenceState::new(&model.cfg, &factory);
            let mut scratch = Scratch::new(&model.cfg);
            expected.push(model.generate_greedy(&mut state, &mut scratch, p, 6));
        }
        for replicas in [1usize, 2, 4] {
            let mut c = cluster(replicas, 1 << 12); // ample pool
            for (i, p) in prompts.iter().enumerate() {
                c.submit(request(i as u64, p.clone(), 6)).unwrap();
            }
            let mut responses = c.run_to_completion();
            responses.sort_by_key(|r| r.id);
            assert_eq!(responses.len(), prompts.len());
            for (i, r) in responses.iter().enumerate() {
                assert_eq!(
                    r.tokens, expected[i],
                    "request {i} diverged from direct generation at {replicas} replicas"
                );
                assert!(r.peak_kv_bytes > 0, "peak KV must be measured");
            }
            let cm = c.metrics();
            assert_eq!(cm.aggregate().requests_completed, prompts.len());
            assert_eq!(cm.drift.len(), prompts.len());
            // Honest footprints never under-estimate: actual peak is at
            // most the projection for every request.
            let (_, hi) = cm.drift_bounds();
            assert!(hi <= 1.0 + 1e-12, "honest footprint must not under-project: {hi}");
        }
    }

    #[test]
    fn duplicate_ids_rejected_cluster_wide() {
        let mut c = cluster(2, 1 << 12);
        c.submit(request(7, vec![1, 2, 3], 4)).unwrap();
        // Already dispatched (in flight on some replica) — still visible
        // to cluster-level admission.
        let err = c.submit(request(7, vec![9, 9], 4)).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "got: {err}");
        assert_eq!(c.run_to_completion().len(), 1);
        assert_eq!(c.metrics().duplicates_rejected, 1);
        // After completion the id is free again (matches engine semantics).
        c.submit(request(7, vec![1, 2, 3], 4)).unwrap();
        assert_eq!(c.run_to_completion().len(), 1);
    }

    /// Satellite regression: after a forced-preemption run, no replica's
    /// tracked load leaks — every charge was drained by completion or
    /// preemption, symmetric by construction.
    #[test]
    fn preemption_reroutes_and_no_load_leaks() {
        let model = tiny_model();
        let factory = halved_factory(&model.cfg);
        // 32-page pools: a 16-token sequence peaks at 24 pages but prices
        // (halved) at 12, so two co-resident sequences over-commit and
        // growth must preempt — on every replica that gets two.
        let mut c = Coordinator::new(
            model,
            factory,
            ClusterConfig { replicas: 2, engine: engine_cfg(32), bin_pack_window: 8 },
        );
        for i in 0..4u64 {
            c.submit(request(i, vec![1, 2, 3, 4, 5, 6, 7, 8], 8)).unwrap();
        }
        let responses = c.run_to_completion();
        assert_eq!(responses.len(), 4);
        assert!(responses.iter().all(|r| r.tokens.len() == 8));
        let cm = c.metrics();
        assert!(
            cm.aggregate().preemptions >= 1,
            "scenario must actually force preemption (got none)"
        );
        assert!(
            cm.preemption_reroutes >= 1,
            "every ejected preemption must be re-routed by the coordinator"
        );
        assert_eq!(
            c.loads(),
            vec![0, 0],
            "router ledger leaked load after a preemption-heavy run"
        );
        // Under-claiming footprint ⇒ drift ratios above 1 (the signal the
        // drift ledger exists to expose).
        let (_, hi) = cm.drift_bounds();
        assert!(hi > 1.0, "halved footprint must show under-projection drift: {hi}");
    }

    /// Conservation proptest: across random bursts, prompt mixes, replica
    /// counts, and forced preemptions, every submitted request completes
    /// exactly once, cluster metrics sums equal per-replica sums, and the
    /// routing ledger drains to zero.
    #[test]
    fn property_requests_conserved_across_bursts_and_preemptions() {
        let cfg = ModelConfig::tiny_mha(128);
        let weights = Arc::new(Weights::random(&cfg, SEED));
        prop::check(
            "cluster-conservation",
            12,
            |rng: &mut Rng| {
                // v[0] encodes replica count (1..=4); the rest are prompt
                // lengths (1..=12 — small enough that any single request
                // always fits a 32-page pool alone, so forced preemption
                // can never hit the "can never fit" loud failure).
                let n = rng.range(1, 8);
                let mut v = vec![rng.range(1, 5)];
                v.extend((0..n).map(|_| rng.range(1, 13)));
                v
            },
            |input| {
                if input.is_empty() {
                    return true; // shrunk-away input: nothing to check
                }
                let replicas = input[0].clamp(1, 4);
                let plens = &input[1..];
                let shape = cfg.attn_shape();
                let factory: Box<BackendFactory> = Box::new(move |_| {
                    Box::new(HalvedFootprint(FullAttention::new(shape))) as _
                });
                let model = Model { cfg: cfg.clone(), weights: Arc::clone(&weights) };
                let mut c = Coordinator::new(
                    model,
                    factory,
                    ClusterConfig {
                        replicas,
                        engine: engine_cfg(32),
                        bin_pack_window: 4,
                    },
                );
                for (i, &plen) in plens.iter().enumerate() {
                    let prompt: Vec<usize> = (0..plen.max(1)).map(|t| (t * 7 + i) % 50).collect();
                    if c.submit(request(i as u64, prompt, 4)).is_err() {
                        return false;
                    }
                }
                let mut responses = c.run_to_completion();
                responses.sort_by_key(|r| r.id);
                // Exactly once: every id present, no extras, no repeats.
                if responses.len() != plens.len() {
                    return false;
                }
                if responses.iter().enumerate().any(|(i, r)| r.id != i as u64) {
                    return false;
                }
                let cm = c.metrics();
                let agg = cm.aggregate();
                let per_completed: usize =
                    cm.per_replica.iter().map(|m| m.requests_completed).sum();
                let per_generated: usize =
                    cm.per_replica.iter().map(|m| m.tokens_generated).sum();
                let delivered: usize = responses.iter().map(|r| r.tokens.len()).sum();
                agg.requests_completed == plens.len()
                    && per_completed == plens.len()
                    && agg.tokens_generated == delivered
                    && per_generated == delivered
                    && cm.dispatched == plens.len()
                    && cm.drift.len() == plens.len()
                    && c.loads().iter().all(|&l| l == 0)
                    && c.outstanding() == 0
            },
        );
    }

    /// The engine's loud-failure semantics survive the thread boundary: a
    /// request that can never fit its replica's pool panics the caller,
    /// not a background thread the caller cannot see.
    #[test]
    #[should_panic(expected = "can never fit")]
    fn impossible_request_panics_on_caller_thread() {
        let model = tiny_model();
        let factory = lying_factory(&model.cfg);
        // 8 pages ≈ 5 dense tokens; the 8-token prompt alone can never
        // fit. The zero-claiming footprint admits it (idle pool), growth
        // evicts it running alone — the engine asserts, the worker
        // forwards Died, the coordinator re-raises here.
        let mut c = Coordinator::new(
            model,
            factory,
            ClusterConfig { replicas: 1, engine: engine_cfg(8), bin_pack_window: 1 },
        );
        c.submit(request(0, vec![1, 2, 3, 4, 5, 6, 7, 8], 4)).unwrap();
        c.run_to_completion();
    }

    fn lying_factory(cfg: &ModelConfig) -> Box<BackendFactory> {
        let shape = cfg.attn_shape();
        Box::new(move |_| Box::new(LyingFootprint(FullAttention::new(shape))) as _)
    }

    /// Prefix placement: a second request with a published prompt prefix
    /// is routed to the replica that published it (and adopts, skipping
    /// the shared prefill) even when another replica is emptier.
    #[test]
    fn prefix_index_places_matching_prompt_on_publisher() {
        let model = tiny_model();
        let factory = full_factory(&model.cfg);
        let mut ecfg = engine_cfg(1 << 12);
        ecfg.prefix_reuse = true;
        let mut c = Coordinator::new(
            model,
            factory,
            ClusterConfig { replicas: 2, engine: ecfg, bin_pack_window: 8 },
        );
        let prompt: Vec<usize> = (1..=12).collect();
        c.submit(request(0, prompt.clone(), 5)).unwrap();
        assert_eq!(c.run_to_completion().len(), 1);
        let first_replica = {
            // Exactly one replica completed the first request.
            let cm = c.metrics();
            (0..2).find(|&r| cm.per_replica[r].requests_completed == 1).unwrap()
        };
        assert!(!c.prefix_index.is_empty(), "first run must publish its chunk prefix");
        // Same prompt, new id, NO session tag: placement must follow the
        // prefix index to the publisher, not least-loaded (both idle).
        c.submit(request(1, prompt, 5)).unwrap();
        assert_eq!(c.run_to_completion().len(), 1);
        let cm = c.metrics();
        assert_eq!(cm.prefix_hint_hits, 1, "second request must be placed by the index");
        assert_eq!(
            cm.per_replica[first_replica].requests_completed,
            2,
            "prefix-matching request must land on the publishing replica"
        );
        assert_eq!(
            cm.aggregate().prefix_adoptions,
            1,
            "placement must convert into an actual adoption"
        );
    }
}

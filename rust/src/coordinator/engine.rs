//! The serving engine: continuous batching + chunked prefill +
//! cross-sequence batched decode + pool-aware preemption over the CPU
//! model.
//!
//! The step loop is the paper's serving context (vLLM/GPT-fast class),
//! structured as explicit phases:
//!
//! 1. **Admission**: while the running set is below `max_batch`, price the
//!    next waiting request with the factory's [`SequenceFootprint`] at its
//!    decode horizon (`prompt + max_new_tokens`, capped at `max_seq`) and
//!    **reserve the pages immediately**; admit FCFS until a reservation
//!    fails. Reserving at admit time means one pass cannot admit N
//!    requests against the same free pages, and — because the footprint is
//!    backend-aware — a pool that holds k dense-fp32 sequences holds
//!    proportionally more SALS ones (the Table-7 capacity mechanism). A
//!    request whose horizon exceeds even an empty pool is admitted
//!    best-effort (whole-pool reservation) once the pool is idle, so an
//!    early-stopping request with a huge token budget cannot stall the
//!    queue forever.
//! 2. **Partition**: split the running set into *prefilling* sequences
//!    (prompt not yet consumed) and *decode-ready* sequences (pending
//!    next-token logits).
//! 3. **Prefill phase**: each prefilling sequence consumes one
//!    `prefill_chunk`-token chunk through [`Model::forward_batch`] — ONE
//!    multi-token pass whose activations are (chunk, d) matrices — with
//!    sequences fanned out across the engine's persistent worker pool
//!    (created once at [`Engine::new`]; per-step dispatch is a mailbox
//!    handoff, not a thread spawn), leftover lanes granted to each
//!    sequence's intra-attend fan-out from the same budget. Chunked
//!    prefill keeps decode latency bounded for running sequences; page
//!    accounting and preemption stay per engine step, i.e. per chunk.
//! 4. **Decode phase**: the whole decode-ready set advances one token
//!    through a single [`Model::decode_batch`] call — per-sequence
//!    activations stacked into (batch, d) matrices, with the batch's rows
//!    partitioned across the same pool so each weight matrix streams
//!    once per *worker block* of sequences per step (not once per
//!    sequence; serial decode streams it exactly once for the whole
//!    batch). The engine owns one [`BatchScratch`] sized to `max_batch`;
//!    per-sequence `Scratch` is only touched during prefill. Continuous
//!    batching — no static batch barrier: sequences join the decode set
//!    as their prefill completes and leave it the step they finish.
//! 5. **Accounting**: finished sequences (flagged at decode time) are
//!    collected first, releasing their pages. Every surviving sequence
//!    then re-reserves `max(kv_bytes(), admission reservation)` — actual
//!    growth is tracked, but admitted headroom is never handed back
//!    mid-flight (that would recreate the over-commit churn admission-time
//!    reservation exists to prevent). If the pool cannot cover someone
//!    (possible only when a footprint under-estimates), preemption is
//!    youngest-first-*minimal*: preempt the single youngest sequence,
//!    retry every reservation, repeat — never more evictions than needed.
//!    Preempted requests re-queue at the front with caches dropped and
//!    their emitted tokens + preemption count carried on the request
//!    (vLLM recompute mode, **resuming**): re-admission prefills
//!    `prompt ++ generated` and decode continues after the last emitted
//!    token — preemption re-does prefill work but never re-decodes a
//!    token (`Metrics::tokens_decoded` stays equal to
//!    `Metrics::tokens_generated`).

use super::metrics::Metrics;
use super::request::{Request, Response};
use crate::kvcache::{PagePool, PrefixCache, SharedId};
use crate::model::{
    BackendFactory, BatchScratch, Model, Scratch, SequenceFootprint, SequenceSnapshot,
    SequenceState,
};
use crate::util::threadpool::Workers;
use std::collections::VecDeque;
use std::time::Instant;

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub prefill_chunk: usize,
    /// Page size for the KV pool (bytes).
    pub page_bytes: usize,
    /// Total KV memory budget (bytes).
    pub pool_budget: usize,
    /// Size of the engine's persistent worker pool (0 = one per CPU;
    /// the `SALS_THREADS` env var overrides either way). Workers are
    /// created once at [`Engine::new`] and shared by prefill fan-out,
    /// decode batch partitioning, and intra-attend parallelism.
    pub threads: usize,
    /// Shared-prefix KV reuse: publish chunk-aligned prompt prefixes into
    /// a content-addressed cache and let later requests adopt them,
    /// skipping the shared prefill work and charging the shared pages
    /// once. Off by default — publications consume pool pages, which
    /// changes capacity accounting for workloads that never re-adopt.
    pub prefix_reuse: bool,
    /// Replica mode: a preempted request is **ejected** (drained via
    /// [`Engine::take_preempted`]) instead of re-queued on this engine's
    /// own waiting queue. The cluster coordinator re-routes ejected
    /// requests to the least-loaded replica; a standalone engine keeps
    /// the default `false` and resumes its own preemptions locally.
    pub eject_preempted: bool,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            max_batch: 16,
            prefill_chunk: 128,
            page_bytes: 64 * 1024,
            pool_budget: 1 << 30,
            threads: 0,
            prefix_reuse: false,
            eject_preempted: false,
        }
    }
}

/// A change in this engine's published-prefix set, drained by the cluster
/// coordinator ([`Engine::take_prefix_events`]) to keep its content-keyed
/// replica-placement index in sync: `published` entries map `hash` (the
/// [`crate::kvcache::prefix_hash`] of the first `tokens` prompt tokens)
/// to this replica; retirements (pool-pressure evictions) remove them.
#[derive(Clone, Copy, Debug)]
pub struct PrefixEvent {
    pub hash: u64,
    /// Prefix length in tokens (0 for retirements — the hash alone keys
    /// the index).
    pub tokens: usize,
    /// True for a publication, false for an eviction/retirement.
    pub published: bool,
}

struct Running {
    req: Request,
    state: SequenceState,
    scratch: Scratch,
    /// Largest live `kv_bytes()` this run has reached, seeded with the
    /// request's carried peak so the maximum spans preemption resumes.
    /// Raw cache bytes (adopted shared panels included) — the *actual*
    /// side of the cluster's projected-vs-actual drift ledger, compared
    /// against the undiscounted footprint projection it was routed by.
    peak_kv: usize,
    /// What prefill actually consumes: the prompt, plus — for a request
    /// resuming after preemption — the tokens it had already generated
    /// (recompute rebuilds their KV, decode continues after them).
    prefill_tokens: Vec<usize>,
    /// Tokens of `prefill_tokens` already consumed.
    prefilled: usize,
    /// Generated tokens so far — seeded with the request's carried
    /// `generated` on re-admission, so stop-condition budgets
    /// (`max_new_tokens`) keep counting across preemptions.
    out: Vec<usize>,
    /// Pending next-token logits (set once prefill completes).
    logits: Option<Vec<f32>>,
    /// Set at decode time the moment a stop condition is hit (stop token,
    /// max_new_tokens, max_seq) — collection checks this flag instead of
    /// re-scanning `out`.
    finished: bool,
    first_step: Option<Instant>,
    first_token: Option<Instant>,
    /// Bytes reserved at admission (footprint at the decode horizon) —
    /// the accounting floor while this sequence runs. Already discounted
    /// by the adopted prefix's shared bytes when `adopted` is set.
    reserved_bytes: usize,
    /// Shared-prefix holding this sequence adopted at admission (a
    /// refcount it must release when it finishes or is preempted).
    adopted: Option<SharedId>,
    /// Whether this sequence already attempted its one prefix
    /// publication (at its largest complete-chunk prefill boundary).
    published: bool,
}

/// The serving engine.
pub struct Engine {
    pub model: Model,
    factory: Box<BackendFactory>,
    /// Per-sequence footprint model of `factory`'s backends, derived once
    /// at construction — what admission prices requests with.
    footprint: SequenceFootprint,
    pub cfg: EngineConfig,
    pool: PagePool,
    /// Content-addressed index of published prompt prefixes (payload: the
    /// per-layer snapshot an adopter re-hydrates from). Only populated
    /// when `cfg.prefix_reuse` is on.
    prefix_cache: PrefixCache<SequenceSnapshot>,
    waiting: VecDeque<Request>,
    running: Vec<Running>,
    /// Engine-owned scratch for the cross-sequence batched decode phase,
    /// sized to `max_batch` — decode needs no per-sequence scratch.
    batch_scratch: BatchScratch,
    /// Persistent worker-pool handle (created once, from `cfg.threads`):
    /// every per-step fan-out — prefill sequences, decode rows, nested
    /// intra-attend shares — dispatches on these parked workers.
    workers: Workers,
    pub metrics: Metrics,
    done: Vec<Response>,
    /// Preempted requests ejected under `cfg.eject_preempted` instead of
    /// re-queued locally — drained by the replica worker for re-routing.
    ejected: Vec<Request>,
    /// Published/retired prefix notifications since the last drain (see
    /// [`PrefixEvent`]); only populated when `cfg.prefix_reuse` is on.
    prefix_events: Vec<PrefixEvent>,
}

impl Engine {
    pub fn new(model: Model, factory: Box<BackendFactory>, cfg: EngineConfig) -> Engine {
        let pool = PagePool::with_budget(cfg.page_bytes, cfg.pool_budget);
        let workers = Workers::auto(cfg.threads);
        let batch_scratch = BatchScratch::sized_with(&model.cfg, cfg.max_batch, workers.clone());
        let footprint = SequenceFootprint::of(&model.cfg, &factory);
        let prefix_cache = PrefixCache::new(cfg.prefill_chunk.max(1));
        Engine {
            model,
            factory,
            footprint,
            cfg,
            pool,
            prefix_cache,
            waiting: VecDeque::new(),
            running: Vec::new(),
            batch_scratch,
            workers,
            metrics: Metrics::default(),
            done: Vec::new(),
            ejected: Vec::new(),
            prefix_events: Vec::new(),
        }
    }

    /// Drain responses completed since the last drain (replica-worker
    /// surface; [`Engine::run_to_completion`] drains the same buffer).
    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    /// Drain requests ejected by preemption under `cfg.eject_preempted`
    /// (empty in standalone mode, where preemptions re-queue locally).
    pub fn take_preempted(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.ejected)
    }

    /// Drain prefix publication/retirement events since the last drain.
    pub fn take_prefix_events(&mut self) -> Vec<PrefixEvent> {
        std::mem::take(&mut self.prefix_events)
    }

    /// Enqueue a request (stamps arrival time). The id must be unique
    /// among in-flight requests — it keys the page-pool ledger, so a
    /// duplicate would silently merge two sequences' reservations.
    pub fn submit(&mut self, mut req: Request) {
        assert!(
            !self.waiting.iter().any(|w| w.id == req.id)
                && !self.running.iter().any(|r| r.req.id == req.id),
            "duplicate in-flight request id {}",
            req.id
        );
        req.arrival.get_or_insert_with(Instant::now);
        self.metrics.requests_submitted += 1;
        self.waiting.push_back(req);
    }

    /// Number of requests not yet completed.
    pub fn outstanding(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    /// Admission price of a request: the factory's footprint at the decode
    /// horizon — `prompt + max_new_tokens` tokens, capped at `max_seq`
    /// (decode stops there regardless of the token budget). Backend-aware:
    /// a SALS factory prices the same request at a fraction of dense fp32.
    fn admission_bytes(&self, req: &Request) -> usize {
        // saturating: a sentinel-huge max_new_tokens ("unbounded") must
        // clamp to max_seq, not wrap into a tiny horizon.
        let horizon =
            req.prompt.len().saturating_add(req.params.max_new_tokens).min(self.model.cfg.max_seq);
        self.footprint.bytes_at(horizon)
    }

    fn admit(&mut self) {
        while self.running.len() < self.cfg.max_batch {
            let Some(front) = self.waiting.front() else { break };
            // Prefix reuse: the longest published prefix of what this
            // request would prefill (prompt ++ carried generated tokens).
            // Adoption must leave at least one token to prefill — the
            // suffix pass is what produces the first logits.
            let mut adoption: Option<(usize, SharedId, SequenceSnapshot)> =
                if self.cfg.prefix_reuse {
                    let mut toks =
                        Vec::with_capacity(front.prompt.len() + front.generated.len());
                    toks.extend_from_slice(&front.prompt);
                    toks.extend_from_slice(&front.generated);
                    self.prefix_cache
                        .lookup_longest(&toks)
                        .filter(|&(n, _, _)| n < toks.len())
                        .map(|(n, id, snap)| (n, id, snap.clone()))
                } else {
                    None
                };
            // Reserve the full-horizon footprint NOW: later iterations of
            // this loop see the reduced free-page count, so a burst of
            // requests can no longer all be admitted against the same
            // memory (the pre-PR-3 over-commit→preemption-churn bug).
            let mut est = self.admission_bytes(front);
            if let Some((_, id, snap)) = &adoption {
                // Retain BEFORE reserving so our own reservation's
                // eviction pass cannot reclaim the holding we are about
                // to adopt; the private price excludes the shared bytes,
                // which the shared ledger already charges once.
                if self.pool.retain_shared(*id) {
                    est = est.saturating_sub(snap.shared_bytes());
                } else {
                    adoption = None; // index/pool desync — cold admit
                }
            }
            let pool_bytes = self.pool.page_bytes * self.pool.total_pages;
            if est > pool_bytes && self.running.is_empty() {
                // The horizon exceeds even an EMPTY pool (e.g. a huge
                // max_new_tokens whose stop token fires early in practice).
                // Strict pricing would park the request forever and stall
                // the queue behind it; admit it best-effort with the whole
                // pool instead — the accounting safety valve governs its
                // actual growth from here.
                est = pool_bytes;
            }
            if self.pool.reserve(front.id, est).is_err() {
                if let Some((_, id, _)) = adoption {
                    self.pool.release_shared(id);
                }
                break; // backpressure
            }
            self.drain_evictions();
            let mut req = self.waiting.pop_front().unwrap();
            let mut state = SequenceState::new(&self.model.cfg, &self.factory);
            let scratch = Scratch::new(&self.model.cfg);
            // Resume support: a preempted request carries its emitted
            // tokens — recompute prefills prompt ++ generated and decode
            // picks up after the last emitted token (out is seeded so the
            // max_new_tokens budget does not reset).
            let out = std::mem::take(&mut req.generated);
            let mut prefill_tokens = Vec::with_capacity(req.prompt.len() + out.len());
            prefill_tokens.extend_from_slice(&req.prompt);
            prefill_tokens.extend_from_slice(&out);
            // Re-hydrate the adopted prefix: the backends take the frozen
            // panels by reference and prefill resumes at the boundary.
            let mut prefilled = 0usize;
            let mut adopted = None;
            if let Some((n, id, snap)) = adoption {
                if state.adopt_prefix(&snap) {
                    prefilled = n;
                    adopted = Some(id);
                    self.metrics.prefix_adoptions += 1;
                    self.metrics.prefill_tokens_avoided += n;
                } else {
                    // A refused adopt may leave layers partially adopted;
                    // the state must be rebuilt cold, never patched.
                    state = SequenceState::new(&self.model.cfg, &self.factory);
                    self.pool.release_shared(id);
                }
            }
            // Resumed requests keep their ORIGINAL scheduling/first-token
            // timestamps: the first token is never re-emitted, so TTFT
            // and queue delay must describe the first run.
            let first_step = req.first_step.take();
            let first_token = req.first_token.take();
            let peak_kv = req.peak_kv_bytes;
            self.running.push(Running {
                req,
                state,
                scratch,
                peak_kv,
                prefill_tokens,
                prefilled,
                out,
                logits: None,
                finished: false,
                first_step,
                first_token,
                reserved_bytes: est,
                adopted,
                published: false,
            });
        }
        self.metrics.peak_running = self.metrics.peak_running.max(self.running.len());
    }

    /// Sync the prefix index with holdings the pool evicted under
    /// pressure (any reserve/publish may evict unreferenced entries).
    fn drain_evictions(&mut self) {
        for id in self.pool.take_evicted() {
            for hash in self.prefix_cache.remove_shared(id) {
                self.prefix_events.push(PrefixEvent { hash, tokens: 0, published: false });
            }
            self.metrics.shared_prefix_evictions += 1;
        }
    }

    /// One engine step. Returns the number of sequences that actually did
    /// work this step — consumed a prefill chunk or produced a decode
    /// token. (0 only when nothing is running, e.g. admission is
    /// pool-gated; finished sequences removed at the end of the step still
    /// count as stepped.)
    pub fn step(&mut self) -> usize {
        self.admit();
        if self.running.is_empty() {
            return 0;
        }
        self.metrics.steps += 1;
        let now = Instant::now();
        let prefill_chunk = self.cfg.prefill_chunk.max(1);

        let stepped;
        let mut decoded = 0usize;
        {
            let Engine {
                model,
                running,
                batch_scratch,
                workers,
                pool,
                prefix_cache,
                prefix_events,
                metrics,
                cfg,
                ..
            } = self;
            let model: &Model = model;

            // ---- partition: prefilling vs decode-ready ----
            // A sequence whose prefill completes this step gets its first
            // logits now and joins the decode set next step (continuous
            // batching, unchanged from the scalar engine).
            let mut prefilling: Vec<&mut Running> = Vec::new();
            let mut decoding: Vec<&mut Running> = Vec::new();
            let mut degenerate = 0usize;
            for r in running.iter_mut() {
                r.first_step.get_or_insert(now);
                if r.prefilled < r.prefill_tokens.len() {
                    prefilling.push(r);
                } else if r.logits.is_some() {
                    decoding.push(r);
                } else {
                    // Degenerate: an empty prompt never produces logits
                    // (prefill never runs), so there is nothing to decode
                    // from — complete with whatever was generated (nothing).
                    // Counts as stepped: the request progresses (it is
                    // collected below), so the stall guard must not trip
                    // on a stream of these.
                    r.finished = true;
                    degenerate += 1;
                }
            }
            stepped = prefilling.len() + decoding.len() + degenerate;

            // ---- prefill phase: one batched chunk per sequence, fanned
            // out across the persistent pool (per-sequence caches +
            // scratch are independent; the model is shared read-only).
            // Leftover lanes are granted to each chunk's intra-attend
            // fan-out (per-KV-head lanes, block score scans) as disjoint
            // sub-handles carved from the same budget — live workers
            // never exceed the pool size. ----
            workers.nested_for_each_mut(&mut prefilling, |_, r, sub| {
                r.state.set_attend_workers(sub);
                let hi = (r.prefilled + prefill_chunk).min(r.prefill_tokens.len());
                let last = hi == r.prefill_tokens.len();
                let l = model.forward_batch(
                    &mut r.state,
                    &mut r.scratch,
                    &r.prefill_tokens[r.prefilled..hi],
                    last,
                );
                if last {
                    r.logits = l;
                    // Transition to decode: drop the prefill-sized panels
                    // in every layer backend and the chunk-sized activation
                    // matrices (they'd otherwise pin O(prompt·d +
                    // chunk·d_ff) scratch all decode long). Decode uses the
                    // engine's shared BatchScratch instead.
                    r.state.end_prefill();
                    r.scratch.end_prefill();
                }
                r.prefilled = hi;
            });

            // ---- prefix publication: when a sequence's prefill crosses
            // its largest complete-chunk boundary (which it does exactly
            // once — prefill advances in whole chunks), freeze those
            // tokens into the shared ledger + index so later requests
            // with the same prompt prefix can adopt instead of
            // recomputing. One attempt per sequence; an existing entry
            // for the same tokens wins; a backend that refuses to fork
            // (e.g. SALS mid-sparse-prefill) just skips publication. ----
            if cfg.prefix_reuse {
                for r in prefilling.iter_mut() {
                    let len = r.prefill_tokens.len();
                    if r.published
                        || r.prefilled == 0
                        || r.prefilled % prefill_chunk != 0
                        || len - r.prefilled >= prefill_chunk
                    {
                        continue;
                    }
                    r.published = true;
                    let key = &r.prefill_tokens[..r.prefilled];
                    if prefix_cache.contains(key) {
                        continue;
                    }
                    let Some(snap) = r.state.fork_prefix(r.prefilled) else { continue };
                    let Ok(id) = pool.publish_shared(snap.shared_bytes()) else { continue };
                    for ev in pool.take_evicted() {
                        for hash in prefix_cache.remove_shared(ev) {
                            prefix_events
                                .push(PrefixEvent { hash, tokens: 0, published: false });
                        }
                        metrics.shared_prefix_evictions += 1;
                    }
                    prefix_cache.insert(key, id, snap);
                    prefix_events.push(PrefixEvent {
                        hash: crate::kvcache::prefix_hash(key),
                        tokens: key.len(),
                        published: true,
                    });
                    metrics.prefix_publications += 1;
                }
            }

            // ---- decode phase: sample pending logits, then ONE stacked
            // forward for every sequence still generating ----
            let mut batch: Vec<(&mut Running, usize)> = Vec::with_capacity(decoding.len());
            for r in decoding {
                let logits = r.logits.take().unwrap();
                let next = crate::tensor::ops::argmax(&logits);
                decoded += 1;
                r.out.push(next);
                r.first_token.get_or_insert_with(Instant::now);
                if r.out.len() >= r.req.params.max_new_tokens
                    || r.req.params.stop_token == Some(next)
                    || r.state.pos + 1 >= model.cfg.max_seq
                {
                    r.finished = true;
                } else {
                    batch.push((r, next));
                }
            }
            if !batch.is_empty() {
                let tokens: Vec<usize> = batch.iter().map(|(_, t)| *t).collect();
                // decode_batch divides the pool between cross-sequence
                // batch rows and intra-attend parallelism itself: rows
                // are partitioned over the scratch's pool handle and the
                // leftover lanes are granted to each block's sequences
                // as nested sub-shares, re-derived every step as the
                // batch grows and shrinks. Worker handles never change
                // outputs (the set_workers contract), only scheduling.
                let mut states: Vec<&mut SequenceState> =
                    batch.iter_mut().map(|(r, _)| &mut r.state).collect();
                let all_logits = model.decode_batch(&mut states, &tokens, batch_scratch);
                drop(states);
                for ((r, _), l) in batch.iter_mut().zip(all_logits) {
                    r.logits = Some(l);
                }
            }
        }

        self.metrics.tokens_decoded += decoded;

        // ---- collect finished (flag set at decode time — no O(out) scan),
        // releasing their pages before the survivors re-reserve ----
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].finished {
                let mut r = self.running.remove(i);
                // Final growth happened this step, after the last peak
                // refresh — fold it in before the state is dropped.
                r.peak_kv = r.peak_kv.max(r.state.kv_bytes());
                self.pool.release(r.req.id);
                if let Some(id) = r.adopted {
                    // Drop the adoption refcount; the holding stays
                    // resident as reusable cache until pressure evicts it.
                    self.pool.release_shared(id);
                }
                let arrival = r.req.arrival.unwrap_or(now);
                let end = Instant::now();
                self.metrics.requests_completed += 1;
                self.metrics.tokens_prefilled += r.req.prompt.len();
                self.metrics.tokens_generated += r.out.len();
                let ttft = r.first_token.map(|t| t - arrival).unwrap_or_default().as_secs_f64();
                let e2e = (end - arrival).as_secs_f64();
                self.metrics.ttft.push(ttft);
                self.metrics.e2e.push(e2e);
                self.done.push(Response {
                    id: r.req.id,
                    prompt_len: r.req.prompt.len(),
                    tokens: r.out,
                    queue_s: r.first_step.map(|t| t - arrival).unwrap_or_default().as_secs_f64(),
                    ttft_s: ttft,
                    e2e_s: e2e,
                    preemptions: r.req.preemptions,
                    peak_kv_bytes: r.peak_kv,
                });
            } else {
                i += 1;
            }
        }

        // ---- pool accounting + preemption ----
        // Re-reserve every survivor to max(actual kv_bytes, admission
        // reservation): growth is tracked, admitted headroom is kept. A
        // failure means a footprint under-estimated (the reserve-at-admit
        // ledger already priced everyone's horizon) — preempt the single
        // *youngest* sequence, retry all reservations, repeat: minimal
        // FCFS-friendly eviction, never the old evict-everyone-that-failed.
        for r in self.running.iter_mut() {
            r.peak_kv = r.peak_kv.max(r.state.kv_bytes());
        }
        loop {
            let mut exhausted = false;
            for r in self.running.iter() {
                // Bytes held by reference to an adopted shared prefix are
                // subtracted — the shared ledger charges them once.
                // Saturating: a window-capped backend (StreamingLLM) can
                // report kv_bytes below the un-evicted shared panel size.
                let target = r
                    .state
                    .kv_bytes()
                    .saturating_sub(r.state.shared_prefix_bytes())
                    .max(r.reserved_bytes);
                if self.pool.reserve(r.req.id, target).is_err() {
                    exhausted = true;
                    break;
                }
            }
            if !exhausted {
                break;
            }
            // Youngest = last admitted (running keeps admission order;
            // collection preserves it, re-admissions append).
            let r = self.running.pop().expect("pool exhausted with nothing running");
            self.pool.release(r.req.id);
            if let Some(id) = r.adopted {
                self.pool.release_shared(id);
            }
            // A victim that was running ALONE failed against an otherwise
            // empty pool: its live cache exceeds the entire budget, so
            // re-queueing would preempt/recompute-loop forever (and the
            // stall guard never fires — recompute counts as progress).
            // Fail loudly instead, like the stall guard does for requests
            // that can never be admitted.
            assert!(
                !self.running.is_empty(),
                "request {} can never fit: needs {} bytes, pool holds {}",
                r.req.id,
                r.state.kv_bytes().max(r.reserved_bytes),
                self.pool.page_bytes * self.pool.total_pages
            );
            self.metrics.preemptions += 1;
            // Drop caches; recompute later (vLLM recompute mode) — but
            // RESUME, don't restart: the emitted tokens ride on the
            // request, re-admission prefills prompt ++ generated, and
            // decode continues after the last emitted token. Preemption
            // costs re-prefill work only, never re-decoded tokens. The
            // preemption count rides along the same way.
            let mut req = r.req;
            req.preemptions += 1;
            req.generated = r.out;
            req.first_step = r.first_step;
            req.first_token = r.first_token;
            req.arrival = req.arrival.or(Some(now));
            req.peak_kv_bytes = r.peak_kv;
            if self.cfg.eject_preempted {
                // Replica mode: hand the request back to the coordinator
                // for a least-loaded re-route instead of resuming here.
                self.ejected.push(req);
            } else {
                self.waiting.push_front(req);
            }
        }
        self.drain_evictions();
        // The pool tracks its own high-water mark inside every reserve(),
        // so this is exact even when the peak happened mid-step (e.g. just
        // before a finishing sequence released its pages).
        self.metrics.peak_pool_pages = self.pool.peak_used_pages();

        stepped
    }

    /// Drive until every submitted request completes; returns responses in
    /// completion order.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let t0 = Instant::now();
        let mut stall_guard = 0usize;
        while self.outstanding() > 0 {
            // step() returns the number of sequences that did work; with
            // requests outstanding, 0 means admission is pool-gated with
            // nothing running, so a long run of zeros is a stuck pool (a
            // request that can never fit), not slow progress.
            let stepped = self.step();
            if stepped == 0 {
                stall_guard += 1;
                assert!(
                    stall_guard < 1000,
                    "engine stalled: {} waiting, pool free {} pages",
                    self.waiting.len(),
                    self.pool.free_pages()
                );
            } else {
                stall_guard = 0;
            }
        }
        self.metrics.wall_s += t0.elapsed().as_secs_f64();
        std::mem::take(&mut self.done)
    }
}

/// Test-only helpers shared with the cluster tests (which need the same
/// preemption-forcing scenarios this module pins for a single engine).
#[cfg(test)]
pub(crate) mod testutil {
    use crate::attention::FullAttention;

    /// FullAttention wrapper whose footprint *lies* (claims zero growth):
    /// forces admission to over-admit so actual `kv_bytes()` growth must
    /// hit the preemption path — the safety valve for under-estimating
    /// footprints.
    pub(crate) struct LyingFootprint(pub(crate) FullAttention);

    impl crate::attention::AttentionBackend for LyingFootprint {
        fn append(&mut self, k: &[f32], v: &[f32]) {
            self.0.append(k, v)
        }
        fn attend(&mut self, q: &[f32], out: &mut [f32]) {
            self.0.attend(q, out)
        }
        fn len(&self) -> usize {
            self.0.len()
        }
        fn traffic(&self) -> crate::attention::Traffic {
            self.0.traffic()
        }
        fn kv_bytes(&self) -> usize {
            self.0.kv_bytes()
        }
        fn fork_prefix(&self, n_tokens: usize) -> Option<crate::attention::PrefixSnapshot> {
            self.0.fork_prefix(n_tokens)
        }
        fn adopt_prefix(&mut self, snap: &crate::attention::PrefixSnapshot) -> bool {
            self.0.adopt_prefix(snap)
        }
        fn shared_prefix_bytes(&self) -> usize {
            self.0.shared_prefix_bytes()
        }
        fn footprint(&self) -> crate::attention::FootprintModel {
            crate::attention::FootprintModel::linear(0, 0)
        }
        fn name(&self) -> &'static str {
            "lying-footprint"
        }
    }

    /// FullAttention wrapper that under-claims its growth by 2× instead of
    /// ∞: admission still over-admits (forcing the preemption path), but
    /// dispatch costs stay nonzero — what the cluster tests need to assert
    /// router-ledger conservation across preemption re-routes (a zero-cost
    /// footprint would make "no load leaked" vacuously true).
    pub(crate) struct HalvedFootprint(pub(crate) FullAttention);

    impl crate::attention::AttentionBackend for HalvedFootprint {
        fn append(&mut self, k: &[f32], v: &[f32]) {
            self.0.append(k, v)
        }
        fn attend(&mut self, q: &[f32], out: &mut [f32]) {
            self.0.attend(q, out)
        }
        fn len(&self) -> usize {
            self.0.len()
        }
        fn traffic(&self) -> crate::attention::Traffic {
            self.0.traffic()
        }
        fn kv_bytes(&self) -> usize {
            self.0.kv_bytes()
        }
        fn fork_prefix(&self, n_tokens: usize) -> Option<crate::attention::PrefixSnapshot> {
            self.0.fork_prefix(n_tokens)
        }
        fn adopt_prefix(&mut self, snap: &crate::attention::PrefixSnapshot) -> bool {
            self.0.adopt_prefix(snap)
        }
        fn shared_prefix_bytes(&self) -> usize {
            self.0.shared_prefix_bytes()
        }
        fn footprint(&self) -> crate::attention::FootprintModel {
            let f = self.0.footprint();
            crate::attention::FootprintModel {
                bytes_per_token: f.bytes_per_token / 2,
                ..f
            }
        }
        fn name(&self) -> &'static str {
            "halved-footprint"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::LyingFootprint;
    use super::*;
    use crate::attention::FullAttention;
    use crate::coordinator::request::GenParams;
    use crate::model::{ModelConfig, Weights};
    use std::sync::Arc;

    fn engine(max_batch: usize, budget: usize) -> Engine {
        let cfg = ModelConfig::tiny_mha(128);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
        Engine::new(
            model,
            factory,
            EngineConfig {
                max_batch,
                prefill_chunk: 8,
                page_bytes: 4096,
                pool_budget: budget,
                threads: 2,
                prefix_reuse: false,
                eject_preempted: false,
            },
        )
    }

    #[test]
    fn completes_all_requests() {
        let mut e = engine(4, 1 << 24);
        for i in 0..10 {
            e.submit(Request::new(i, vec![1, 2, 3, (i as usize) % 50], GenParams { max_new_tokens: 5, stop_token: None }));
        }
        let responses = e.run_to_completion();
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.tokens.len(), 5);
            assert!(r.e2e_s >= 0.0 && r.ttft_s >= 0.0);
        }
        assert_eq!(e.metrics.requests_completed, 10);
        assert_eq!(e.metrics.tokens_generated, 50);
    }

    #[test]
    fn output_matches_unbatched_generation() {
        // Batched serving must produce exactly the same tokens as a direct
        // greedy generation (continuous batching is semantically invisible).
        let mut e = engine(3, 1 << 24);
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6, 7], vec![9, 10, 11, 12], vec![42]];
        for (i, p) in prompts.iter().enumerate() {
            e.submit(Request::new(i as u64, p.clone(), GenParams { max_new_tokens: 6, stop_token: None }));
        }
        let mut responses = e.run_to_completion();
        responses.sort_by_key(|r| r.id);

        let cfg = ModelConfig::tiny_mha(128);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
        for (i, p) in prompts.iter().enumerate() {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut scratch = Scratch::new(&cfg);
            let direct = model.generate_greedy(&mut state, &mut scratch, p, 6);
            assert_eq!(responses[i].tokens, direct, "request {i}");
        }
    }

    /// Engine output vs direct greedy generation for an arbitrary backend
    /// family: batched decode must be semantically invisible for the
    /// compressed-cache paths too, not just FullAttention. Prompts stay
    /// under one prefill chunk so both sides run identical arithmetic
    /// (single-chunk forward_batch + per-row decode), making the token
    /// comparison exact.
    fn assert_engine_matches_direct(make: &dyn Fn() -> Box<BackendFactory>, seed: u64) {
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6, 7], vec![9, 10, 11, 12], vec![42], vec![1, 2]];
        let cfg = ModelConfig::tiny_gqa(128);
        let mut e = Engine::new(
            Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, seed))),
            make(),
            EngineConfig {
                max_batch: 4,
                prefill_chunk: 8,
                page_bytes: 4096,
                pool_budget: 1 << 24,
                threads: 2,
                prefix_reuse: false,
                eject_preempted: false,
            },
        );
        for (i, p) in prompts.iter().enumerate() {
            e.submit(Request::new(i as u64, p.clone(), GenParams { max_new_tokens: 6, stop_token: None }));
        }
        let mut responses = e.run_to_completion();
        responses.sort_by_key(|r| r.id);

        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, seed)));
        let factory = make();
        for (i, p) in prompts.iter().enumerate() {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut scratch = Scratch::new(&cfg);
            let direct = model.generate_greedy(&mut state, &mut scratch, p, 6);
            assert_eq!(responses[i].tokens, direct, "request {i}");
        }
    }

    #[test]
    fn output_matches_unbatched_generation_sals() {
        use crate::attention::{SalsAttention, SalsConfig};
        use crate::lowrank::Calibrator;
        use crate::quant::Bits;
        use crate::util::rng::Rng;
        let cfg = ModelConfig::tiny_gqa(128);
        let shape = cfg.attn_shape();
        let kvd = cfg.kv_dim();
        let mut crng = Rng::new(61);
        let mut cal = Calibrator::new(kvd);
        for _ in 0..4 * kvd {
            cal.add_key(&crng.normal_vec(kvd, 1.0));
        }
        let proj = cal.fit(kvd / 2).unwrap();
        // critical ≥ any length reached here, so the selection set is
        // insensitive to top-k score ties; the latent store, recent ring,
        // and quantized values are all still exercised.
        let sc = SalsConfig {
            rank: kvd / 2,
            r_star: kvd / 4,
            sink: 2,
            recent: 4,
            critical: 64,
            v_bits: Bits::B4,
            group: 8,
            prefill: None,
        };
        assert_engine_matches_direct(
            &move || {
                let (p, c) = (proj.clone(), sc.clone());
                Box::new(move |_| {
                    Box::new(SalsAttention::new(shape, c.clone(), p.clone()))
                        as Box<dyn crate::attention::AttentionBackend + Send>
                })
            },
            53,
        );
    }

    #[test]
    fn output_matches_unbatched_generation_streaming_llm() {
        use crate::attention::baselines::streaming_llm::StreamingLlmAttention;
        let cfg = ModelConfig::tiny_gqa(128);
        let shape = cfg.attn_shape();
        // sink 2 + recent 4 < generated length: eviction is active, so the
        // parity covers a backend whose cache actually drops tokens.
        assert_engine_matches_direct(
            &move || {
                Box::new(move |_| {
                    Box::new(StreamingLlmAttention::new(shape, 2, 4))
                        as Box<dyn crate::attention::AttentionBackend + Send>
                })
            },
            59,
        );
    }

    #[test]
    fn step_returns_count_actually_stepped() {
        let mut e = engine(4, 1 << 24);
        assert_eq!(e.step(), 0, "nothing submitted");
        e.submit(Request::new(0, vec![1, 2, 3], GenParams { max_new_tokens: 2, stop_token: None }));
        e.submit(Request::new(1, vec![4, 5], GenParams { max_new_tokens: 2, stop_token: None }));
        assert_eq!(e.step(), 2, "both consume their single prefill chunk");
        assert_eq!(e.step(), 2, "both decode token 1");
        assert_eq!(e.step(), 2, "both decode token 2 and finish this step");
        assert_eq!(e.step(), 0, "nothing left running");
        assert_eq!(e.outstanding(), 0);
        assert_eq!(e.metrics.requests_completed, 2);
    }

    #[test]
    fn prefill_chunk_scheduling_is_sound() {
        // Chunked batched prefill is a scheduling choice: every chunk size
        // must complete every request deterministically with the right
        // token counts, and a chunk size spanning the whole prompt must be
        // bitwise-identical to direct generation (same single-chunk
        // forward_batch calls on both sides). Cross-chunk-size *token*
        // equality is deliberately not asserted here: different blockings
        // reassociate fp adds (~1e-5 logit drift), so greedy argmax is
        // only statistically — not provably — invariant; the semantic
        // equivalence claim lives in proptests.rs at the logits level
        // with a 1e-4 tolerance.
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6, 7, 8, 9, 10, 11], vec![1, 2, 3]];
        let run = |chunk: usize| -> Vec<Vec<usize>> {
            let cfg = ModelConfig::tiny_mha(128);
            let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 41)));
            let shape = cfg.attn_shape();
            let factory: Box<BackendFactory> =
                Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
            let mut e = Engine::new(
                model,
                factory,
                EngineConfig {
                    max_batch: 2,
                    prefill_chunk: chunk,
                    page_bytes: 4096,
                    pool_budget: 1 << 24,
                    threads: 1,
                    prefix_reuse: false,
                    eject_preempted: false,
                },
            );
            for (i, p) in prompts.iter().enumerate() {
                e.submit(Request::new(
                    i as u64,
                    p.clone(),
                    GenParams { max_new_tokens: 4, stop_token: None },
                ));
            }
            let mut rs = e.run_to_completion();
            rs.sort_by_key(|r| r.id);
            assert_eq!(rs.len(), prompts.len(), "chunk {chunk}: not all requests completed");
            rs.into_iter().map(|r| r.tokens).collect()
        };
        // Multi-chunk schedules (1- and 4-token chunks) complete with the
        // right counts and are run-to-run deterministic.
        for chunk in [1usize, 4] {
            let toks = run(chunk);
            assert!(toks.iter().all(|t| t.len() == 4), "chunk {chunk}: {toks:?}");
            assert_eq!(toks, run(chunk), "chunk {chunk}: nondeterministic");
        }
        // Whole-prompt chunk == direct generation, exactly: both sides make
        // one forward_batch call per prompt, so the arithmetic is identical.
        let engine_tokens = run(64);
        let cfg = ModelConfig::tiny_mha(128);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 41)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
        for (i, p) in prompts.iter().enumerate() {
            let mut state = SequenceState::new(&cfg, &factory);
            let mut scratch = Scratch::new(&cfg);
            let direct = model.generate_greedy(&mut state, &mut scratch, p, 4);
            assert_eq!(engine_tokens[i], direct, "request {i}");
        }
    }

    #[test]
    fn stop_token_halts_generation() {
        let mut e = engine(1, 1 << 24);
        // Find what the model generates, then use its first token as stop.
        e.submit(Request::new(0, vec![3, 4], GenParams { max_new_tokens: 8, stop_token: None }));
        let r = e.run_to_completion();
        let first = r[0].tokens[0];
        let mut e2 = engine(1, 1 << 24);
        e2.submit(Request::new(1, vec![3, 4], GenParams { max_new_tokens: 8, stop_token: Some(first) }));
        let r2 = e2.run_to_completion();
        assert_eq!(r2[0].tokens.len(), 1);
    }

    #[test]
    fn tiny_pool_causes_backpressure_not_deadlock() {
        // Budget fits ~one sequence; engine must still finish all requests
        // serially via admission gating.
        let kv_one = 40 * 6 * 2 * 128 * 4; // ~40 tokens worth
        let mut e = engine(4, kv_one);
        for i in 0..4 {
            e.submit(Request::new(i, vec![1, 2, 3], GenParams { max_new_tokens: 4, stop_token: None }));
        }
        let responses = e.run_to_completion();
        assert_eq!(responses.len(), 4);
    }

    #[test]
    fn metrics_populated() {
        let mut e = engine(2, 1 << 24);
        for i in 0..3 {
            e.submit(Request::new(i, vec![1, 2], GenParams { max_new_tokens: 3, stop_token: None }));
        }
        e.run_to_completion();
        assert!(e.metrics.wall_s > 0.0);
        assert!(e.metrics.tokens_per_second() > 0.0);
        assert_eq!(e.metrics.ttft.len(), 3);
        assert!(e.metrics.steps > 0);
        assert!(e.metrics.peak_running >= 1);
    }

    #[test]
    fn admission_reserves_and_does_not_overcommit() {
        // tiny_mha(128): 6 layers × 2 × kv_dim 128 × 4 B = 6144 B/token.
        // Horizon = prompt 4 + max_new 4 = 8 tokens → 49152 B → 12 pages
        // (4096 B pages). A 16-page pool holds ONE such reservation — a
        // burst of 4 simultaneous requests must not all be admitted in one
        // admit() pass (the pre-reservation over-commit bug).
        let mut e = engine(4, 16 * 4096);
        for i in 0..4 {
            e.submit(Request::new(i, vec![1, 2, 3, 4], GenParams { max_new_tokens: 4, stop_token: None }));
        }
        e.admit();
        assert_eq!(e.running.len(), 1, "one admit() pass over-committed the pool");
        assert_eq!(e.waiting.len(), 3);
        let responses = e.run_to_completion();
        assert_eq!(responses.len(), 4);
        // Honest reserve-ahead admission means growth never outruns the
        // pool: zero preemption churn, and every response reports so.
        assert_eq!(e.metrics.preemptions, 0);
        assert!(responses.iter().all(|r| r.preemptions == 0));
        assert_eq!(e.metrics.peak_running, 1);
    }

    #[test]
    fn oversized_horizon_is_admitted_best_effort_when_pool_idle() {
        // max_new_tokens prices the horizon beyond the entire pool, but a
        // stop token ends generation after one token in practice: strict
        // horizon pricing would park the request (and the queue behind it)
        // forever; an idle pool must admit it best-effort instead.
        let mut e = engine(1, 40 * 6144);
        e.submit(Request::new(0, vec![3, 4], GenParams { max_new_tokens: 8, stop_token: None }));
        let first = e.run_to_completion()[0].tokens[0];

        let mut e2 = engine(2, 40 * 6144);
        e2.submit(Request::new(
            1,
            vec![3, 4],
            GenParams { max_new_tokens: 1 << 20, stop_token: Some(first) },
        ));
        // A normal request queued behind it must also complete.
        e2.submit(Request::new(2, vec![5, 6], GenParams { max_new_tokens: 4, stop_token: None }));
        let mut rs = e2.run_to_completion();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].tokens.len(), 1, "stop token must end the oversized request");
        assert_eq!(rs[1].tokens.len(), 4);
    }

    #[test]
    #[should_panic(expected = "can never fit")]
    fn impossible_request_fails_loudly() {
        // 8 pages ≈ 5 tokens of dense cache; the 8-token prompt alone can
        // never fit. Best-effort admission lets it in (idle pool), growth
        // evicts it while running alone — that must be a loud failure, not
        // a silent preempt/recompute livelock.
        let mut e = engine(1, 8 * 4096);
        e.submit(Request::new(
            0,
            vec![1, 2, 3, 4, 5, 6, 7, 8],
            GenParams { max_new_tokens: 4, stop_token: None },
        ));
        e.run_to_completion();
    }

    #[test]
    fn preempted_request_reports_preemptions() {
        // Pool of 32 pages; two 16-token sequences need 24 pages EACH at
        // completion (16 × 6144 B = 24 pages), so running both concurrently
        // must preempt. The zero footprint admits both; growth evicts the
        // youngest (id 1) at least once; the oldest (id 0) must never be
        // touched — and the completed response must carry the count
        // (regression: it was incremented on a dropped struct and reset to
        // 0 on re-admission, so Response.preemptions was always 0).
        let cfg = ModelConfig::tiny_mha(128);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(LyingFootprint(FullAttention::new(shape))) as _);
        let mut e = Engine::new(
            model,
            factory,
            EngineConfig {
                max_batch: 2,
                prefill_chunk: 8,
                page_bytes: 4096,
                pool_budget: 32 * 4096,
                threads: 2,
                prefix_reuse: false,
                eject_preempted: false,
            },
        );
        for i in 0..2 {
            e.submit(Request::new(
                i,
                vec![1, 2, 3, 4, 5, 6, 7, 8],
                GenParams { max_new_tokens: 8, stop_token: None },
            ));
        }
        let mut responses = e.run_to_completion();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2);
        assert!(responses.iter().all(|r| r.tokens.len() == 8));
        assert!(e.metrics.preemptions >= 1, "scenario must actually force preemption");
        // Youngest-first-minimal: every preemption lands on id 1, id 0 runs
        // undisturbed, and the per-request counts add up to the engine's.
        assert_eq!(responses[0].preemptions, 0, "oldest sequence must not be preempted");
        assert!(responses[1].preemptions >= 1, "preempted request must report it");
        assert_eq!(
            responses.iter().map(|r| r.preemptions).sum::<usize>(),
            e.metrics.preemptions,
            "Response counts must account for every engine preemption"
        );
        // Recompute-RESUME: the re-queued request carries its emitted
        // tokens, so no token is ever decoded twice — total decode
        // samples must equal the tokens delivered, despite preemptions
        // (the pre-fix engine dropped `out` and re-decoded the victim's
        // whole output from scratch).
        let delivered: usize = responses.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(
            e.metrics.tokens_decoded, delivered,
            "resumed request must not re-decode already-emitted tokens"
        );
        assert_eq!(e.metrics.tokens_generated, delivered);
    }

    fn engine_with_reuse(max_batch: usize, budget: usize, reuse: bool) -> Engine {
        let cfg = ModelConfig::tiny_mha(128);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
        Engine::new(
            model,
            factory,
            EngineConfig {
                max_batch,
                prefill_chunk: 8,
                page_bytes: 4096,
                pool_budget: budget,
                threads: 2,
                prefix_reuse: reuse,
                eject_preempted: false,
            },
        )
    }

    #[test]
    fn prefix_reuse_avoids_prefill_and_matches_cold_outputs() {
        // Three sequential requests with the same 12-token prompt
        // (prefill chunk 8): with reuse on, the first publishes its
        // 8-token chunk boundary and the next two adopt it, prefilling
        // only the 4-token suffix — and because adopt restores the exact
        // panels, every generated token matches the cold run exactly.
        let prompt: Vec<usize> = (1..=12).collect();
        let run = |reuse: bool| {
            let mut e = engine_with_reuse(2, 1 << 24, reuse);
            let mut all = Vec::new();
            for i in 0..3u64 {
                e.submit(Request::new(
                    i,
                    prompt.clone(),
                    GenParams { max_new_tokens: 5, stop_token: None },
                ));
                all.append(&mut e.run_to_completion());
            }
            (all, e.metrics.clone())
        };
        let (cold, mc) = run(false);
        let (warm, mw) = run(true);
        assert_eq!(mc.prefix_adoptions, 0);
        assert_eq!(mc.prefill_tokens_avoided, 0);
        assert_eq!(mw.prefix_publications, 1, "later identical prefixes must not re-publish");
        assert_eq!(mw.prefix_adoptions, 2);
        assert_eq!(mw.prefill_tokens_avoided, 16);
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.tokens, w.tokens, "request {}: adopted decode diverged from cold", c.id);
        }
    }

    #[test]
    fn unreferenced_prefix_evicted_under_pool_pressure() {
        // 32-page pool; each request reserves 18 pages (12-token horizon)
        // and publishes a 12-page prefix. The second (different-prompt)
        // publication does not fit next to the first — the pool must
        // reclaim the finished, unreferenced holding rather than skip
        // publishing or deadlock.
        let mut e = engine_with_reuse(2, 32 * 4096, true);
        e.submit(Request::new(0, (1..=8).collect(), GenParams { max_new_tokens: 4, stop_token: None }));
        assert_eq!(e.run_to_completion().len(), 1);
        assert_eq!(e.metrics.prefix_publications, 1);
        assert_eq!(e.metrics.shared_prefix_evictions, 0);
        e.submit(Request::new(1, (21..=28).collect(), GenParams { max_new_tokens: 4, stop_token: None }));
        assert_eq!(e.run_to_completion().len(), 1);
        assert_eq!(e.metrics.prefix_publications, 2, "second prefix must publish after eviction");
        assert_eq!(e.metrics.shared_prefix_evictions, 1, "first holding must be LRU-evicted");
        assert_eq!(e.metrics.prefix_adoptions, 0);
    }

    #[test]
    fn preempted_adopter_resumes_correctly() {
        // Zero-claiming footprints over-admit two same-prompt sequences
        // whose real growth exceeds the pool; the second adopts the
        // first's published prefix, gets preempted by growth, re-queues,
        // and must still deliver its full output without re-decoding any
        // token — preemption-resume and adopted panels composing.
        let cfg = ModelConfig::tiny_mha(128);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(LyingFootprint(FullAttention::new(shape))) as _);
        let mut e = Engine::new(
            model,
            factory,
            EngineConfig {
                max_batch: 2,
                prefill_chunk: 8,
                page_bytes: 4096,
                pool_budget: 48 * 4096,
                threads: 2,
                prefix_reuse: true,
                eject_preempted: false,
            },
        );
        let prompt: Vec<usize> = (1..=12).collect();
        e.submit(Request::new(0, prompt.clone(), GenParams { max_new_tokens: 8, stop_token: None }));
        // Step until the prefix is published, THEN submit the twin so its
        // admission sees the cache.
        let mut guard = 0;
        while e.metrics.prefix_publications == 0 {
            e.step();
            guard += 1;
            assert!(guard < 50, "prefix never published");
        }
        e.submit(Request::new(1, prompt, GenParams { max_new_tokens: 8, stop_token: None }));
        let mut responses = e.run_to_completion();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2);
        assert!(responses.iter().all(|r| r.tokens.len() == 8));
        assert!(e.metrics.prefix_adoptions >= 1, "twin request must adopt the published prefix");
        assert!(e.metrics.preemptions >= 1, "growth must force preemption in this scenario");
        assert_eq!(responses[0].preemptions, 0, "oldest sequence must not be preempted");
        assert!(responses[1].preemptions >= 1);
        let delivered: usize = responses.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(
            e.metrics.tokens_decoded, delivered,
            "resumed adopter must not re-decode already-emitted tokens"
        );
    }

    #[test]
    fn sals_admits_more_concurrent_sequences_than_full() {
        // Capacity parity under ONE pool budget (the serving-side analogue
        // of the paper's compression claim): per token per layer, full
        // costs 2·kv_dim·4 = 256 B while SALS costs rank·4 + quantized
        // value rate = 80 B (tiny_gqa: kv_dim 32, rank 8, 4-bit values,
        // group 8). At horizon 28 (prompt 24 + max_new 4) and 1 KiB pages
        // that prices full at 42 pages/seq and SALS at 22, so an 88-page
        // pool concurrently admits 2 full sequences but 4 SALS ones.
        use crate::attention::{SalsAttention, SalsConfig};
        use crate::lowrank::Calibrator;
        use crate::quant::Bits;
        use crate::util::rng::Rng;

        let cfg = ModelConfig::tiny_gqa(128);
        let shape = cfg.attn_shape();
        let kvd = cfg.kv_dim();
        let mut crng = Rng::new(67);
        let mut cal = Calibrator::new(kvd);
        for _ in 0..4 * kvd {
            cal.add_key(&crng.normal_vec(kvd, 1.0));
        }
        let proj = cal.fit(kvd / 4).unwrap();
        let sc = SalsConfig {
            rank: kvd / 4,
            r_star: kvd / 8,
            sink: 2,
            recent: 4,
            critical: 8,
            v_bits: Bits::B4,
            group: 8,
            prefill: None,
        };
        let sals_factory: Box<BackendFactory> = Box::new(move |_| {
            Box::new(SalsAttention::new(shape, sc.clone(), proj.clone()))
                as Box<dyn crate::attention::AttentionBackend + Send>
        });
        let full_factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);

        let run = |factory: Box<BackendFactory>| {
            let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 71)));
            let mut e = Engine::new(
                model,
                factory,
                EngineConfig {
                    max_batch: 4,
                    prefill_chunk: 8,
                    page_bytes: 1024,
                    pool_budget: 88 * 1024,
                    threads: 2,
                    prefix_reuse: false,
                    eject_preempted: false,
                },
            );
            let mut rng = Rng::new(73);
            for i in 0..6u64 {
                let prompt: Vec<usize> = (0..24).map(|_| rng.below(cfg.vocab)).collect();
                e.submit(Request::new(i, prompt, GenParams { max_new_tokens: 4, stop_token: None }));
            }
            let responses = e.run_to_completion();
            assert_eq!(responses.len(), 6);
            assert_eq!(e.metrics.preemptions, 0, "honest footprints must not churn");
            e.metrics
        };
        let full = run(full_factory);
        let sals = run(sals_factory);
        assert!(
            sals.peak_running > full.peak_running,
            "SALS must admit strictly more concurrent sequences: {} vs {}",
            sals.peak_running,
            full.peak_running
        );
        assert_eq!(full.peak_running, 2);
        assert_eq!(sals.peak_running, 4);
    }
}

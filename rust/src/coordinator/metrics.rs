//! Serving metrics registry: counters + latency samples, JSON-exportable.

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Engine-level metrics collected during a run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_submitted: usize,
    pub requests_completed: usize,
    pub tokens_prefilled: usize,
    pub tokens_generated: usize,
    /// Next-token samples actually computed in decode phases. Equals
    /// `tokens_generated` when no decode work is ever discarded — with
    /// preemption-*resume* (emitted tokens carried across the re-queue)
    /// the two stay equal even under preemption; a gap means re-decoded
    /// tokens, i.e. wasted decode work.
    pub tokens_decoded: usize,
    pub preemptions: usize,
    pub steps: usize,
    /// Prompt tokens never prefilled because a published shared prefix
    /// was adopted instead (the prefix-reuse win, in tokens).
    pub prefill_tokens_avoided: usize,
    /// Prefix snapshots published into the shared ledger + index.
    pub prefix_publications: usize,
    /// Admissions that adopted a published prefix.
    pub prefix_adoptions: usize,
    /// Unreferenced shared-prefix holdings evicted under pool pressure.
    pub shared_prefix_evictions: usize,
    /// Per-request time-to-first-token (s).
    pub ttft: Vec<f64>,
    /// Per-request end-to-end latency (s).
    pub e2e: Vec<f64>,
    /// Wall-clock of the whole run (s).
    pub wall_s: f64,
    /// Peak pool utilization (pages).
    pub peak_pool_pages: usize,
    /// Peak concurrent running-set size — the serving-capacity number the
    /// footprint-aware admission is meant to raise for compressed backends.
    pub peak_running: usize,
}

impl Metrics {
    /// Fold another replica's metrics into this one (cluster aggregation).
    /// Counters and token tallies sum; the latency sample vectors
    /// concatenate (cluster percentiles are over the union of requests);
    /// `wall_s` takes the max (replicas run concurrently, so summing walls
    /// would double-count time); `peak_pool_pages` sums (each replica owns
    /// a distinct pool, so the total is real pages); `peak_running` sums
    /// (an upper bound on cluster-wide concurrency — per-replica peaks
    /// need not be simultaneous, which is why it is a bound, not a peak).
    pub fn absorb(&mut self, other: &Metrics) {
        self.requests_submitted += other.requests_submitted;
        self.requests_completed += other.requests_completed;
        self.tokens_prefilled += other.tokens_prefilled;
        self.tokens_generated += other.tokens_generated;
        self.tokens_decoded += other.tokens_decoded;
        self.preemptions += other.preemptions;
        self.steps += other.steps;
        self.prefill_tokens_avoided += other.prefill_tokens_avoided;
        self.prefix_publications += other.prefix_publications;
        self.prefix_adoptions += other.prefix_adoptions;
        self.shared_prefix_evictions += other.shared_prefix_evictions;
        self.ttft.extend_from_slice(&other.ttft);
        self.e2e.extend_from_slice(&other.e2e);
        self.wall_s = self.wall_s.max(other.wall_s);
        self.peak_pool_pages += other.peak_pool_pages;
        self.peak_running += other.peak_running;
    }

    /// Decode throughput over the run (generated tokens / wall time).
    pub fn tokens_per_second(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_s
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttft)
    }

    pub fn e2e_summary(&self) -> Summary {
        Summary::of(&self.e2e)
    }

    /// Export as JSON for EXPERIMENTS.md records.
    pub fn to_json(&self) -> Json {
        let t = self.ttft_summary();
        let e = self.e2e_summary();
        Json::obj()
            .field("requests_completed", self.requests_completed)
            .field("tokens_generated", self.tokens_generated)
            .field("tokens_decoded", self.tokens_decoded)
            .field("preemptions", self.preemptions)
            .field("steps", self.steps)
            .field("prefill_tokens_avoided", self.prefill_tokens_avoided)
            .field("prefix_publications", self.prefix_publications)
            .field("prefix_adoptions", self.prefix_adoptions)
            .field("shared_prefix_evictions", self.shared_prefix_evictions)
            .field("wall_s", self.wall_s)
            .field("tokens_per_second", self.tokens_per_second())
            .field("ttft_p50_s", t.p50)
            .field("ttft_p99_s", t.p99)
            .field("e2e_p50_s", e.p50)
            .field("e2e_p99_s", e.p99)
            .field("peak_pool_pages", self.peak_pool_pages)
            .field("peak_running", self.peak_running)
    }
}

/// One request's projected-vs-actual byte record: what the coordinator
/// routed by (the [`crate::model::SequenceFootprint`] at the decode
/// horizon) against the peak live cache the request actually reached.
/// The ratio is the estimator's *drift* — persistently low actuals mean
/// footprints over-reserve (capacity left on the table), high actuals
/// mean under-reservation (preemption churn risk).
#[derive(Clone, Debug)]
pub struct DriftRecord {
    pub id: crate::kvcache::SeqId,
    /// Footprint bytes at the decode horizon, as priced at dispatch.
    pub projected_bytes: usize,
    /// Peak live `kv_bytes()` across every run of the request.
    pub actual_bytes: usize,
}

impl DriftRecord {
    /// actual / projected (1.0 = perfect estimate; 0 projected ⇒ ∞-like
    /// drift reported as the actual byte count to stay finite-ish in
    /// summaries — only a deliberately lying footprint projects 0).
    pub fn ratio(&self) -> f64 {
        if self.projected_bytes == 0 {
            self.actual_bytes as f64
        } else {
            self.actual_bytes as f64 / self.projected_bytes as f64
        }
    }
}

/// Cluster-level view: per-replica [`Metrics`] snapshots plus the
/// coordinator's own counters (routing, re-routing, prefix placement)
/// and the per-request drift ledger.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    /// Snapshot of each replica engine's metrics (index = replica id).
    pub per_replica: Vec<Metrics>,
    /// Requests the coordinator dispatched to a replica.
    pub dispatched: usize,
    /// Preempted requests the coordinator re-routed by current load
    /// (each one drained the origin replica's ledger via
    /// [`super::Router::note_preemption`]).
    pub preemption_reroutes: usize,
    /// Dispatches placed by a prefix-index hit (the chosen replica had
    /// published the request's longest matching prefix).
    pub prefix_hint_hits: usize,
    /// Dispatches that bypassed an older queued request because that
    /// request fit no replica yet (horizon bin-packing, not strict FCFS).
    pub fcfs_bypasses: usize,
    /// Duplicate-id submissions rejected at cluster admission.
    pub duplicates_rejected: usize,
    /// Per-request projected-vs-actual bytes, in completion order.
    pub drift: Vec<DriftRecord>,
}

impl ClusterMetrics {
    /// Sum of the per-replica metrics (see [`Metrics::absorb`] for the
    /// per-field semantics). The conservation invariant the cluster tests
    /// pin: aggregate counters equal the per-replica sums, and
    /// `requests_completed` equals the requests submitted to the cluster.
    pub fn aggregate(&self) -> Metrics {
        let mut m = Metrics::default();
        for r in &self.per_replica {
            m.absorb(r);
        }
        m
    }

    /// Mean drift ratio (actual/projected) over completed requests;
    /// 1.0 when no records exist.
    pub fn mean_drift(&self) -> f64 {
        if self.drift.is_empty() {
            return 1.0;
        }
        self.drift.iter().map(|d| d.ratio()).sum::<f64>() / self.drift.len() as f64
    }

    /// Worst over-estimate and under-estimate ratios `(min, max)`.
    pub fn drift_bounds(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for d in &self.drift {
            let r = d.ratio();
            lo = lo.min(r);
            hi = hi.max(r);
        }
        if self.drift.is_empty() {
            (1.0, 1.0)
        } else {
            (lo, hi)
        }
    }

    /// Export the cluster view (aggregate + coordinator counters + drift
    /// summary) for BENCH_cluster.json / EXPERIMENTS.md records.
    pub fn to_json(&self) -> Json {
        let (drift_min, drift_max) = self.drift_bounds();
        Json::obj()
            .field("replicas", self.per_replica.len())
            .field("dispatched", self.dispatched)
            .field("preemption_reroutes", self.preemption_reroutes)
            .field("prefix_hint_hits", self.prefix_hint_hits)
            .field("fcfs_bypasses", self.fcfs_bypasses)
            .field("duplicates_rejected", self.duplicates_rejected)
            .field("drift_mean", self.mean_drift())
            .field("drift_min", drift_min)
            .field("drift_max", drift_max)
            .field("aggregate", self.aggregate().to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_computation() {
        let m = Metrics { tokens_generated: 100, wall_s: 4.0, ..Default::default() };
        assert!((m.tokens_per_second() - 25.0).abs() < 1e-12);
        assert_eq!(Metrics::default().tokens_per_second(), 0.0);
    }

    #[test]
    fn json_has_fields() {
        let m = Metrics { ttft: vec![0.1, 0.2], e2e: vec![0.5], ..Default::default() };
        let s = m.to_json().to_string();
        assert!(s.contains("\"ttft_p50_s\""));
        assert!(s.contains("\"tokens_per_second\""));
    }

    #[test]
    fn absorb_sums_counters_and_concatenates_samples() {
        let a = Metrics {
            requests_completed: 3,
            tokens_generated: 30,
            preemptions: 1,
            ttft: vec![0.1],
            wall_s: 2.0,
            peak_pool_pages: 10,
            peak_running: 2,
            ..Default::default()
        };
        let b = Metrics {
            requests_completed: 2,
            tokens_generated: 20,
            ttft: vec![0.2, 0.3],
            wall_s: 3.0,
            peak_pool_pages: 5,
            peak_running: 1,
            ..Default::default()
        };
        let mut sum = a.clone();
        sum.absorb(&b);
        assert_eq!(sum.requests_completed, 5);
        assert_eq!(sum.tokens_generated, 50);
        assert_eq!(sum.preemptions, 1);
        assert_eq!(sum.ttft.len(), 3);
        assert_eq!(sum.wall_s, 3.0, "concurrent replicas: wall is the max");
        assert_eq!(sum.peak_pool_pages, 15, "distinct pools: pages sum");
        assert_eq!(sum.peak_running, 3);
        let cm = ClusterMetrics { per_replica: vec![a, b], ..Default::default() };
        assert_eq!(cm.aggregate().requests_completed, 5);
    }

    #[test]
    fn drift_records_summarize() {
        let cm = ClusterMetrics {
            drift: vec![
                DriftRecord { id: 0, projected_bytes: 100, actual_bytes: 50 },
                DriftRecord { id: 1, projected_bytes: 100, actual_bytes: 150 },
            ],
            ..Default::default()
        };
        assert!((cm.mean_drift() - 1.0).abs() < 1e-12);
        assert_eq!(cm.drift_bounds(), (0.5, 1.5));
        assert_eq!(ClusterMetrics::default().mean_drift(), 1.0);
        let s = cm.to_json().to_string();
        assert!(s.contains("\"drift_mean\"") && s.contains("\"aggregate\""));
    }
}

//! Serving metrics registry: counters + latency samples, JSON-exportable.

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Engine-level metrics collected during a run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_submitted: usize,
    pub requests_completed: usize,
    pub tokens_prefilled: usize,
    pub tokens_generated: usize,
    /// Next-token samples actually computed in decode phases. Equals
    /// `tokens_generated` when no decode work is ever discarded — with
    /// preemption-*resume* (emitted tokens carried across the re-queue)
    /// the two stay equal even under preemption; a gap means re-decoded
    /// tokens, i.e. wasted decode work.
    pub tokens_decoded: usize,
    pub preemptions: usize,
    pub steps: usize,
    /// Prompt tokens never prefilled because a published shared prefix
    /// was adopted instead (the prefix-reuse win, in tokens).
    pub prefill_tokens_avoided: usize,
    /// Prefix snapshots published into the shared ledger + index.
    pub prefix_publications: usize,
    /// Admissions that adopted a published prefix.
    pub prefix_adoptions: usize,
    /// Unreferenced shared-prefix holdings evicted under pool pressure.
    pub shared_prefix_evictions: usize,
    /// Per-request time-to-first-token (s).
    pub ttft: Vec<f64>,
    /// Per-request end-to-end latency (s).
    pub e2e: Vec<f64>,
    /// Wall-clock of the whole run (s).
    pub wall_s: f64,
    /// Peak pool utilization (pages).
    pub peak_pool_pages: usize,
    /// Peak concurrent running-set size — the serving-capacity number the
    /// footprint-aware admission is meant to raise for compressed backends.
    pub peak_running: usize,
}

impl Metrics {
    /// Decode throughput over the run (generated tokens / wall time).
    pub fn tokens_per_second(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_s
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttft)
    }

    pub fn e2e_summary(&self) -> Summary {
        Summary::of(&self.e2e)
    }

    /// Export as JSON for EXPERIMENTS.md records.
    pub fn to_json(&self) -> Json {
        let t = self.ttft_summary();
        let e = self.e2e_summary();
        Json::obj()
            .field("requests_completed", self.requests_completed)
            .field("tokens_generated", self.tokens_generated)
            .field("tokens_decoded", self.tokens_decoded)
            .field("preemptions", self.preemptions)
            .field("steps", self.steps)
            .field("prefill_tokens_avoided", self.prefill_tokens_avoided)
            .field("prefix_publications", self.prefix_publications)
            .field("prefix_adoptions", self.prefix_adoptions)
            .field("shared_prefix_evictions", self.shared_prefix_evictions)
            .field("wall_s", self.wall_s)
            .field("tokens_per_second", self.tokens_per_second())
            .field("ttft_p50_s", t.p50)
            .field("ttft_p99_s", t.p99)
            .field("e2e_p50_s", e.p50)
            .field("e2e_p99_s", e.p99)
            .field("peak_pool_pages", self.peak_pool_pages)
            .field("peak_running", self.peak_running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_computation() {
        let m = Metrics { tokens_generated: 100, wall_s: 4.0, ..Default::default() };
        assert!((m.tokens_per_second() - 25.0).abs() < 1e-12);
        assert_eq!(Metrics::default().tokens_per_second(), 0.0);
    }

    #[test]
    fn json_has_fields() {
        let m = Metrics { ttft: vec![0.1, 0.2], e2e: vec![0.5], ..Default::default() };
        let s = m.to_json().to_string();
        assert!(s.contains("\"ttft_p50_s\""));
        assert!(s.contains("\"tokens_per_second\""));
    }
}

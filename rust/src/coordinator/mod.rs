//! L3 serving coordinator: request types, router, continuous-batching
//! engine, and metrics. This layer owns the event loop, the page-pool
//! admission control, and the scheduling policy; the compute is delegated
//! to the model's attention backends (CPU) or the PJRT runtime (artifacts).

pub mod cluster;
pub mod engine;
pub mod metrics;
pub mod replica;
pub mod request;
pub mod router;
pub mod trace;

pub use cluster::{ClusterConfig, Coordinator};
pub use engine::{Engine, EngineConfig, PrefixEvent};
pub use metrics::{ClusterMetrics, DriftRecord, Metrics};
pub use replica::{Command, Event};
pub use request::{GenParams, Request, Response};
pub use router::{Policy, ReplicaId, Router};
pub use trace::{TraceGen, TraceSpec};

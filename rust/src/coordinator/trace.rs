//! Synthetic request-trace generator (Poisson arrivals, mixed lengths) —
//! feeds the serving benches and the end-to-end example.

use super::request::{GenParams, Request};
use crate::util::rng::Rng;

/// Trace parameters.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub n_requests: usize,
    /// Mean arrival rate (requests/second) for Poisson arrivals.
    pub rate: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub new_tokens_min: usize,
    pub new_tokens_max: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> TraceSpec {
        TraceSpec {
            n_requests: 32,
            rate: 16.0,
            prompt_min: 16,
            prompt_max: 128,
            new_tokens_min: 8,
            new_tokens_max: 64,
            vocab: 512,
            seed: 42,
        }
    }
}

/// A generated trace entry: the request plus its arrival offset (seconds
/// from trace start).
#[derive(Clone, Debug)]
pub struct TimedRequest {
    pub at_s: f64,
    pub request: Request,
}

/// Deterministic trace generator.
pub struct TraceGen;

impl TraceGen {
    pub fn generate(spec: &TraceSpec) -> Vec<TimedRequest> {
        assert!(spec.prompt_min >= 1 && spec.prompt_max >= spec.prompt_min);
        assert!(spec.new_tokens_max >= spec.new_tokens_min && spec.new_tokens_min >= 1);
        let mut rng = Rng::new(spec.seed);
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(spec.n_requests);
        for id in 0..spec.n_requests {
            t += rng.exponential(spec.rate);
            let plen = rng.range(spec.prompt_min, spec.prompt_max + 1);
            let prompt: Vec<usize> = (0..plen).map(|_| rng.below(spec.vocab)).collect();
            let n_new = rng.range(spec.new_tokens_min, spec.new_tokens_max + 1);
            out.push(TimedRequest {
                at_s: t,
                request: Request::new(id as u64, prompt, GenParams { max_new_tokens: n_new, stop_token: None }),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_monotone() {
        let spec = TraceSpec::default();
        let a = TraceGen::generate(&spec);
        let b = TraceGen::generate(&spec);
        assert_eq!(a.len(), spec.n_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.request.prompt, y.request.prompt);
        }
        for w in a.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn respects_bounds() {
        let spec = TraceSpec { prompt_min: 4, prompt_max: 6, new_tokens_min: 2, new_tokens_max: 3, ..Default::default() };
        for tr in TraceGen::generate(&spec) {
            assert!((4..=6).contains(&tr.request.prompt.len()));
            assert!((2..=3).contains(&tr.request.params.max_new_tokens));
            assert!(tr.request.prompt.iter().all(|&t| t < spec.vocab));
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let spec = TraceSpec { n_requests: 2000, rate: 10.0, ..Default::default() };
        let tr = TraceGen::generate(&spec);
        let span = tr.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }
}

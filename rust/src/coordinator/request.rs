//! Request/response types for the serving coordinator.

use crate::kvcache::SeqId;
use std::time::Instant;

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// Stop early when this token is produced (optional).
    pub stop_token: Option<usize>,
}

impl Default for GenParams {
    fn default() -> GenParams {
        GenParams { max_new_tokens: 32, stop_token: None }
    }
}

/// An inference request entering the router.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: SeqId,
    pub prompt: Vec<usize>,
    pub params: GenParams,
    /// Arrival timestamp assigned at submit time (None until submitted).
    pub arrival: Option<Instant>,
    /// Times this request has been preempted so far. Lives on the request
    /// (not the engine's running slot) so the count survives re-queue and
    /// re-admission and the final [`Response`] reports it faithfully.
    pub preemptions: usize,
    /// Tokens already generated before a preemption (empty for fresh
    /// requests). vLLM-style recompute **resume**: on re-admission the
    /// engine prefills `prompt ++ generated` and decoding continues after
    /// the last emitted token — prefill work is redone (the caches were
    /// dropped), but no already-emitted token is ever re-decoded and the
    /// `max_new_tokens` budget keeps counting from where it left off.
    pub generated: Vec<usize>,
    /// First time this request was ever scheduled (carried across
    /// preemption so `Response::queue_s` reports the original queueing
    /// delay, not the re-admission's).
    pub first_step: Option<Instant>,
    /// When this request's first token was actually emitted (carried
    /// across preemption — the resumed run never re-emits it, so
    /// forgetting this would inflate `Response::ttft_s` to the first
    /// post-resume token).
    pub first_token: Option<Instant>,
    /// Conversation this request belongs to, if any. The cluster
    /// coordinator pins a session's turns to one replica (warm prefix
    /// cache) and re-pins on preemption re-route; `None` requests are
    /// placed purely by prefix-index hits and projected load.
    pub session: Option<SeqId>,
    /// Largest live `kv_bytes()` this request's sequence ever reached,
    /// carried across preemption (caches are dropped on re-queue, so the
    /// engine alone cannot remember the first run's peak). The completed
    /// [`Response`] reports it as the *actual* side of the cluster's
    /// projected-vs-actual estimator-drift ledger.
    pub peak_kv_bytes: usize,
}

impl Request {
    pub fn new(id: SeqId, prompt: Vec<usize>, params: GenParams) -> Request {
        Request {
            id,
            prompt,
            params,
            arrival: None,
            preemptions: 0,
            generated: Vec::new(),
            first_step: None,
            first_token: None,
            session: None,
            peak_kv_bytes: 0,
        }
    }

    /// Tag the request with a conversation id (see [`Request::session`]).
    pub fn with_session(mut self, session: SeqId) -> Request {
        self.session = Some(session);
        self
    }
}

/// Completed request with timing breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: SeqId,
    pub tokens: Vec<usize>,
    pub prompt_len: usize,
    /// Queueing delay: submit -> first scheduled step (seconds).
    pub queue_s: f64,
    /// Time to first token: submit -> first generated token (seconds).
    pub ttft_s: f64,
    /// Total latency: submit -> finish (seconds).
    pub e2e_s: f64,
    /// Times this sequence was preempted and re-queued.
    pub preemptions: usize,
    /// Peak live cache bytes across every run of this request (resumes
    /// included) — the measured side the cluster compares against the
    /// footprint projection it routed by.
    pub peak_kv_bytes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let p = GenParams::default();
        assert!(p.max_new_tokens > 0);
        assert!(p.stop_token.is_none());
        let r = Request::new(1, vec![1, 2], p);
        assert!(r.arrival.is_none());
    }
}

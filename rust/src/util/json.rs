//! Minimal JSON writer for metrics / experiment dumps.
//!
//! The offline crate cache has no `serde` facade, so we emit JSON by hand.
//! Only writing is needed (experiment records, metrics snapshots); nothing
//! in the system parses JSON back.

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object builder entry point.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert a field (builder style). Panics if `self` is not an object.
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::field on non-object"),
        }
        self
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj()
            .field("name", "sals")
            .field("n", 3usize)
            .field("ok", true)
            .field("xs", vec![1.0f64, 2.5]);
        assert_eq!(j.to_string(), r#"{"name":"sals","n":3,"ok":true,"xs":[1,2.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}

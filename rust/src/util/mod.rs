//! Shared substrates: error type, PRNG, statistics, JSON writer, CLI parser,
//! timing, thread pool, and a mini property-testing harness.

pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;

pub use error::{Error, Result};

//! Fixed-size thread pool with scoped parallel-for (no rayon offline).
//!
//! Used by the coordinator for worker fan-out and by benches for parallel
//! workload generation. `parallel_for` splits an index range into contiguous
//! chunks and runs them on `std::thread::scope` threads;
//! `parallel_for_each_mut` is the `&mut`-item variant the engine's prefill
//! phase uses to fan work out over per-sequence state (each item is owned
//! by exactly one worker thread).

/// Run `f(i)` for every i in 0..n across up to `threads` OS threads.
///
/// `f` must be Sync; each index is processed exactly once. Chunking is
/// contiguous so cache locality of per-index work is preserved.
pub fn parallel_for(n: usize, threads: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Run `f(i, &mut items[i])` for every item across up to `threads` OS
/// threads. Contiguous chunking: each thread owns a disjoint `&mut` slice,
/// so `f` gets exclusive access to its item with no locks. This is the
/// fan-out primitive for per-sequence work over shared read-only weights
/// (cross-sequence batched decode, parallel prefill).
pub fn parallel_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, item) in slice.iter_mut().enumerate() {
                    f(t * chunk + j, item);
                }
            });
        }
    });
}

/// Run `f(chunk_index, chunk)` over contiguous `chunk_size`-sized mutable
/// chunks of `buf` across up to `threads` threads (last chunk may be
/// short; chunk `i` starts at element `i * chunk_size`).
///
/// The decomposition is fixed by `chunk_size`, NOT by the thread count —
/// so callers whose per-element work is independent of the chunking (e.g.
/// the SALS latent score scan, where each score is one dot product) get
/// bit-identical results for every `threads` value.
pub fn parallel_chunks_mut<T: Send>(
    buf: &mut [T],
    chunk_size: usize,
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_size > 0, "parallel_chunks_mut needs a positive chunk size");
    if buf.is_empty() {
        return;
    }
    let n_chunks = buf.len().div_ceil(chunk_size);
    let workers = threads.max(1).min(n_chunks);
    if workers <= 1 {
        for (i, chunk) in buf.chunks_mut(chunk_size).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // Each worker owns a contiguous run of whole chunks (only the last
    // run may end with the short tail chunk), carved straight off the
    // slice — no intermediate collection is allocated (this runs per
    // (layer, token) on the decode hot path). Chunk indices and
    // boundaries are identical to the serial decomposition.
    let per_worker = n_chunks.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rem: &mut [T] = buf;
        let mut base = 0usize;
        while !rem.is_empty() {
            let take = (per_worker * chunk_size).min(rem.len());
            let (head, rest) = std::mem::take(&mut rem).split_at_mut(take);
            rem = rest;
            let f = &f;
            let start = base;
            base += head.len().div_ceil(chunk_size);
            s.spawn(move || {
                for (k, chunk) in head.chunks_mut(chunk_size).enumerate() {
                    f(start + k, chunk);
                }
            });
        }
    });
}

/// Partition `n_units` contiguous units of `out` (each `unit_width`
/// elements; `out.len() == n_units * unit_width`) across one worker per
/// lane of `lanes`: worker `w` owns lane `w`, a contiguous unit range,
/// and the matching `out` slice, calling `f(unit_index, lane, unit_out)`
/// serially within its range. The shared carving scaffold of the
/// per-KV-head attention fan-outs (`sparse_attend_threaded`,
/// `fused_sparse_attend`) — one lane per worker, slices carved straight
/// off `out`, no per-call collection allocated (this runs per
/// (layer, token) on the decode hot path). A single lane runs inline
/// with no thread spawn. Bit-invariance contract: `f`'s per-unit
/// arithmetic must not depend on the partition, so worker count cannot
/// change results.
pub fn parallel_units_mut<L: Send, T: Send>(
    lanes: &mut [L],
    out: &mut [T],
    unit_width: usize,
    n_units: usize,
    f: impl Fn(usize, &mut L, &mut [T]) + Sync,
) {
    assert!(!lanes.is_empty(), "parallel_units_mut needs at least one lane");
    assert!(unit_width > 0);
    assert_eq!(out.len(), n_units * unit_width);
    let workers = lanes.len().min(n_units.max(1));
    if workers <= 1 {
        let lane = &mut lanes[0];
        for (u, unit_out) in out.chunks_mut(unit_width).enumerate() {
            f(u, lane, unit_out);
        }
        return;
    }
    let chunk = n_units.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rem: &mut [T] = out;
        for (w, lane) in lanes.iter_mut().enumerate() {
            let lo = w * chunk;
            if lo >= n_units {
                break;
            }
            let hi = (lo + chunk).min(n_units);
            let (head, rest) = std::mem::take(&mut rem).split_at_mut((hi - lo) * unit_width);
            rem = rest;
            let f = &f;
            s.spawn(move || {
                for (i, unit_out) in head.chunks_mut(unit_width).enumerate() {
                    f(lo + i, lane, unit_out);
                }
            });
        }
    });
}

/// Map `f` over 0..n in parallel, collecting results in index order.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    // Each scope thread owns a disjoint &mut [Option<T>] chunk — no locks.
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
    } else {
        let chunk = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, slice) in out.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, slot) in slice.iter_mut().enumerate() {
                        *slot = Some(f(t * chunk + j));
                    }
                });
            }
        });
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Number of available CPUs (fallback 4).
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_for_each_mut_visits_each_item_once_with_its_index() {
        let mut items: Vec<usize> = vec![0; 357];
        parallel_for_each_mut(&mut items, 8, |i, item| {
            *item += i + 1; // +1 distinguishes "visited index 0" from "missed"
        });
        assert_eq!(items, (0..357).map(|i| i + 1).collect::<Vec<_>>());
        // Degenerate sizes.
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_each_mut(&mut empty, 4, |_, _| panic!("should not run"));
        let mut one = vec![7usize];
        parallel_for_each_mut(&mut one, 16, |i, item| *item += i);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn parallel_chunks_mut_fixed_decomposition() {
        // 357 elements in 16-sized chunks: every element visited once, the
        // chunk index maps to the right offset, any thread count.
        for threads in [1usize, 3, 8] {
            let mut items: Vec<usize> = vec![0; 357];
            parallel_chunks_mut(&mut items, 16, threads, |ci, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = ci * 16 + j + 1;
                }
            });
            assert_eq!(items, (0..357).map(|i| i + 1).collect::<Vec<_>>(), "threads={threads}");
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_chunks_mut(&mut empty, 4, 4, |_, _| panic!("should not run"));
    }

    #[test]
    fn parallel_units_mut_partitions_units_and_lanes() {
        // 7 units of width 3 over {1, 2, 3, 8} lanes: every unit visited
        // once with the right offset, and each unit touched by the lane
        // that owns its contiguous range.
        for n_lanes in [1usize, 2, 3, 8] {
            let mut lanes: Vec<usize> = vec![0; n_lanes];
            let mut out: Vec<usize> = vec![0; 7 * 3];
            parallel_units_mut(&mut lanes, &mut out, 3, 7, |u, lane, unit| {
                *lane += 1; // worker-serial: no lock needed
                for (k, x) in unit.iter_mut().enumerate() {
                    *x = u * 3 + k + 1;
                }
            });
            assert_eq!(out, (0..21).map(|i| i + 1).collect::<Vec<_>>(), "{n_lanes} lanes");
            assert_eq!(lanes.iter().sum::<usize>(), 7, "every unit ran exactly once");
        }
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 7, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let out = parallel_map(1, 16, |i| i + 1);
        assert_eq!(out, vec![1]);
    }
}

//! Worker-pool substrate (no rayon offline): persistent pinned pool +
//! scoped spawn fallback.
//!
//! Two tiers live here:
//!
//! * **Free functions** (`parallel_for`, `parallel_for_each_mut`,
//!   `parallel_chunks_mut`, `parallel_units_mut`, `parallel_map`) fan out
//!   over fresh `std::thread::scope` threads per call (~10µs/spawn). They
//!   remain the reference decomposition and the right tool for coarse,
//!   infrequent fan-outs (bench workload generation).
//! * **`WorkerPool` / `Workers`** is the decode-hot-path tier: N long-lived
//!   OS threads created once per `Engine` (or once per bench), with
//!   per-call task handoff through a per-lane closure slot + atomic epoch
//!   (spin-then-park). Dispatch is allocation-free and sub-microsecond when
//!   the pool is hot, which is what lets the attention kernels' work-size
//!   guards sit an order of magnitude lower than the spawn tier allowed.
//!
//! The `Workers` handle mirrors the free functions' decompositions
//! *exactly* (same chunk boundaries, same index order), so outputs are
//! bit-identical between the pooled, scoped, and serial execution modes —
//! thread count and execution tier are scheduling knobs only.

use std::any::Any;
use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Run `f(i)` for every i in 0..n across up to `threads` OS threads.
///
/// `f` must be Sync; each index is processed exactly once. Chunking is
/// contiguous so cache locality of per-index work is preserved.
pub fn parallel_for(n: usize, threads: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Run `f(i, &mut items[i])` for every item across up to `threads` OS
/// threads. Contiguous chunking: each thread owns a disjoint `&mut` slice,
/// so `f` gets exclusive access to its item with no locks. This is the
/// fan-out primitive for per-sequence work over shared read-only weights
/// (cross-sequence batched decode, parallel prefill).
pub fn parallel_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, item) in slice.iter_mut().enumerate() {
                    f(t * chunk + j, item);
                }
            });
        }
    });
}

/// Run `f(chunk_index, chunk)` over contiguous `chunk_size`-sized mutable
/// chunks of `buf` across up to `threads` threads (last chunk may be
/// short; chunk `i` starts at element `i * chunk_size`).
///
/// The decomposition is fixed by `chunk_size`, NOT by the thread count —
/// so callers whose per-element work is independent of the chunking (e.g.
/// the SALS latent score scan, where each score is one dot product) get
/// bit-identical results for every `threads` value.
pub fn parallel_chunks_mut<T: Send>(
    buf: &mut [T],
    chunk_size: usize,
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_size > 0, "parallel_chunks_mut needs a positive chunk size");
    if buf.is_empty() {
        return;
    }
    let n_chunks = buf.len().div_ceil(chunk_size);
    let workers = threads.max(1).min(n_chunks);
    if workers <= 1 {
        for (i, chunk) in buf.chunks_mut(chunk_size).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // Each worker owns a contiguous run of whole chunks (only the last
    // run may end with the short tail chunk), carved straight off the
    // slice — no intermediate collection is allocated (this runs per
    // (layer, token) on the decode hot path). Chunk indices and
    // boundaries are identical to the serial decomposition.
    let per_worker = n_chunks.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rem: &mut [T] = buf;
        let mut base = 0usize;
        while !rem.is_empty() {
            let take = (per_worker * chunk_size).min(rem.len());
            let (head, rest) = std::mem::take(&mut rem).split_at_mut(take);
            rem = rest;
            let f = &f;
            let start = base;
            base += head.len().div_ceil(chunk_size);
            s.spawn(move || {
                for (k, chunk) in head.chunks_mut(chunk_size).enumerate() {
                    f(start + k, chunk);
                }
            });
        }
    });
}

/// Partition `n_units` contiguous units of `out` (each `unit_width`
/// elements; `out.len() == n_units * unit_width`) across one worker per
/// lane of `lanes`: worker `w` owns lane `w`, a contiguous unit range,
/// and the matching `out` slice, calling `f(unit_index, lane, unit_out)`
/// serially within its range. The shared carving scaffold of the
/// per-KV-head attention fan-outs (`sparse_attend_threaded`,
/// `fused_sparse_attend`) — one lane per worker, slices carved straight
/// off `out`, no per-call collection allocated (this runs per
/// (layer, token) on the decode hot path). A single lane runs inline
/// with no thread spawn. Bit-invariance contract: `f`'s per-unit
/// arithmetic must not depend on the partition, so worker count cannot
/// change results.
pub fn parallel_units_mut<L: Send, T: Send>(
    lanes: &mut [L],
    out: &mut [T],
    unit_width: usize,
    n_units: usize,
    f: impl Fn(usize, &mut L, &mut [T]) + Sync,
) {
    assert!(!lanes.is_empty(), "parallel_units_mut needs at least one lane");
    assert!(unit_width > 0);
    assert_eq!(out.len(), n_units * unit_width);
    let workers = lanes.len().min(n_units.max(1));
    if workers <= 1 {
        let lane = &mut lanes[0];
        for (u, unit_out) in out.chunks_mut(unit_width).enumerate() {
            f(u, lane, unit_out);
        }
        return;
    }
    let chunk = n_units.div_ceil(workers);
    std::thread::scope(|s| {
        let mut rem: &mut [T] = out;
        for (w, lane) in lanes.iter_mut().enumerate() {
            let lo = w * chunk;
            if lo >= n_units {
                break;
            }
            let hi = (lo + chunk).min(n_units);
            let (head, rest) = std::mem::take(&mut rem).split_at_mut((hi - lo) * unit_width);
            rem = rest;
            let f = &f;
            s.spawn(move || {
                for (i, unit_out) in head.chunks_mut(unit_width).enumerate() {
                    f(lo + i, lane, unit_out);
                }
            });
        }
    });
}

/// Map `f` over 0..n in parallel, collecting results in index order.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    // Each scope thread owns a disjoint &mut [Option<T>] chunk — no locks.
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
    } else {
        let chunk = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, slice) in out.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, slot) in slice.iter_mut().enumerate() {
                        *slot = Some(f(t * chunk + j));
                    }
                });
            }
        });
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Number of available CPUs (fallback 4).
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// `SALS_THREADS` override, parsed once per process (like `SALS_SIMD`).
///
/// When set to a positive integer it forces the worker-pool size for the
/// engine, the benches, and every `resolve_threads` caller — reproducible
/// perf runs and CI bit-invariance shakeouts (`SALS_THREADS=1` vs `=8`).
/// Unset, empty, or unparsable means no override.
pub fn threads_override() -> Option<usize> {
    static OVERRIDE: OnceLock<Option<usize>> = OnceLock::new();
    *OVERRIDE.get_or_init(|| {
        std::env::var("SALS_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
    })
}

/// Resolve a requested worker count against the environment: the
/// `SALS_THREADS` override wins outright; otherwise `requested == 0`
/// means auto (one worker per CPU) and any positive value is taken as
/// given.
pub fn resolve_threads(requested: usize) -> usize {
    if let Some(n) = threads_override() {
        return n;
    }
    if requested == 0 {
        num_cpus()
    } else {
        requested
    }
}

/// Spin iterations a worker burns on an empty mailbox before parking on
/// its condvar. Back-to-back decode dispatches arrive within microseconds
/// of each other, so the hot path never parks; an idle engine (or a pool
/// outliving a burst) falls back to a blocking wait instead of burning a
/// core.
const PARK_AFTER_SPINS: u32 = 1 << 14;

/// Spin iterations the dispatcher burns waiting for lane completion
/// before yielding the CPU between polls. Lane work on the decode hot
/// path is microseconds, so completion waits almost never yield.
const WAIT_YIELD_AFTER_SPINS: u32 = 1 << 16;

/// Type-erased job: a pointer to a live `Fn(usize)` closure plus the
/// monomorphized trampoline that calls it with the lane's worker index.
#[derive(Clone, Copy)]
struct JobSlot {
    data: *const (),
    call: unsafe fn(*const (), usize),
    arg: usize,
}

/// Trampoline instantiated per closure type by `Workers::broadcast`.
///
/// # Safety
/// `data` must point at a live `F` that outlives the call (the
/// dispatching `broadcast` keeps the closure alive until every lane has
/// reported completion).
unsafe fn call_thunk<F: Fn(usize) + Sync>(data: *const (), arg: usize) {
    // SAFETY: per this function's contract, `data` is a valid `&F` for
    // the duration of the call.
    let f = unsafe { &*(data as *const F) };
    f(arg);
}

/// No-op used as the initial slot value before the first dispatch.
///
/// # Safety
/// Always safe to call; never actually invoked (workers only read the
/// slot after observing a job epoch published by a dispatcher, which
/// overwrites the slot first).
unsafe fn noop_thunk(_data: *const (), _arg: usize) {}

/// One worker's dispatch mailbox.
///
/// Protocol: the dispatcher writes `slot`, then publishes `job = n+1`
/// (SeqCst); the worker observes the new epoch (Acquire/SeqCst), runs the
/// job, stores any panic payload, then publishes `done = job` (Release).
/// `job == done` therefore means "idle, slot free"; the single-dispatcher
/// rule (a `Workers` handle's lane range is never broadcast from two
/// threads at once) makes the slot write race-free, and the epoch pair
/// makes completion detection allocation-free.
struct Lane {
    job: AtomicU64,
    done: AtomicU64,
    slot: UnsafeCell<JobSlot>,
    /// Panic payload captured by the worker, taken by the dispatcher
    /// after it observes `done` (never concurrently).
    panic: UnsafeCell<Option<Box<dyn Any + Send>>>,
    /// True while the worker is parked (or about to park) on `condvar`.
    sleeping: AtomicBool,
    mutex: Mutex<()>,
    condvar: Condvar,
}

// SAFETY: the `UnsafeCell` fields are synchronized by the job/done epoch
// protocol documented on `Lane`: the dispatcher only writes `slot` when
// `job == done` (lane idle) and the worker only reads it after observing
// a newer `job`; `panic` is written by the worker before its `done`
// release-store and read by the dispatcher after the matching acquire
// load. Raw pointers inside `JobSlot` are only dereferenced while the
// dispatching closure is provably alive.
unsafe impl Sync for Lane {}

impl Lane {
    fn new() -> Lane {
        Lane {
            job: AtomicU64::new(0),
            done: AtomicU64::new(0),
            slot: UnsafeCell::new(JobSlot { data: std::ptr::null(), call: noop_thunk, arg: 0 }),
            panic: UnsafeCell::new(None),
            sleeping: AtomicBool::new(false),
            mutex: Mutex::new(()),
            condvar: Condvar::new(),
        }
    }
}

struct PoolShared {
    lanes: Vec<Lane>,
    shutdown: AtomicBool,
    /// Total jobs handed to lanes over the pool's lifetime — lets tests
    /// assert that degenerate inputs (empty, single item) stay serial.
    dispatches: AtomicU64,
    /// Worker threads of this pool still running (spawned minus exited).
    live: AtomicUsize,
}

/// Observable live-worker count of one pool that outlives the pool
/// itself: `WorkerPool::drop` joins every worker, so after the pool is
/// gone the probe reads 0 — the no-leaked-threads contract across
/// engine restarts in one process, pinned by tests.
pub struct PoolLiveProbe {
    shared: Arc<PoolShared>,
}

impl PoolLiveProbe {
    /// Worker threads of the probed pool still running.
    pub fn count(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }
}

/// Persistent pinned worker pool: `size - 1` long-lived OS threads (the
/// dispatching thread is always implicit worker 0), one dispatch mailbox
/// per thread. Created once per `Engine` (and once per bench); `Drop`
/// joins every worker, so pools never leak threads across engine
/// restarts in one process.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool sized for `size` total workers (`size - 1` OS
    /// threads; `size <= 1` spawns none and every handle runs inline).
    pub fn new(size: usize) -> WorkerPool {
        let n_lanes = size.max(1) - 1;
        let shared = Arc::new(PoolShared {
            lanes: (0..n_lanes).map(|_| Lane::new()).collect(),
            shutdown: AtomicBool::new(false),
            dispatches: AtomicU64::new(0),
            live: AtomicUsize::new(n_lanes),
        });
        let mut handles = Vec::with_capacity(n_lanes);
        for idx in 0..n_lanes {
            let shared = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sals-pool-{idx}"))
                    .spawn(move || worker_loop(shared, idx))
                    .expect("failed to spawn pool worker"),
            );
        }
        WorkerPool { shared, handles }
    }

    /// Number of pooled OS threads (total workers minus the caller).
    pub fn lanes(&self) -> usize {
        self.shared.lanes.len()
    }

    /// Lifetime dispatch count (jobs handed to pooled lanes).
    pub fn dispatch_count(&self) -> u64 {
        self.shared.dispatches.load(Ordering::Relaxed)
    }

    /// A live-worker probe that can be read after the pool is dropped.
    pub fn live_probe(&self) -> PoolLiveProbe {
        PoolLiveProbe { shared: Arc::clone(&self.shared) }
    }

    /// Hand `(data, call, arg)` to lane `lane_idx`. The lane must be idle
    /// (single-dispatcher rule); the caller must keep `data` alive until
    /// `wait_idle` returns for this lane.
    fn dispatch(
        &self,
        lane_idx: usize,
        data: *const (),
        call: unsafe fn(*const (), usize),
        arg: usize,
    ) {
        let lane = &self.shared.lanes[lane_idx];
        let prev = lane.job.load(Ordering::Relaxed);
        assert_eq!(
            lane.done.load(Ordering::Acquire),
            prev,
            "worker-pool lane dispatched while busy (overlapping broadcasts on one lane range)"
        );
        // SAFETY: the lane is idle (assert above), so the worker is not
        // reading the slot, and only this thread may dispatch to it
        // (single-dispatcher rule) — the write cannot race.
        unsafe {
            *lane.slot.get() = JobSlot { data, call, arg };
        }
        // SeqCst on both the epoch publish and the `sleeping` check so
        // the classic lost-wakeup interleaving is impossible: either the
        // worker's final epoch re-check (under the mutex) sees the new
        // job, or our `sleeping` load sees true and we notify under the
        // same mutex.
        lane.job.store(prev + 1, Ordering::SeqCst);
        if lane.sleeping.load(Ordering::SeqCst) {
            let _guard = lane.mutex.lock().unwrap();
            lane.condvar.notify_one();
        }
        self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
    }

    /// Spin until lane `lane_idx` finishes its current job; returns the
    /// panic payload if the job panicked.
    fn wait_idle(&self, lane_idx: usize) -> Option<Box<dyn Any + Send>> {
        let lane = &self.shared.lanes[lane_idx];
        let target = lane.job.load(Ordering::Relaxed);
        let mut spins: u32 = 0;
        while lane.done.load(Ordering::Acquire) != target {
            spins += 1;
            if spins < WAIT_YIELD_AFTER_SPINS {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        // SAFETY: the acquire load above observed the worker's release
        // store of `done == job`, so the worker has finished writing
        // `panic` and will not touch it again before the next dispatch,
        // which only this thread can issue.
        unsafe { (*lane.panic.get()).take() }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // No broadcast can be in flight here (`broadcast` blocks until
        // all lanes are idle before returning, and dropping requires
        // exclusive ownership), so every lane is idle: bump its epoch
        // with the shutdown flag set and the worker exits instead of
        // reading the (stale) slot.
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for lane in &self.shared.lanes {
            lane.job.fetch_add(1, Ordering::SeqCst);
            let _guard = lane.mutex.lock().unwrap();
            lane.condvar.notify_one();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>, idx: usize) {
    let lane = &shared.lanes[idx];
    let mut seen: u64 = 0;
    while let Some(epoch) = wait_for_job(lane, &shared, seen) {
        seen = epoch;
        // SAFETY: the dispatcher wrote the slot before publishing
        // `job == seen` and will not rewrite it until we store
        // `done == seen` below, so this read cannot race.
        let slot = unsafe { *lane.slot.get() };
        let result = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: `call` is the trampoline monomorphized for the
            // closure `data` points at; the dispatching `broadcast`
            // keeps that closure alive until this lane publishes
            // completion.
            unsafe { (slot.call)(slot.data, slot.arg) }
        }));
        if let Err(payload) = result {
            // SAFETY: the dispatcher does not read `panic` until it has
            // observed the `done` store below.
            unsafe {
                *lane.panic.get() = Some(payload);
            }
        }
        lane.done.store(seen, Ordering::Release);
    }
    shared.live.fetch_sub(1, Ordering::SeqCst);
}

/// Block until the lane's job epoch moves past `seen` (spin, then park).
/// Returns `None` on shutdown.
fn wait_for_job(lane: &Lane, shared: &PoolShared, seen: u64) -> Option<u64> {
    let mut spins: u32 = 0;
    loop {
        let epoch = lane.job.load(Ordering::SeqCst);
        if shared.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        if epoch != seen {
            return Some(epoch);
        }
        spins += 1;
        if spins < PARK_AFTER_SPINS {
            std::hint::spin_loop();
            continue;
        }
        // Park: set `sleeping`, then re-check the epoch under the mutex
        // before waiting — paired with the dispatcher's publish-then-
        // check-sleeping order this cannot lose a wakeup.
        lane.sleeping.store(true, Ordering::SeqCst);
        {
            let mut guard = lane.mutex.lock().unwrap();
            while lane.job.load(Ordering::SeqCst) == seen && !shared.shutdown.load(Ordering::SeqCst)
            {
                guard = lane.condvar.wait(guard).unwrap();
            }
        }
        lane.sleeping.store(false, Ordering::SeqCst);
        spins = 0;
    }
}

/// A worker-fan-out handle: the unit that flows everywhere a raw
/// `threads: usize` count used to.
///
/// Three modes share one decomposition (bit-identical outputs):
///
/// * `Workers::serial()` — width 1, everything runs inline.
/// * `Workers::scoped(n)` — width n over fresh `std::thread::scope`
///   threads per call (the legacy tier; also the bit-parity reference
///   for pool tests).
/// * pooled (`Workers::for_pool` / `Workers::pooled`) — width
///   `1 + lane range` over a [`WorkerPool`]: the dispatching thread is
///   worker 0 and each pooled lane in `[lo, hi)` is one additional
///   worker. Sub-ranges of one pool (from [`Workers::nested_for_each_mut`])
///   are disjoint, which is what caps nested fan-out at the pool size.
///
/// Single-dispatcher rule: a handle (and any clone sharing its lane
/// range) must not issue overlapping broadcasts from two threads; lane
/// mailboxes assert on double dispatch. Ownership in this codebase
/// (scratch structs own their handle) enforces this structurally.
#[derive(Clone)]
pub struct Workers {
    pool: Option<Arc<WorkerPool>>,
    lo: usize,
    hi: usize,
    scoped: usize,
}

impl Default for Workers {
    fn default() -> Workers {
        Workers::serial()
    }
}

impl std::fmt::Debug for Workers {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.pool.is_some() {
            write!(f, "Workers::pooled(width={})", self.width())
        } else if self.scoped > 1 {
            write!(f, "Workers::scoped(width={})", self.scoped)
        } else {
            write!(f, "Workers::serial")
        }
    }
}

impl Workers {
    /// Width-1 handle: everything runs inline on the caller.
    pub fn serial() -> Workers {
        Workers { pool: None, lo: 0, hi: 0, scoped: 1 }
    }

    /// Scoped-spawn handle of the given width (legacy tier: fresh
    /// threads per call, ~10µs dispatch).
    pub fn scoped(width: usize) -> Workers {
        Workers { pool: None, lo: 0, hi: 0, scoped: width.max(1) }
    }

    /// Create a fresh private pool of `width` total workers and return
    /// its full-width handle (the pool lives as long as some clone of
    /// the handle does).
    pub fn pooled(width: usize) -> Workers {
        Workers::for_pool(&Arc::new(WorkerPool::new(width)))
    }

    /// Handle for a legacy `threads: usize` request: resolve through
    /// [`resolve_threads`] (`SALS_THREADS` override wins, 0 = one per
    /// CPU), then serial for width 1 and a fresh private pool otherwise.
    /// Callers that already own a pool should use [`Workers::for_pool`]
    /// instead of minting one per call site.
    pub fn auto(requested: usize) -> Workers {
        let n = resolve_threads(requested);
        if n <= 1 {
            Workers::serial()
        } else {
            Workers::pooled(n)
        }
    }

    /// Full-width handle over an existing pool.
    pub fn for_pool(pool: &Arc<WorkerPool>) -> Workers {
        Workers { pool: Some(Arc::clone(pool)), lo: 0, hi: pool.lanes(), scoped: 1 }
    }

    /// Total workers this handle fans out to (caller included).
    pub fn width(&self) -> usize {
        if self.pool.is_some() {
            1 + (self.hi - self.lo)
        } else {
            self.scoped
        }
    }

    /// True when backed by a persistent pool (vs scoped spawn / serial).
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Lifetime dispatch count of the backing pool (0 for non-pooled
    /// handles) — lets tests assert degenerate inputs stay serial.
    pub fn pool_dispatch_count(&self) -> u64 {
        self.pool.as_ref().map_or(0, |p| p.dispatch_count())
    }

    /// Live-worker probe of the backing pool (None for non-pooled
    /// handles); readable after every handle is dropped.
    pub fn live_probe(&self) -> Option<PoolLiveProbe> {
        self.pool.as_ref().map(|p| p.live_probe())
    }

    /// Run `f(t)` for `t in 0..width.min(self.width())`, caller as
    /// worker 0, blocking until all workers finish. Worker panics are
    /// re-raised on the caller after every lane has completed (so the
    /// scoped borrows stay sound and the pool stays reusable).
    fn broadcast<F: Fn(usize) + Sync>(&self, width: usize, f: &F) {
        let w = width.min(self.width()).max(1);
        if w <= 1 {
            f(0);
            return;
        }
        match &self.pool {
            Some(pool) => {
                let data = f as *const F as *const ();
                for t in 1..w {
                    pool.dispatch(self.lo + t - 1, data, call_thunk::<F>, t);
                }
                let mut first_panic = catch_unwind(AssertUnwindSafe(|| f(0))).err();
                for t in 1..w {
                    let lane_panic = pool.wait_idle(self.lo + t - 1);
                    if first_panic.is_none() {
                        first_panic = lane_panic;
                    }
                }
                if let Some(payload) = first_panic {
                    resume_unwind(payload);
                }
            }
            None => {
                std::thread::scope(|s| {
                    for t in 1..w {
                        s.spawn(move || f(t));
                    }
                    f(0);
                });
            }
        }
    }

    /// Pool-backed drop-in for [`parallel_for`]: same chunking, same
    /// index order, sub-microsecond dispatch when pooled.
    pub fn parallel_for(&self, n: usize, f: impl Fn(usize) + Sync) {
        let w = self.width().min(n.max(1));
        if w <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let chunk = n.div_ceil(w);
        self.broadcast(w, &|t: usize| {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            for i in lo..hi {
                f(i);
            }
        });
    }

    /// Pool-backed drop-in for [`parallel_for_each_mut`]: each worker
    /// owns a disjoint contiguous `&mut` range of `items`.
    pub fn for_each_mut<T: Send>(&self, items: &mut [T], f: impl Fn(usize, &mut T) + Sync) {
        let n = items.len();
        let w = self.width().min(n.max(1));
        if w <= 1 || n <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = n.div_ceil(w);
        let base = items.as_mut_ptr() as usize;
        self.broadcast(w, &|t: usize| {
            let lo = t * chunk;
            if lo >= n {
                return;
            }
            let hi = ((t + 1) * chunk).min(n);
            // SAFETY: workers receive disjoint contiguous index ranges
            // [lo, hi) of `items` (div_ceil chunking over distinct t),
            // each carved exactly once, and `broadcast` does not return
            // until every worker finishes — so each element has exactly
            // one live &mut inside the caller's borrow of `items`.
            let part = unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(lo), hi - lo) };
            for (j, item) in part.iter_mut().enumerate() {
                f(lo + j, item);
            }
        });
    }

    /// Pool-backed drop-in for [`parallel_chunks_mut`]: decomposition
    /// fixed by `chunk_size` (never the worker count), so per-element
    /// work that is independent of the chunking is bit-identical for
    /// every handle width.
    pub fn chunks_mut<T: Send>(
        &self,
        buf: &mut [T],
        chunk_size: usize,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        assert!(chunk_size > 0, "Workers::chunks_mut needs a positive chunk size");
        if buf.is_empty() {
            return;
        }
        let n = buf.len();
        let n_chunks = n.div_ceil(chunk_size);
        let w = self.width().min(n_chunks);
        if w <= 1 {
            for (i, chunk) in buf.chunks_mut(chunk_size).enumerate() {
                f(i, chunk);
            }
            return;
        }
        let per_worker = n_chunks.div_ceil(w);
        let base = buf.as_mut_ptr() as usize;
        self.broadcast(w, &|t: usize| {
            let c0 = t * per_worker;
            if c0 >= n_chunks {
                return;
            }
            let lo = c0 * chunk_size;
            let hi = ((c0 + per_worker) * chunk_size).min(n);
            // SAFETY: workers receive disjoint contiguous element ranges
            // (whole runs of `per_worker` chunks; only the last run may
            // end short), each carved exactly once, and `broadcast`
            // blocks until all workers finish — one live &mut per
            // element inside the caller's borrow of `buf`.
            let run = unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(lo), hi - lo) };
            for (k, chunk) in run.chunks_mut(chunk_size).enumerate() {
                f(c0 + k, chunk);
            }
        });
    }

    /// Pool-backed drop-in for [`parallel_units_mut`]: worker `t` owns
    /// lane `t`, a contiguous unit range, and the matching `out` slice.
    /// Worker count is `lanes.len().min(n_units).min(self.width())`.
    pub fn units_mut<L: Send, T: Send>(
        &self,
        lanes: &mut [L],
        out: &mut [T],
        unit_width: usize,
        n_units: usize,
        f: impl Fn(usize, &mut L, &mut [T]) + Sync,
    ) {
        assert!(!lanes.is_empty(), "Workers::units_mut needs at least one lane");
        assert!(unit_width > 0);
        assert_eq!(out.len(), n_units * unit_width);
        let w = lanes.len().min(n_units.max(1)).min(self.width());
        if w <= 1 {
            let lane = &mut lanes[0];
            for (u, unit_out) in out.chunks_mut(unit_width).enumerate() {
                f(u, lane, unit_out);
            }
            return;
        }
        let chunk = n_units.div_ceil(w);
        let lane_base = lanes.as_mut_ptr() as usize;
        let out_base = out.as_mut_ptr() as usize;
        self.broadcast(w, &|t: usize| {
            let lo = t * chunk;
            if lo >= n_units {
                return;
            }
            let hi = (lo + chunk).min(n_units);
            // SAFETY: worker t exclusively owns lane index t (distinct
            // per worker, t < w <= lanes.len()) and the disjoint
            // contiguous unit range [lo, hi) of `out`; `broadcast`
            // blocks until all workers finish, so each lane/element has
            // exactly one live &mut inside the caller's borrows.
            let lane = unsafe { &mut *(lane_base as *mut L).add(t) };
            // SAFETY: as above — unit ranges are disjoint across workers
            // and in-bounds (`hi <= n_units`, `out.len() == n_units *
            // unit_width`).
            let seg = unsafe {
                std::slice::from_raw_parts_mut(
                    (out_base as *mut T).add(lo * unit_width),
                    (hi - lo) * unit_width,
                )
            };
            for (i, unit_out) in seg.chunks_mut(unit_width).enumerate() {
                f(lo + i, lane, unit_out);
            }
        });
    }

    /// Two-level fan-out from one budget: partition `items` over up to
    /// `width` active workers and grant each a *disjoint* sub-handle for
    /// its own nested fan-out, such that active + granted == width.
    ///
    /// This replaces the old `share = threads / batch` arithmetic, which
    /// could oversubscribe (`ceil(threads/batch) * batch > threads` when
    /// the batch doesn't divide the count) and, pooled, would have needed
    /// overlapping lane ranges. Spare workers are spread round-robin:
    /// worker `t` gets `1 + spare/active + (t < spare%active)` total
    /// width. With a single item (or width 1) the item inherits this
    /// whole handle, so a batch of one keeps the full pool for its
    /// per-sequence attend fan-out.
    pub fn nested_for_each_mut<T: Send>(
        &self,
        items: &mut [T],
        f: impl Fn(usize, &mut T, &Workers) + Sync,
    ) {
        let n = items.len();
        if n == 0 {
            return;
        }
        let width = self.width();
        let active = width.min(n);
        if active <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item, self);
            }
            return;
        }
        let chunk = n.div_ceil(active);
        let spare = width - active;
        let per = spare / active;
        let rem = spare % active;
        let sub_for = |t: usize| -> Workers {
            let extra = per + usize::from(t < rem);
            match &self.pool {
                Some(pool) => {
                    // The broadcast below occupies pool lanes
                    // [self.lo, self.lo + active - 1) (the caller is
                    // worker 0); spare lanes follow, carved into
                    // disjoint per-worker ranges.
                    let start = self.lo + (active - 1) + t * per + t.min(rem);
                    Workers {
                        pool: Some(Arc::clone(pool)),
                        lo: start,
                        hi: start + extra,
                        scoped: 1,
                    }
                }
                None => Workers::scoped(1 + extra),
            }
        };
        let base = items.as_mut_ptr() as usize;
        self.broadcast(active, &|t: usize| {
            let lo = t * chunk;
            if lo >= n {
                return;
            }
            let hi = ((t + 1) * chunk).min(n);
            let sub = sub_for(t);
            // SAFETY: workers receive disjoint contiguous index ranges
            // of `items` (div_ceil chunking over distinct t), each
            // carved exactly once, and `broadcast` blocks until all
            // workers finish.
            let part = unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(lo), hi - lo) };
            for (j, item) in part.iter_mut().enumerate() {
                f(lo + j, item, &sub);
            }
        });
    }

    /// Measured per-call fan-out latency of this handle (best-of over
    /// batches of empty full-width broadcasts), in nanoseconds. For a
    /// pooled handle this is the mailbox handoff + completion wait; for
    /// a scoped handle it is the thread spawn + join cost the pool
    /// replaces.
    pub fn dispatch_ns(&self) -> f64 {
        let w = self.width();
        // Warm: fault in stacks, wake parked workers into the spin loop.
        for _ in 0..64 {
            self.broadcast(w, &|_: usize| {});
        }
        let iters: u32 = if self.pool.is_some() || w <= 1 { 2048 } else { 256 };
        let mut best = f64::INFINITY;
        for _ in 0..4 {
            let start = std::time::Instant::now();
            for _ in 0..iters {
                self.broadcast(w, &|_: usize| {});
            }
            best = best.min(start.elapsed().as_secs_f64());
        }
        best / f64::from(iters) * 1e9
    }
}

/// Pool provenance stamped into every BENCH_*.json by
/// `harness::bench_doc`: `(pool_size, measured dispatch ns)` for the
/// size `resolve_threads(0)` resolves to. Probed once per process on a
/// transient pool (created, warmed, measured, joined) so the stamp
/// reflects steady-state handoff latency without holding threads alive.
pub fn pool_provenance() -> (usize, f64) {
    static PROBE: OnceLock<(usize, f64)> = OnceLock::new();
    *PROBE.get_or_init(|| {
        let size = resolve_threads(0);
        let workers = Workers::pooled(size);
        (size, workers.dispatch_ns())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_for_each_mut_visits_each_item_once_with_its_index() {
        let mut items: Vec<usize> = vec![0; 357];
        parallel_for_each_mut(&mut items, 8, |i, item| {
            *item += i + 1; // +1 distinguishes "visited index 0" from "missed"
        });
        assert_eq!(items, (0..357).map(|i| i + 1).collect::<Vec<_>>());
        // Degenerate sizes.
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_each_mut(&mut empty, 4, |_, _| panic!("should not run"));
        let mut one = vec![7usize];
        parallel_for_each_mut(&mut one, 16, |i, item| *item += i);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn parallel_chunks_mut_fixed_decomposition() {
        // 357 elements in 16-sized chunks: every element visited once, the
        // chunk index maps to the right offset, any thread count.
        for threads in [1usize, 3, 8] {
            let mut items: Vec<usize> = vec![0; 357];
            parallel_chunks_mut(&mut items, 16, threads, |ci, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = ci * 16 + j + 1;
                }
            });
            assert_eq!(items, (0..357).map(|i| i + 1).collect::<Vec<_>>(), "threads={threads}");
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_chunks_mut(&mut empty, 4, 4, |_, _| panic!("should not run"));
    }

    #[test]
    fn parallel_units_mut_partitions_units_and_lanes() {
        // 7 units of width 3 over {1, 2, 3, 8} lanes: every unit visited
        // once with the right offset, and each unit touched by the lane
        // that owns its contiguous range.
        for n_lanes in [1usize, 2, 3, 8] {
            let mut lanes: Vec<usize> = vec![0; n_lanes];
            let mut out: Vec<usize> = vec![0; 7 * 3];
            parallel_units_mut(&mut lanes, &mut out, 3, 7, |u, lane, unit| {
                *lane += 1; // worker-serial: no lock needed
                for (k, x) in unit.iter_mut().enumerate() {
                    *x = u * 3 + k + 1;
                }
            });
            assert_eq!(out, (0..21).map(|i| i + 1).collect::<Vec<_>>(), "{n_lanes} lanes");
            assert_eq!(lanes.iter().sum::<usize>(), 7, "every unit ran exactly once");
        }
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 7, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let out = parallel_map(1, 16, |i| i + 1);
        assert_eq!(out, vec![1]);
    }

    /// Run all four parallel shapes under one handle and return every
    /// observable output (index sums for `parallel_for`, full element
    /// vectors for the `&mut` shapes, unit-visit totals for
    /// `units_mut`) so modes can be compared for exact equality.
    type ShapeOutputs = (usize, Vec<usize>, Vec<usize>, usize, Vec<usize>);

    fn run_all_shapes(w: &Workers, n: usize, chunk_size: usize) -> ShapeOutputs {
        let sum = AtomicUsize::new(0);
        w.parallel_for(n, |i| {
            sum.fetch_add(i * 31 + 1, Ordering::Relaxed);
        });
        let mut items = vec![0usize; n];
        w.for_each_mut(&mut items, |i, item| *item = i * 7 + 3);
        let mut buf = vec![0usize; n];
        w.chunks_mut(&mut buf, chunk_size, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = ci * chunk_size + j + 1;
            }
        });
        let mut lanes = vec![0usize; 3];
        let mut out = vec![0usize; n * 2];
        w.units_mut(&mut lanes, &mut out, 2, n, |u, lane, unit| {
            *lane += 1;
            for (k, x) in unit.iter_mut().enumerate() {
                *x = u * 2 + k + 5;
            }
        });
        (sum.into_inner(), items, buf, lanes.iter().sum(), out)
    }

    #[test]
    fn pool_scoped_serial_parity_all_shapes() {
        // Proptest: for random (n, chunk_size), the pooled handle (two
        // sizes), the scoped handle (two widths), and the serial handle
        // produce identical outputs on all four parallel shapes.
        let pooled2 = Workers::pooled(2);
        let pooled8 = Workers::pooled(8);
        crate::util::prop::check(
            "pool-vs-scoped-bit-parity",
            60,
            |r| (r.below(257), 1 + r.below(12)),
            |&(n, chunk_size)| {
                let reference = run_all_shapes(&Workers::serial(), n, chunk_size);
                [&Workers::scoped(3), &Workers::scoped(8), &pooled2, &pooled8]
                    .into_iter()
                    .all(|w| run_all_shapes(w, n, chunk_size) == reference)
            },
        );
    }

    #[test]
    fn pooled_dispatch_reuses_lanes_across_calls() {
        // Many back-to-back broadcasts (epoch reuse) with occasional
        // sleeps long enough to park the workers — both the spinning and
        // the parked wakeup path must deliver every job.
        let pooled = Workers::pooled(4);
        for round in 0..40 {
            let hits = AtomicUsize::new(0);
            pooled.parallel_for(128, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 128, "round {round}");
            if round % 10 == 9 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pooled = Workers::pooled(4);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pooled.parallel_for(100, |i| {
                // Index 73 lands on a pooled lane (chunk 25 → worker 2),
                // exercising the cross-thread panic path, not just the
                // caller's own chunk.
                assert_ne!(i, 73, "deliberate test panic");
            });
        }));
        assert!(result.is_err(), "worker panic must propagate to the dispatching caller");
        // All lanes were waited on before the rethrow, so the pool is
        // idle and reusable — a panicked step must not wedge the engine.
        let hits = AtomicUsize::new(0);
        pooled.parallel_for(100, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn drop_joins_all_workers_across_restarts() {
        // Three create/use/drop cycles in one process (engine restarts):
        // every cycle must end with zero live workers for that pool.
        for cycle in 0..3 {
            let pooled = Workers::pooled(5);
            let probe = pooled.live_probe().expect("pooled handle has a probe");
            assert_eq!(probe.count(), 4, "cycle {cycle}: 5 workers = caller + 4 threads");
            let hits = AtomicUsize::new(0);
            pooled.parallel_for(64, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 64);
            drop(pooled);
            assert_eq!(probe.count(), 0, "cycle {cycle}: Drop must join every worker");
        }
    }

    #[test]
    fn degenerate_inputs_stay_serial_on_pooled_handles() {
        let pooled = Workers::pooled(8);
        let before = pooled.pool_dispatch_count();
        pooled.parallel_for(0, |_| panic!("should not run"));
        pooled.parallel_for(1, |i| assert_eq!(i, 0));
        let mut one = vec![9usize];
        pooled.for_each_mut(&mut one, |_, x| *x += 1);
        assert_eq!(one, vec![10]);
        let mut empty: Vec<usize> = Vec::new();
        pooled.for_each_mut(&mut empty, |_, _| panic!("should not run"));
        pooled.chunks_mut(&mut empty, 4, |_, _| panic!("should not run"));
        let mut small = vec![0usize; 3];
        pooled.chunks_mut(&mut small, 8, |_, c| c.fill(1)); // single chunk
        assert_eq!(small, vec![1; 3]);
        let mut lanes = vec![0usize; 4];
        let mut out = vec![0usize; 6];
        pooled.units_mut(&mut lanes, &mut out, 6, 1, |_, _, unit| unit.fill(2));
        assert_eq!(out, vec![2; 6]);
        assert_eq!(
            pooled.pool_dispatch_count(),
            before,
            "empty/single-item inputs must not touch the pool lanes"
        );
    }

    #[test]
    fn pool_of_one_runs_inline() {
        let pooled = Workers::pooled(1);
        assert_eq!(pooled.width(), 1);
        assert_eq!(pooled.live_probe().unwrap().count(), 0, "no OS threads for width 1");
        let mut items = vec![0usize; 10];
        pooled.for_each_mut(&mut items, |i, x| *x = i);
        assert_eq!(items, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn nested_budget_never_exceeds_pool_width() {
        // 8-wide pool over 3 items: active = 3, spare = 5 → sub-widths
        // {3, 3, 2}. Total grants must equal the budget exactly and the
        // observed worker concurrency (outer + all nested fan-outs) must
        // never exceed the pool width — the oversubscription fix.
        for w in [Workers::pooled(8), Workers::scoped(8)] {
            let widths = Mutex::new(vec![0usize; 3]);
            let current = AtomicUsize::new(0);
            let high_water = AtomicUsize::new(0);
            let mut items = vec![0usize; 3];
            w.nested_for_each_mut(&mut items, |i, _item, sub| {
                widths.lock().unwrap()[i] = sub.width();
                sub.parallel_for(sub.width(), |_| {
                    let live = current.fetch_add(1, Ordering::SeqCst) + 1;
                    high_water.fetch_max(live, Ordering::SeqCst);
                    // Hold the slot long enough for fan-outs to overlap.
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    current.fetch_sub(1, Ordering::SeqCst);
                });
            });
            let mut widths = widths.into_inner().unwrap();
            assert_eq!(widths.iter().sum::<usize>(), 8, "grants must spend the whole budget");
            widths.sort_unstable();
            assert_eq!(widths, vec![2, 3, 3]);
            assert!(
                high_water.load(Ordering::SeqCst) <= 8,
                "nested fan-out exceeded the pool budget: {}",
                high_water.load(Ordering::SeqCst)
            );
        }
    }

    #[test]
    fn nested_single_item_inherits_full_handle() {
        let pooled = Workers::pooled(6);
        let mut items = vec![0usize; 1];
        let seen_width = AtomicUsize::new(0);
        pooled.nested_for_each_mut(&mut items, |_, _, sub| {
            seen_width.store(sub.width(), Ordering::Relaxed);
        });
        assert_eq!(
            seen_width.load(Ordering::Relaxed),
            6,
            "a batch of one keeps the whole pool for its intra-attend fan-out"
        );
    }

    #[test]
    fn nested_matches_flat_decomposition() {
        // Item partition of the nested fan-out must be identical to
        // for_each_mut (same div_ceil chunking over active workers).
        for n in [1usize, 2, 3, 5, 8, 13] {
            let pooled = Workers::pooled(4);
            let mut nested_items = vec![0usize; n];
            pooled.nested_for_each_mut(&mut nested_items, |i, item, _| *item = i * 11 + 2);
            let mut flat_items = vec![0usize; n];
            pooled.for_each_mut(&mut flat_items, |i, item| *item = i * 11 + 2);
            assert_eq!(nested_items, flat_items, "n={n}");
        }
    }

    #[test]
    fn resolve_threads_auto_and_explicit() {
        // Under SALS_THREADS the override wins for every request;
        // otherwise 0 means one-per-CPU and positive values pass through.
        match threads_override() {
            Some(n) => {
                assert_eq!(resolve_threads(0), n);
                assert_eq!(resolve_threads(3), n);
            }
            None => {
                assert_eq!(resolve_threads(0), num_cpus());
                assert_eq!(resolve_threads(3), 3);
            }
        }
    }

    #[test]
    fn dispatch_probe_returns_finite_latency() {
        let (size, ns) = pool_provenance();
        assert!(size >= 1);
        assert!(ns.is_finite() && ns >= 0.0);
    }
}

//! Fixed-size thread pool with scoped parallel-for (no rayon offline).
//!
//! Used by the coordinator for worker fan-out and by benches for parallel
//! workload generation. `parallel_for` splits an index range into contiguous
//! chunks and runs them on `std::thread::scope` threads;
//! `parallel_for_each_mut` is the `&mut`-item variant the engine's prefill
//! phase uses to fan work out over per-sequence state (each item is owned
//! by exactly one worker thread).

/// Run `f(i)` for every i in 0..n across up to `threads` OS threads.
///
/// `f` must be Sync; each index is processed exactly once. Chunking is
/// contiguous so cache locality of per-index work is preserved.
pub fn parallel_for(n: usize, threads: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Run `f(i, &mut items[i])` for every item across up to `threads` OS
/// threads. Contiguous chunking: each thread owns a disjoint `&mut` slice,
/// so `f` gets exclusive access to its item with no locks. This is the
/// fan-out primitive for per-sequence work over shared read-only weights
/// (cross-sequence batched decode, parallel prefill).
pub fn parallel_for_each_mut<T: Send>(
    items: &mut [T],
    threads: usize,
    f: impl Fn(usize, &mut T) + Sync,
) {
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, item) in slice.iter_mut().enumerate() {
                    f(t * chunk + j, item);
                }
            });
        }
    });
}

/// Map `f` over 0..n in parallel, collecting results in index order.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    // Each scope thread owns a disjoint &mut [Option<T>] chunk — no locks.
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
    } else {
        let chunk = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, slice) in out.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, slot) in slice.iter_mut().enumerate() {
                        *slot = Some(f(t * chunk + j));
                    }
                });
            }
        });
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Number of available CPUs (fallback 4).
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_for_each_mut_visits_each_item_once_with_its_index() {
        let mut items: Vec<usize> = vec![0; 357];
        parallel_for_each_mut(&mut items, 8, |i, item| {
            *item += i + 1; // +1 distinguishes "visited index 0" from "missed"
        });
        assert_eq!(items, (0..357).map(|i| i + 1).collect::<Vec<_>>());
        // Degenerate sizes.
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_each_mut(&mut empty, 4, |_, _| panic!("should not run"));
        let mut one = vec![7usize];
        parallel_for_each_mut(&mut one, 16, |i, item| *item += i);
        assert_eq!(one, vec![7]);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 7, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let out = parallel_map(1, 16, |i| i + 1);
        assert_eq!(out, vec![1]);
    }
}

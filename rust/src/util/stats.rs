//! Descriptive statistics for benchmark reporting (mean, std, percentiles).

/// Summary statistics over a sample of measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute summary statistics. Returns a zeroed summary for empty input.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Relative L2 error ||a-b|| / max(||b||, eps) between two vectors.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        num += d * d;
        den += (*y as f64) * (*y as f64);
    }
    (num / den.max(1e-30)).sqrt()
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    dot / (na.sqrt() * nb.sqrt()).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_singleton() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert!(rel_l2(&a, &a) < 1e-12);
    }

    #[test]
    fn cosine_orthogonal_and_parallel() {
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 2.0], &[2.0, 4.0]) - 1.0).abs() < 1e-9);
    }
}

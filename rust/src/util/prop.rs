//! Miniature property-testing harness (no `proptest` in the offline set).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs greedy shrinking via
//! the input's `Shrink` implementation and panics with the minimal
//! counterexample. Used for coordinator invariants (routing, batching,
//! cache-state) and numeric-kernel invariants.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller values (may be empty).
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for (usize, usize) {
    fn shrink(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1));
        }
        for b in self.1.shrink() {
            out.push((self.0, b));
        }
        out
    }
}

impl Shrink for Vec<f32> {
    fn shrink(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
        }
        if self.iter().any(|x| *x != 0.0) {
            out.push(vec![0.0; self.len()]);
        }
        out
    }
}

impl Shrink for Vec<usize> {
    fn shrink(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
        }
        out
    }
}

/// Run a property over `cases` random inputs; shrink + panic on failure.
pub fn check<T: Shrink>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(0xC0FFEE ^ name.len() as u64);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!("property '{name}' failed on case {case}; minimal counterexample: {minimal:?}");
        }
    }
}

fn shrink_loop<T: Shrink>(mut failing: T, prop: &impl Fn(&T) -> bool) -> T {
    // Greedy descent: keep taking the first shrunk candidate that still fails.
    'outer: for _ in 0..1000 {
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check("add-commutes", 200, |r| (r.below(100), r.below(100)), |(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check("all-below-50", 500, |r| r.below(100), |&x| x < 50);
    }

    #[test]
    fn shrink_usize_descends() {
        let s = 10usize.shrink();
        assert!(s.contains(&5));
        assert!(s.contains(&9));
        assert!(0usize.shrink().is_empty());
    }
}

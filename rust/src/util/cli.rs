//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value parsed as T, or `default`.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Whether a bare `--flag` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--rank=64", "extra"]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get_or::<usize>("rank", 0), 64);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--dry-run"]);
        assert!(a.flag("dry-run"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn get_or_falls_back() {
        let a = parse(&["--k", "notanumber"]);
        assert_eq!(a.get_or::<usize>("k", 7), 7);
        assert_eq!(a.get_or::<usize>("missing", 9), 9);
    }
}

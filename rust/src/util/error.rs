//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the SALS library.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape mismatch between tensors or against a config.
    #[error("shape mismatch: {0}")]
    Shape(String),
    /// Invalid configuration value.
    #[error("invalid config: {0}")]
    Config(String),
    /// I/O error (artifact loading, trace files).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// Error bubbled up from the XLA/PJRT runtime.
    #[error("xla error: {0}")]
    Xla(String),
    /// Coordinator-level failure (queue closed, session missing, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: the offline build environment has
//! no crate registry, so we do not pull in `thiserror`.

use std::fmt;

/// Errors surfaced by the SALS library.
#[derive(Debug)]
pub enum Error {
    /// Shape mismatch between tensors or against a config.
    Shape(String),
    /// Invalid configuration value.
    Config(String),
    /// I/O error (artifact loading, trace files).
    Io(std::io::Error),
    /// Error bubbled up from the XLA/PJRT runtime.
    Xla(String),
    /// Coordinator-level failure (queue closed, session missing, ...).
    Coordinator(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Config(s) => write!(f, "invalid config: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

//! Deterministic PRNG (xoshiro256**) used everywhere randomness is needed.
//!
//! The offline crate set has no `rand`, so we carry a small, well-tested
//! generator: xoshiro256** (Blackman & Vigna). Determinism matters — every
//! experiment in EXPERIMENTS.md is seeded and reproducible.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our scale (n << 2^64): modulo bias
        // is negligible for n < 2^32 but we do the widening trick anyway.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "Rng::range empty");
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; throughput is not a concern for weight init).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), ascending order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut set = std::collections::BTreeSet::new();
        for j in n - k..n {
            let t = self.below(j + 1);
            if !set.insert(t) {
                set.insert(j);
            }
        }
        set.into_iter().collect()
    }

    /// Poisson sample via Knuth (small lambda) — used by trace generators.
    pub fn poisson(&mut self, lambda: f64) -> usize {
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // guard against pathological lambda
            }
        }
    }

    /// Exponential inter-arrival time with the given rate (per second).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_positive_mean_close() {
        let mut r = Rng::new(19);
        let rate = 4.0;
        let n = 20_000;
        let mean = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.02, "mean={mean}");
    }
}

//! Timing helpers for the bench harness.

use std::time::Instant;

/// Measure one closure invocation in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `warmup` unmeasured then `iters` measured invocations; returns
/// per-iteration seconds.
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Adaptively pick an iteration count so total measured time ≈ `budget_s`,
/// then measure. Returns per-iteration seconds (at least `min_iters`).
pub fn time_budgeted(budget_s: f64, min_iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    // Pilot run to estimate cost.
    let t0 = Instant::now();
    f();
    let pilot = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / pilot) as usize).clamp(min_iters, 100_000);
    time_iters(1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn time_iters_counts() {
        let mut n = 0;
        let ts = time_iters(2, 5, || n += 1);
        assert_eq!(ts.len(), 5);
        assert_eq!(n, 7);
    }

    #[test]
    fn budgeted_respects_min() {
        let ts = time_budgeted(0.0, 3, || {
            std::hint::black_box(1 + 1);
        });
        assert!(ts.len() >= 3);
    }
}

//! `sals` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve      drive a request trace through the serving engine (CPU model)
//!   serve-xla  drive a trace through the AOT HLO artifacts (PJRT runtime)
//!   calibrate  run the offline §4.2 calibration and save projectors
//!   analyze    figure data generators: pca-rope | overlap | rank
//!   model      print the §4.5 memory-traffic model for given settings
//!   info       environment + artifact status

use sals::attention::traffic::sals_speedup_model;
use sals::coordinator::{Engine, EngineConfig, TraceGen, TraceSpec};
use sals::model::{
    calibrate, fit_calibration, make_factory, Method, Model, ModelConfig, SparsityParams, Weights,
};
use sals::util::cli::Args;
use sals::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "serve-xla" => serve_xla(&args),
        "calibrate" => calibrate_cmd(&args),
        "analyze" => analyze(&args),
        "model" => traffic_model(&args),
        "info" => info(),
        _ => help(),
    }
}

fn help() {
    println!("sals — Sparse Attention in Latent Space (paper reproduction)");
    println!();
    println!("usage: sals <command> [--options]");
    println!("  serve      [--method sals25|sals125|full] [--requests N] [--seq N]");
    println!("  serve-xla  [--variant sals|dense] [--requests N]   (needs `make artifacts`)");
    println!("  calibrate  [--rank R] [--streams N] [--out DIR]");
    println!("  analyze    pca-rope | overlap | rank");
    println!("  model      [--seq N] [--dim D] [--rank R] [--k K]");
    println!("  info");
}

fn parse_method(s: &str) -> Method {
    match s {
        "full" => Method::Full,
        "sals25" => Method::Sals25,
        "sals125" => Method::Sals125,
        "kivi4" => Method::Kivi4,
        "kivi2" => Method::Kivi2,
        "palu30" => Method::Palu30,
        "palu50" => Method::Palu50,
        "loki" => Method::Loki,
        "ds" => Method::DoubleSparse,
        "hshare" => Method::HShare,
        "quest" => Method::Quest,
        "streaming" => Method::StreamingLlm,
        other => {
            eprintln!("unknown method {other}, using sals25");
            Method::Sals25
        }
    }
}

fn serve(args: &Args) {
    let method = parse_method(args.get("method").unwrap_or("sals25"));
    let n_requests: usize = args.get_or("requests", 16);
    let seq: usize = args.get_or("seq", 512);
    let cfg = ModelConfig::tiny_mha(seq + 64);
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 7)));

    // Calibration (fast, small streams).
    let mut rng = Rng::new(11);
    let streams: Vec<Vec<usize>> =
        (0..2).map(|_| (0..256).map(|_| rng.below(cfg.vocab)).collect()).collect();
    let fitted = Arc::new(fit_calibration(&cfg, &calibrate(&model, &streams)));
    let factory = make_factory(method, &fitted, SparsityParams::scaled(seq));

    let mut engine = Engine::new(model, factory, EngineConfig::default());
    let trace = TraceGen::generate(&TraceSpec {
        n_requests,
        vocab: cfg.vocab,
        prompt_min: seq / 4,
        prompt_max: seq / 2,
        ..Default::default()
    });
    for tr in trace {
        engine.submit(tr.request);
    }
    let responses = engine.run_to_completion();
    println!("method={} completed={} tokens/s={:.1}", method.name(), responses.len(), engine.metrics.tokens_per_second());
    println!("{}", engine.metrics.to_json().to_string());
}

fn serve_xla(args: &Args) {
    use sals::runtime::{ArtifactRuntime, XlaModel, XlaVariant};
    let variant = match args.get("variant").unwrap_or("sals") {
        "dense" => XlaVariant::Dense,
        _ => XlaVariant::Sals,
    };
    let n: usize = args.get_or("requests", 8);
    let dir = std::path::PathBuf::from("artifacts");
    let mut rt = match ArtifactRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime init failed: {e}");
            std::process::exit(1);
        }
    };
    let mut m = match XlaModel::new(&mut rt, &dir, variant) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifact load failed ({e}); run `make artifacts`");
            std::process::exit(2);
        }
    };
    let mut rng = Rng::new(3);
    let t0 = std::time::Instant::now();
    let mut tokens = 0usize;
    for i in 0..n {
        m.reset();
        let prompt: Vec<usize> = (0..16 + rng.below(16)).map(|_| rng.below(m.meta.vocab)).collect();
        let out = m.generate(&rt, &prompt, 8).expect("generate");
        tokens += out.len();
        println!("req {i}: prompt {} -> {:?}", prompt.len(), &out[..4.min(out.len())]);
    }
    println!("variant={variant:?} throughput={:.1} tok/s (PJRT CPU, interpret-mode kernels)", tokens as f64 / t0.elapsed().as_secs_f64());
}

fn calibrate_cmd(args: &Args) {
    let rank: usize = args.get_or("rank", 32);
    let n_streams: usize = args.get_or("streams", 8);
    let out = std::path::PathBuf::from(args.get("out").unwrap_or("artifacts"));
    std::fs::create_dir_all(&out).expect("mkdir");
    let cfg = ModelConfig::tiny_mha(512);
    let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 7)));
    let mut rng = Rng::new(17);
    let streams: Vec<Vec<usize>> =
        (0..n_streams).map(|_| (0..256).map(|_| rng.below(cfg.vocab)).collect()).collect();
    let calib = calibrate(&model, &streams);
    for (l, lc) in calib.layers.iter().enumerate() {
        let mut c = sals::lowrank::Calibrator::new(cfg.kv_dim());
        c.add_keys(&lc.pre_keys.data);
        let proj = c.fit(rank.min(cfg.kv_dim())).unwrap();
        let path = out.join(format!("projector_layer{l}.txt"));
        proj.save(&path).expect("save projector");
        println!(
            "layer {l}: rank {} energy {:.1}% rank90 {} -> {}",
            proj.rank,
            100.0 * proj.captured_energy(),
            proj.rank_at(90.0),
            path.display()
        );
    }
}

fn analyze(args: &Args) {
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("pca-rope");
    match what {
        "pca-rope" => {
            let rep = sals::analyze::pca_rope_demo(64, 2048, 10_000.0, 7);
            println!("Figure 1(b) data:");
            println!("  anisotropy pre {:.2} post {:.2}", rep.anisotropy_pre, rep.anisotropy_post);
            println!("  principal-axis |cos| {:.3}", rep.principal_cos);
            println!("  spectrum pre  (top8): {:?}", &rep.spectrum_pre[..8]);
            println!("  spectrum post (top8): {:?}", &rep.spectrum_post[..8]);
        }
        "rank" => {
            let mut rng = Rng::new(5);
            let kv = 64;
            // Low-rank synthetic keys.
            let basis: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(kv, 1.0)).collect();
            let n = 1024;
            let mut keys = vec![0.0f32; n * kv];
            for j in 0..n {
                for b in &basis {
                    sals::tensor::ops::axpy(rng.normal_f32(), b, &mut keys[j * kv..(j + 1) * kv]);
                }
            }
            let rep = sals::analyze::rank_analysis(0, &keys, kv, 32, n, 10_000.0);
            println!("Figure 4 data: rank90 pre={} post={}", rep.rank90_pre, rep.rank90_post);
        }
        "overlap" => {
            println!("run `cargo bench --bench fig2_overlap` for the full per-layer table");
        }
        other => eprintln!("unknown analysis {other} (pca-rope | overlap | rank)"),
    }
}

fn traffic_model(args: &Args) {
    let s: usize = args.get_or("seq", 4096);
    let d: usize = args.get_or("dim", 4096);
    let r: usize = args.get_or("rank", d / 4);
    let k: usize = args.get_or("k", s / 8);
    let speedup = sals_speedup_model(s, d, r, r / 2, k);
    println!("§4.5 model: seq={s} dim={d} rank={r} r*={} k={k} -> predicted memory-bound speedup {speedup:.2}x", r / 2);
}

fn info() {
    println!("sals v{}", env!("CARGO_PKG_VERSION"));
    let meta = std::path::Path::new("artifacts/meta.txt");
    println!("artifacts: {}", if meta.exists() { "built" } else { "missing (run `make artifacts`)" });
    println!("cpus: {}", sals::util::threadpool::num_cpus());
}

//! Integer group quantization for the value cache (§5.1) and the KIVI
//! baseline (Liu et al., 2024).
//!
//! The paper stores values quantized channel-wise (per-channel groups along
//! the token axis): 4-bit at the 25% setting, 2-bit at 12.5%. KIVI's scheme
//! is asymmetric per-channel for keys / per-token for values; both are
//! implemented here over the same packed representation.

pub mod store;

pub use store::{QuantSnapshot, TokenQuantStore};

use crate::util::{Error, Result};

/// Quantization bit-width supported by the packed stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bits {
    B2,
    B4,
    B8,
}

impl Bits {
    pub fn bits(self) -> u32 {
        match self {
            Bits::B2 => 2,
            Bits::B4 => 4,
            Bits::B8 => 8,
        }
    }
    pub fn levels(self) -> u32 {
        1 << self.bits()
    }
    /// Values packed per byte.
    pub fn per_byte(self) -> usize {
        (8 / self.bits()) as usize
    }
    pub fn from_bits(b: u32) -> Result<Bits> {
        match b {
            2 => Ok(Bits::B2),
            4 => Ok(Bits::B4),
            8 => Ok(Bits::B8),
            other => Err(Error::Config(format!("unsupported quant bits: {other}"))),
        }
    }
}

/// One quantized group: packed codes + affine (scale, zero-point) params.
#[derive(Clone, Debug)]
pub struct QuantGroup {
    pub bits: Bits,
    pub n: usize,
    pub scale: f32,
    pub zero: f32,
    pub packed: Vec<u8>,
}

/// Quantize a group of floats with asymmetric affine quantization:
/// code = round((x - min) / scale), x ≈ code * scale + min.
pub fn quantize_group(xs: &[f32], bits: Bits) -> QuantGroup {
    let n = xs.len();
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if n == 0 {
        return QuantGroup { bits, n, scale: 1.0, zero: 0.0, packed: Vec::new() };
    }
    let levels = (bits.levels() - 1) as f32;
    let scale = if hi > lo { (hi - lo) / levels } else { 1.0 };
    let inv = 1.0 / scale;
    let per = bits.per_byte();
    let mut packed = vec![0u8; n.div_ceil(per)];
    let b = bits.bits();
    let mask = (bits.levels() - 1) as u8;
    for (i, &x) in xs.iter().enumerate() {
        let code = (((x - lo) * inv).round() as i64).clamp(0, levels as i64) as u8 & mask;
        packed[i / per] |= code << ((i % per) as u32 * b);
    }
    QuantGroup { bits, n, scale, zero: lo, packed }
}

/// Dequantize into `out` (must have length == group.n).
pub fn dequantize_group(g: &QuantGroup, out: &mut [f32]) {
    assert_eq!(out.len(), g.n);
    let per = g.bits.per_byte();
    let b = g.bits.bits();
    let mask = (g.bits.levels() - 1) as u8;
    for (i, o) in out.iter_mut().enumerate() {
        let code = (g.packed[i / per] >> ((i % per) as u32 * b)) & mask;
        *o = code as f32 * g.scale + g.zero;
    }
}

/// Dequantize a single element without unpacking the group.
#[inline]
pub fn dequantize_at(g: &QuantGroup, i: usize) -> f32 {
    let per = g.bits.per_byte();
    let b = g.bits.bits();
    let mask = (g.bits.levels() - 1) as u8;
    let code = (g.packed[i / per] >> ((i % per) as u32 * b)) & mask;
    code as f32 * g.scale + g.zero
}

/// Channel-wise group-quantized matrix: an (n_rows, n_cols) buffer is cut
/// into per-column (channel) groups of `group_size` consecutive rows, the
/// layout the paper uses for the value cache ("channel-wise group
/// quantisation that mirrors the key-cache setting").
#[derive(Clone, Debug)]
pub struct ChannelQuantMatrix {
    pub rows: usize,
    pub cols: usize,
    pub group_size: usize,
    pub bits: Bits,
    /// groups[c][g] covers rows [g*group_size, ...) of column c.
    groups: Vec<Vec<QuantGroup>>,
}

impl ChannelQuantMatrix {
    /// Quantize a row-major (rows, cols) buffer channel-wise.
    pub fn quantize(data: &[f32], rows: usize, cols: usize, group_size: usize, bits: Bits) -> ChannelQuantMatrix {
        assert_eq!(data.len(), rows * cols);
        assert!(group_size > 0);
        let n_groups = rows.div_ceil(group_size.min(rows.max(1)));
        let mut groups = Vec::with_capacity(cols);
        let mut col_buf = Vec::with_capacity(group_size);
        for c in 0..cols {
            let mut col_groups = Vec::with_capacity(n_groups);
            let mut r = 0;
            while r < rows {
                let hi = (r + group_size).min(rows);
                col_buf.clear();
                for rr in r..hi {
                    col_buf.push(data[rr * cols + c]);
                }
                col_groups.push(quantize_group(&col_buf, bits));
                r = hi;
            }
            groups.push(col_groups);
        }
        ChannelQuantMatrix { rows, cols, group_size, bits, groups }
    }

    /// Dequantize the full matrix (row-major).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut buf = vec![0.0f32; self.group_size];
        for (c, col_groups) in self.groups.iter().enumerate() {
            let mut r = 0;
            for g in col_groups {
                let take = g.n;
                buf.resize(take, 0.0);
                dequantize_group(g, &mut buf[..take]);
                for (i, &v) in buf[..take].iter().enumerate() {
                    out[(r + i) * self.cols + c] = v;
                }
                r += take;
            }
        }
        out
    }

    /// Dequantize one row into `out` (length cols).
    pub fn dequantize_row(&self, row: usize, out: &mut [f32]) {
        assert!(row < self.rows);
        assert_eq!(out.len(), self.cols);
        let g = row / self.group_size;
        let i = row % self.group_size;
        for (c, o) in out.iter_mut().enumerate() {
            *o = dequantize_at(&self.groups[c][g], i);
        }
    }

    /// Stored size in bytes (packed codes + fp32 scale/zero per group).
    pub fn nbytes(&self) -> usize {
        self.groups
            .iter()
            .flat_map(|cg| cg.iter())
            .map(|g| g.packed.len() + 8)
            .sum()
    }
}

/// Simple per-token (row-wise) quantizer — KIVI's value-cache mode.
pub fn quantize_rows(data: &[f32], rows: usize, cols: usize, bits: Bits) -> Vec<QuantGroup> {
    assert_eq!(data.len(), rows * cols);
    (0..rows).map(|r| quantize_group(&data[r * cols..(r + 1) * cols], bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Rng::new(31);
        for bits in [Bits::B2, Bits::B4, Bits::B8] {
            let xs = rng.normal_vec(64, 2.0);
            let g = quantize_group(&xs, bits);
            let mut out = vec![0.0; 64];
            dequantize_group(&g, &mut out);
            for (x, y) in xs.iter().zip(&out) {
                assert!((x - y).abs() <= g.scale * 0.5 + 1e-6, "bits={bits:?} {x} vs {y}");
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(33);
        let xs = rng.normal_vec(256, 1.0);
        let err = |bits| {
            let g = quantize_group(&xs, bits);
            let mut out = vec![0.0; xs.len()];
            dequantize_group(&g, &mut out);
            rel_l2(&out, &xs)
        };
        let (e2, e4, e8) = (err(Bits::B2), err(Bits::B4), err(Bits::B8));
        assert!(e8 < e4 && e4 < e2, "e2={e2} e4={e4} e8={e8}");
    }

    #[test]
    fn constant_group_exact() {
        let xs = vec![3.25f32; 10];
        let g = quantize_group(&xs, Bits::B2);
        let mut out = vec![0.0; 10];
        dequantize_group(&g, &mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn empty_group_ok() {
        let g = quantize_group(&[], Bits::B4);
        assert_eq!(g.n, 0);
        dequantize_group(&g, &mut []);
    }

    #[test]
    fn dequantize_at_matches_group() {
        let mut rng = Rng::new(35);
        let xs = rng.normal_vec(37, 1.0); // odd length exercises tail packing
        let g = quantize_group(&xs, Bits::B4);
        let mut out = vec![0.0; 37];
        dequantize_group(&g, &mut out);
        for i in 0..37 {
            assert_eq!(dequantize_at(&g, i), out[i]);
        }
    }

    #[test]
    fn channel_matrix_roundtrip_and_rowwise() {
        let mut rng = Rng::new(37);
        let (rows, cols, gs) = (50, 8, 16);
        let data = rng.normal_vec(rows * cols, 1.0);
        let q = ChannelQuantMatrix::quantize(&data, rows, cols, gs, Bits::B4);
        let full = q.dequantize();
        // 4-bit over ~4σ-wide groups: quantization noise ≈ step/√12 ≈ 0.08σ.
        assert!(rel_l2(&full, &data) < 0.12, "rel {}", rel_l2(&full, &data));
        let mut row = vec![0.0; cols];
        for r in [0usize, 15, 16, 49] {
            q.dequantize_row(r, &mut row);
            assert_eq!(&full[r * cols..(r + 1) * cols], row.as_slice());
        }
    }

    #[test]
    fn nbytes_reflects_bitwidth() {
        let data = vec![0.5f32; 128 * 4];
        let q2 = ChannelQuantMatrix::quantize(&data, 128, 4, 32, Bits::B2);
        let q8 = ChannelQuantMatrix::quantize(&data, 128, 4, 32, Bits::B8);
        assert!(q2.nbytes() < q8.nbytes());
        // 2-bit: 128 rows/col -> 32 bytes codes + 4 groups * 8 = 64B/col
        assert_eq!(q2.nbytes(), 4 * (32 + 4 * 8));
    }

    #[test]
    fn bits_from_bits_errors() {
        assert!(Bits::from_bits(3).is_err());
        assert_eq!(Bits::from_bits(4).unwrap(), Bits::B4);
    }
}

//! Appendable channel-wise group-quantized token store — the SALS value
//! cache (§5.1: "channel-wise group quantisation that mirrors the key-cache
//! setting", with a high-precision recent window following KIVI).
//!
//! Layout: tokens arrive as (dim,)-rows. The newest `window` tokens stay in
//! fp32 (the high-precision window); once `group` tokens age out of the
//! window they are quantized **per channel** (each channel's group of
//! `group` consecutive token values shares one scale/zero pair).
//!
//! Storage layout (§Perf L3 iteration 2): frozen groups are flat pages —
//! one contiguous nibble/crumb code buffer in row-major (token, channel)
//! order plus per-channel scale/zero arrays. Dequantizing a row is then a
//! single unit-stride scan; the original per-channel `QuantGroup` objects
//! cost one heap indirection per *element* and dominated the SALS decode
//! profile (see EXPERIMENTS.md §Perf).
//!
//! Dequantization dispatches through [`crate::tensor::simd`] (§Perf L6):
//! the nibble/crumb unpack and the per-channel scale/zero affine run in
//! vector lanes on AVX2/NEON hosts, and the fused
//! [`TokenQuantStore::dequant_matmul_acc`] entry points consume pages as
//! codes+params directly inside the attention PV stage, so quantized
//! value rows never round-trip through an fp32 staging panel.

use super::Bits;
use crate::tensor::simd;
use std::sync::Arc;

/// One frozen page: `group` tokens × `dim` channels.
#[derive(Clone, Debug)]
struct Page {
    /// Packed codes, row-major (token-within-group, channel).
    codes: Vec<u8>,
    /// Per-channel affine params.
    scale: Vec<f32>,
    zero: Vec<f32>,
}

fn page_bytes(p: &Page) -> usize {
    p.codes.len() + 4 * (p.scale.len() + p.zero.len())
}

/// An immutable, refcounted frozen-prefix capture of a
/// [`TokenQuantStore`]: the frozen pages behind an `Arc` (adopters share
/// them by reference — prefix-reuse's copy-on-write boundary for the
/// value cache) plus a copy of the fp32 tail, which stays private per
/// adopter because appends mutate it in place.
#[derive(Clone, Debug)]
pub struct QuantSnapshot {
    pages: Arc<Vec<Page>>,
    frozen: usize,
    tail: Vec<f32>,
    len: usize,
}

impl QuantSnapshot {
    /// Tokens captured (frozen + fp32 tail).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Resident bytes of the refcount-shared portion (the frozen pages).
    /// The fp32 tail is copied per adopter and is *not* shared.
    pub fn shared_bytes(&self) -> usize {
        self.pages.iter().map(page_bytes).sum()
    }
}

/// Appendable quantized token store with an fp32 recent window.
#[derive(Clone, Debug)]
pub struct TokenQuantStore {
    pub dim: usize,
    pub bits: Bits,
    pub group: usize,
    pub window: usize,
    /// Adopted frozen-prefix pages, shared by reference with the
    /// sequence(s) this store forked from. Never mutated; private pages
    /// in `pages` logically follow them.
    shared: Option<Arc<Vec<Page>>>,
    /// Private frozen pages appended past the shared prefix.
    pages: Vec<Page>,
    /// Tokens in the quantized region (== (shared + private pages) * group).
    frozen: usize,
    /// fp32 tail: tokens [frozen, len) row-major (len-frozen, dim).
    tail: Vec<f32>,
    len: usize,
}

impl TokenQuantStore {
    pub fn new(dim: usize, bits: Bits, group: usize, window: usize) -> TokenQuantStore {
        assert!(group > 0);
        TokenQuantStore {
            dim,
            bits,
            group,
            window,
            shared: None,
            pages: Vec::new(),
            frozen: 0,
            tail: Vec::new(),
            len: 0,
        }
    }

    /// Frozen page `p` (0-based over shared-then-private order).
    fn page(&self, p: usize) -> &Page {
        let ns = self.shared.as_ref().map_or(0, |s| s.len());
        if p < ns {
            &self.shared.as_ref().unwrap()[p]
        } else {
            &self.pages[p - ns]
        }
    }

    /// All frozen pages in token order: adopted shared prefix first,
    /// then private pages.
    fn pages_iter(&self) -> impl Iterator<Item = &Page> {
        self.shared.iter().flat_map(|s| s.iter()).chain(self.pages.iter())
    }

    /// Capture the current store as an immutable snapshot a fresh store
    /// can [`TokenQuantStore::adopt`]. When the store is itself a pure
    /// adopter (no private pages yet) the existing `Arc` is reused, so
    /// re-forking an adopted prefix costs no page copies.
    pub fn snapshot(&self) -> QuantSnapshot {
        let pages = match (&self.shared, self.pages.is_empty()) {
            (Some(s), true) => Arc::clone(s),
            _ => Arc::new(self.pages_iter().cloned().collect()),
        };
        QuantSnapshot { pages, frozen: self.frozen, tail: self.tail.clone(), len: self.len }
    }

    /// Adopt a snapshot into an empty store: frozen pages by reference,
    /// fp32 tail by copy. Subsequent appends are private — freezes past
    /// the boundary push onto `pages`, never touching the shared `Arc`
    /// (copy-on-write at page granularity). Reads, `nbytes()`, and
    /// traffic meters are bit-identical to a cold store fed the same
    /// rows.
    pub fn adopt(&mut self, snap: &QuantSnapshot) {
        assert!(self.is_empty(), "adopt requires an empty store");
        assert_eq!(
            snap.frozen,
            snap.pages.len() * self.group,
            "snapshot frozen count disagrees with page granularity"
        );
        if let Some(p) = snap.pages.first() {
            assert_eq!(p.scale.len(), self.dim, "snapshot dim mismatch");
        }
        self.shared = Some(Arc::clone(&snap.pages));
        self.frozen = snap.frozen;
        self.tail = snap.tail.clone();
        self.len = snap.len;
    }

    /// Resident bytes held by reference to an adopted shared prefix
    /// (0 for cold stores). Included in [`TokenQuantStore::nbytes`];
    /// pool accounting charges these once across all adopters.
    pub fn shared_bytes(&self) -> usize {
        self.shared.as_ref().map_or(0, |s| s.iter().map(page_bytes).sum())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Tokens currently held in fp32 (recent window + not-yet-full group).
    pub fn fp32_len(&self) -> usize {
        self.len - self.frozen
    }

    /// Append one token row; freezes (quantizes) aged-out full groups.
    pub fn append(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        self.tail.extend_from_slice(row);
        self.len += 1;
        // Freeze while a full group sits entirely outside the window.
        while self.len - self.frozen >= self.window + self.group {
            self.freeze_group();
        }
    }

    fn freeze_group(&mut self) {
        let g = self.group;
        let d = self.dim;
        let levels = (self.bits.levels() - 1) as f32;
        let per = self.bits.per_byte();
        let b = self.bits.bits();
        let mask = (self.bits.levels() - 1) as u8;

        let mut scale = vec![1.0f32; d];
        let mut zero = vec![0.0f32; d];
        // Per-channel min/max over the oldest g tail tokens.
        for c in 0..d {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for t in 0..g {
                let x = self.tail[t * d + c];
                lo = lo.min(x);
                hi = hi.max(x);
            }
            zero[c] = lo;
            scale[c] = if hi > lo { (hi - lo) / levels } else { 1.0 };
        }
        // Pack codes row-major (token, channel) — unit-stride reads later.
        let mut codes = vec![0u8; (g * d).div_ceil(per)];
        for t in 0..g {
            for c in 0..d {
                let i = t * d + c;
                let x = self.tail[i];
                let code =
                    (((x - zero[c]) / scale[c]).round() as i64).clamp(0, levels as i64) as u8 & mask;
                codes[i / per] |= code << ((i % per) as u32 * b);
            }
        }
        self.pages.push(Page { codes, scale, zero });
        self.tail.drain(..g * d);
        self.frozen += g;
    }

    /// Dequantize token `i` into `out`. Recent/fp32 tokens are exact.
    pub fn get(&self, i: usize, out: &mut [f32]) {
        assert!(i < self.len, "token {i} out of range {}", self.len);
        assert_eq!(out.len(), self.dim);
        if i >= self.frozen {
            let t = i - self.frozen;
            out.copy_from_slice(&self.tail[t * self.dim..(t + 1) * self.dim]);
            return;
        }
        self.unpack_page_rows(self.page(i / self.group), std::iter::once(i), out);
    }

    /// Dequantize the selected rows of one frozen page: `idx` yields
    /// absolute token indices, all inside `page`; `out` is (n, dim) for n
    /// yielded rows. The bits dispatch and the page's scale/zero borrows
    /// are hoisted outside the row loop — the per-page setup happens once
    /// per page, not once per row.
    fn unpack_page_rows(&self, page: &Page, idx: impl Iterator<Item = usize>, out: &mut [f32]) {
        self.unpack_page_rows_cols(page, idx, 0, self.dim, out);
    }

    /// Column-sliced [`TokenQuantStore::unpack_page_rows`]: dequantize only
    /// channels `c0..c1` of each selected row into `out` ((n, c1-c0)
    /// row-major). Codes are packed row-major (token, channel), so a
    /// channel range is a contiguous bit-run within each row — the fused
    /// decode kernel uses this to fill per-KV-head value tiles without
    /// unpacking the other heads' channels.
    fn unpack_page_rows_cols(
        &self,
        page: &Page,
        idx: impl Iterator<Item = usize>,
        c0: usize,
        c1: usize,
        out: &mut [f32],
    ) {
        let d = self.dim;
        let w = c1 - c0;
        let (scale, zero) = (&page.scale[c0..c1], &page.zero[c0..c1]);
        // Row dequant dispatches through the SIMD tier: a channel range is
        // one contiguous code run, so each row is a single vector unpack +
        // affine scan (exact class — bit-identical across tiers).
        match self.bits {
            Bits::B8 => {
                for (row, j) in idx.enumerate() {
                    let base = (j % self.group) * d;
                    let codes = &page.codes[base + c0..base + c1];
                    simd::dequant_b8(codes, scale, zero, &mut out[row * w..(row + 1) * w]);
                }
            }
            Bits::B4 => {
                for (row, j) in idx.enumerate() {
                    let base = (j % self.group) * d;
                    let orow = &mut out[row * w..(row + 1) * w];
                    simd::dequant_b4(&page.codes, base + c0, scale, zero, orow);
                }
            }
            Bits::B2 => {
                for (row, j) in idx.enumerate() {
                    let base = (j % self.group) * d;
                    let orow = &mut out[row * w..(row + 1) * w];
                    simd::dequant_b2(&page.codes, base + c0, scale, zero, orow);
                }
            }
        }
    }

    /// Page-coherent gather: dequantize rows `sorted_idx` (strictly
    /// increasing) into `out` ((sorted_idx.len(), dim) row-major).
    /// Equivalent to one [`TokenQuantStore::get`] per row, but selected
    /// tokens are walked **grouped by quant page**, so each touched page's
    /// scale/zero and bit-unpack setup is hoisted across all of its
    /// selected rows and the fp32 tail is copied directly — the decode-time
    /// value-read path of SALS (sorted critical selections) and KIVI.
    pub fn gather_rows(&self, sorted_idx: &[usize], out: &mut [f32]) {
        self.gather_rows_cols(sorted_idx, 0, self.dim, out);
    }

    /// Column-sliced [`TokenQuantStore::gather_rows`]: dequantize only
    /// channels `c0..c1` of rows `sorted_idx` into `out`
    /// ((sorted_idx.len(), c1-c0) row-major), with the same page-coherent
    /// walk. This is the fused decode kernel's per-KV-head value-tile
    /// read: each KV head's worker pulls exactly its `head_dim` channel
    /// slice, so summing the per-head walks over all heads streams the
    /// same payload and per-page param bytes as one full-width gather of
    /// the same index range — callers meter with
    /// [`TokenQuantStore::gather_read_bytes`] per gathered range (per
    /// tile for the fused kernel, whose tiles each re-touch boundary
    /// pages' params).
    pub fn gather_rows_cols(&self, sorted_idx: &[usize], c0: usize, c1: usize, out: &mut [f32]) {
        let d = self.dim;
        assert!(c0 < c1 && c1 <= d, "channel slice {c0}..{c1} out of dim {d}");
        let w = c1 - c0;
        assert_eq!(out.len(), sorted_idx.len() * w);
        debug_assert!(
            sorted_idx.windows(2).all(|x| x[0] < x[1]),
            "gather_rows needs strictly increasing indices"
        );
        let mut i = 0;
        while i < sorted_idx.len() {
            let j = sorted_idx[i];
            assert!(j < self.len, "token {j} out of range {}", self.len);
            if j >= self.frozen {
                // fp32 tail — sorted indices mean everything from here on
                // is a tail row; copy them in one run.
                for (row, &jt) in sorted_idx[i..].iter().enumerate() {
                    let t = jt - self.frozen;
                    out[(i + row) * w..(i + row + 1) * w]
                        .copy_from_slice(&self.tail[t * d + c0..t * d + c1]);
                }
                return;
            }
            let p = j / self.group;
            let mut e = i + 1;
            while e < sorted_idx.len() && sorted_idx[e] / self.group == p {
                e += 1;
            }
            self.unpack_page_rows_cols(
                self.page(p),
                sorted_idx[i..e].iter().copied(),
                c0,
                c1,
                &mut out[i * w..e * w],
            );
            i = e;
        }
    }

    /// Fused dequant-GEMV over a page-coherent row gather:
    /// `acc[g] += Σ_r probs[g·n + r] · dequant(row sorted_idx[r])[c0..c1]`
    /// for each of the `m` coefficient rows — the attention PV stage
    /// consuming the value store **as codes**, so quantized rows never
    /// round-trip through an fp32 staging panel. `probs` is
    /// (m, sorted_idx.len()) row-major; `acc` is (m, c1-c0) and is
    /// accumulated onto (callers zero it or carry a running partial).
    ///
    /// Bit-exactness contract: this produces exactly the floats of
    /// [`TokenQuantStore::gather_rows_cols`] into a panel followed by
    /// `matmul_acc(probs, panel, acc)` — per `acc` row the gathered rows
    /// are accumulated in the same ascending order, and the fused
    /// dequant-axpy kernels are bit-identical to dequant-then-axpy — so
    /// swapping the staged PV for this one cannot change attention
    /// outputs. Byte metering is also unchanged:
    /// [`TokenQuantStore::gather_read_bytes`] describes what is
    /// *streamed* (payload + per-page params), which is identical either
    /// way; only the fp32 staging traffic disappears.
    ///
    /// `row_buf` is retained scratch for the single dequantized row shared
    /// across `m > 1` coefficient rows (never a whole panel); with
    /// `m == 1` frozen rows stream straight from codes into `acc`.
    #[allow(clippy::too_many_arguments)]
    pub fn dequant_matmul_acc(
        &self,
        sorted_idx: &[usize],
        c0: usize,
        c1: usize,
        probs: &[f32],
        m: usize,
        row_buf: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        let d = self.dim;
        assert!(c0 < c1 && c1 <= d, "channel slice {c0}..{c1} out of dim {d}");
        let w = c1 - c0;
        let n = sorted_idx.len();
        assert_eq!(probs.len(), m * n);
        assert_eq!(acc.len(), m * w);
        let mut i = 0;
        while i < n {
            let j = sorted_idx[i];
            assert!(j < self.len, "token {j} out of range {}", self.len);
            if j >= self.frozen {
                // fp32 tail — sorted indices mean everything from here on
                // is a tail row; stream them as plain axpys.
                for (r, &jt) in sorted_idx[i..].iter().enumerate() {
                    let t = jt - self.frozen;
                    let row = &self.tail[t * d + c0..t * d + c1];
                    for g in 0..m {
                        simd::axpy(probs[g * n + i + r], row, &mut acc[g * w..(g + 1) * w]);
                    }
                }
                return;
            }
            let p = j / self.group;
            let mut e = i + 1;
            while e < n && sorted_idx[e] / self.group == p {
                e += 1;
            }
            let rows = sorted_idx[i..e].iter().copied().enumerate().map(|(r, j)| (i + r, j));
            self.dequant_page_rows_acc(self.page(p), rows, c0, c1, probs, m, n, row_buf, acc);
            i = e;
        }
    }

    /// [`TokenQuantStore::dequant_matmul_acc`] over the **whole** store —
    /// the dense-attention (KIVI) PV path. Frozen pages stream
    /// sequentially with their setup hoisted, the fp32 tail follows;
    /// `probs` column `j` is absolute token index `j` (`probs` is
    /// (m, len) row-major). Same bit-exactness contract, with
    /// [`TokenQuantStore::read_all`] + `matmul_acc` as the staged
    /// reference and [`TokenQuantStore::read_all_bytes`] as the
    /// unchanged traffic meter.
    pub fn dequant_matmul_acc_all(
        &self,
        c0: usize,
        c1: usize,
        probs: &[f32],
        m: usize,
        row_buf: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        let d = self.dim;
        assert!(c0 < c1 && c1 <= d, "channel slice {c0}..{c1} out of dim {d}");
        let w = c1 - c0;
        let n = self.len;
        assert_eq!(probs.len(), m * n);
        assert_eq!(acc.len(), m * w);
        let g = self.group;
        for (p, page) in self.pages_iter().enumerate() {
            let lo = p * g;
            let rows = (lo..lo + g).map(|j| (j, j));
            self.dequant_page_rows_acc(page, rows, c0, c1, probs, m, n, row_buf, acc);
        }
        for t in 0..n - self.frozen {
            let row = &self.tail[t * d + c0..t * d + c1];
            let col = self.frozen + t;
            for gq in 0..m {
                simd::axpy(probs[gq * n + col], row, &mut acc[gq * w..(gq + 1) * w]);
            }
        }
    }

    /// Per-page worker of the fused dequant-GEMV walks: accumulate the
    /// yielded `(probs column, absolute token)` rows of `page` onto `acc`.
    /// `m == 1` fuses dequant into the axpy (codes → acc, no staging at
    /// all); `m > 1` dequantizes each row once into `row_buf` and shares
    /// it across the coefficient rows.
    #[allow(clippy::too_many_arguments)]
    fn dequant_page_rows_acc(
        &self,
        page: &Page,
        rows: impl Iterator<Item = (usize, usize)>,
        c0: usize,
        c1: usize,
        probs: &[f32],
        m: usize,
        n: usize,
        row_buf: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        let d = self.dim;
        let w = c1 - c0;
        let (scale, zero) = (&page.scale[c0..c1], &page.zero[c0..c1]);
        for (col, j) in rows {
            let base = (j % self.group) * d;
            if m == 1 {
                let p = probs[col];
                match self.bits {
                    Bits::B8 => {
                        let codes = &page.codes[base + c0..base + c1];
                        simd::dequant_axpy_b8(p, codes, scale, zero, acc);
                    }
                    Bits::B4 => simd::dequant_axpy_b4(p, &page.codes, base + c0, scale, zero, acc),
                    Bits::B2 => simd::dequant_axpy_b2(p, &page.codes, base + c0, scale, zero, acc),
                }
            } else {
                row_buf.resize(w, 0.0);
                match self.bits {
                    Bits::B8 => {
                        let codes = &page.codes[base + c0..base + c1];
                        simd::dequant_b8(codes, scale, zero, row_buf);
                    }
                    Bits::B4 => simd::dequant_b4(&page.codes, base + c0, scale, zero, row_buf),
                    Bits::B2 => simd::dequant_b2(&page.codes, base + c0, scale, zero, row_buf),
                }
                for g in 0..m {
                    simd::axpy(probs[g * n + col], row_buf, &mut acc[g * w..(g + 1) * w]);
                }
            }
        }
    }

    /// Dequantize the whole store into `out` ((len, dim) row-major): pages
    /// stream sequentially with their setup hoisted, the fp32 tail is
    /// copied directly — the dense-attention (KIVI) read path.
    pub fn read_all(&self, out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(out.len(), self.len * d);
        let g = self.group;
        for (p, page) in self.pages_iter().enumerate() {
            // All `group` rows of the page, in token order: codes are
            // row-major (token, channel), so this is one linear scan.
            let lo = p * g;
            self.unpack_page_rows(page, lo..lo + g, &mut out[lo * d..(lo + g) * d]);
        }
        out[self.frozen * d..self.len * d].copy_from_slice(&self.tail);
    }

    /// Bytes needed to read token `i` from the store (for traffic metering):
    /// packed codes + its group's scale/zero amortized, or fp32 row.
    pub fn row_read_bytes(&self, i: usize) -> usize {
        if i >= self.frozen {
            self.dim * 4
        } else {
            // dim channels × (bits/8 payload + amortized params)
            self.dim * self.bits.bits() as usize / 8 + (self.dim * 8).div_ceil(self.group)
        }
    }

    /// Traffic cost of a [`TokenQuantStore::gather_rows`] over `sorted_idx`:
    /// per-row packed payload (or fp32 tail row) plus each **touched page's**
    /// scale/zero params charged once per page — the bytes the page-coherent
    /// walk actually streams. [`TokenQuantStore::row_read_bytes`] amortizes
    /// params per row, which misprices sparse selections: a selection
    /// touching one row per page streams the full params for every page.
    pub fn gather_read_bytes(&self, sorted_idx: &[usize]) -> usize {
        let payload = self.dim * self.bits.bits() as usize / 8;
        let params = self.dim * 2 * 4; // per-channel scale + zero, fp32
        let mut bytes = 0;
        let mut last_page = usize::MAX;
        for &j in sorted_idx {
            if j >= self.frozen {
                bytes += self.dim * 4;
            } else {
                bytes += payload;
                let p = j / self.group;
                if p != last_page {
                    bytes += params;
                    last_page = p;
                }
            }
        }
        bytes
    }

    /// Traffic cost of [`TokenQuantStore::read_all`]: every page's packed
    /// codes and params once, plus the fp32 tail.
    pub fn read_all_bytes(&self) -> usize {
        let pages: usize = self.pages_iter().map(page_bytes).sum();
        pages + self.tail.len() * 4
    }

    /// Asymptotic resident bytes per *frozen* token: the packed payload
    /// plus the page's per-channel scale/zero pair amortized over the
    /// group. Footprint estimation (the marginal rate a long sequence
    /// converges to); [`TokenQuantStore::nbytes`] meters the live store.
    pub fn frozen_row_bytes(&self) -> usize {
        self.dim * self.bits.bits() as usize / 8 + (self.dim * 8).div_ceil(self.group)
    }

    /// Expected steady-state *excess* of the fp32 tail over the frozen
    /// rate: the tail holds `window..window+group` tokens (the window plus
    /// a group still filling), each resident as `dim` fp32s instead of a
    /// frozen row. Charged as a fixed footprint term so an affine
    /// `fixed + rate·tokens` model tracks `nbytes()` at any phase of the
    /// freeze cycle; the midpoint (`window + group/2`) makes the model
    /// exact mid-phase and off by at most `±group/2` tokens' excess.
    pub fn tail_excess_bytes(&self) -> usize {
        (self.window + self.group / 2) * (self.dim * 4).saturating_sub(self.frozen_row_bytes())
    }

    /// Resident bytes of the whole store, adopted shared pages included
    /// — an adopter's `nbytes()` is bit-identical to a cold store's, so
    /// footprint models need no reuse-awareness; the engine subtracts
    /// [`TokenQuantStore::shared_bytes`] when charging the pool.
    pub fn nbytes(&self) -> usize {
        let packed: usize = self.pages_iter().map(page_bytes).sum();
        packed + self.tail.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn recent_tokens_exact() {
        let mut st = TokenQuantStore::new(4, Bits::B2, 8, 16);
        let mut rng = Rng::new(61);
        let rows: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(4, 1.0)).collect();
        for r in &rows {
            st.append(r);
        }
        let mut out = vec![0.0; 4];
        // Newest 16 tokens must be bit-exact.
        for i in 40 - 16..40 {
            st.get(i, &mut out);
            assert_eq!(out, rows[i][..], "row {i}");
        }
    }

    #[test]
    fn frozen_tokens_approximate() {
        let mut st = TokenQuantStore::new(8, Bits::B4, 8, 8);
        let mut rng = Rng::new(63);
        let rows: Vec<Vec<f32>> = (0..64).map(|_| rng.normal_vec(8, 1.0)).collect();
        for r in &rows {
            st.append(r);
        }
        assert!(st.fp32_len() < 8 + 8 + 1);
        let mut out = vec![0.0; 8];
        let mut errs = Vec::new();
        for (i, r) in rows.iter().enumerate().take(st.len() - st.fp32_len()) {
            st.get(i, &mut out);
            errs.push(rel_l2(&out, r));
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean > 0.0 && mean < 0.2, "mean rel err {mean}");
    }

    #[test]
    fn quant_error_bounded_by_half_step_per_channel() {
        let mut st = TokenQuantStore::new(6, Bits::B4, 4, 4);
        let mut rng = Rng::new(69);
        let rows: Vec<Vec<f32>> = (0..32).map(|_| rng.normal_vec(6, 2.0)).collect();
        for r in &rows {
            st.append(r);
        }
        let mut out = vec![0.0; 6];
        for (i, r) in rows.iter().enumerate().take(st.frozen) {
            st.get(i, &mut out);
            let page = &st.pages[i / st.group];
            for c in 0..6 {
                assert!(
                    (out[c] - r[c]).abs() <= page.scale[c] * 0.5 + 1e-5,
                    "row {i} ch {c}: {} vs {}",
                    out[c],
                    r[c]
                );
            }
        }
    }

    #[test]
    fn freeze_boundary_counts() {
        let mut st = TokenQuantStore::new(2, Bits::B4, 4, 4);
        for i in 0..12 {
            st.append(&[i as f32, -(i as f32)]);
        }
        // len 12, window 4, group 4 -> frozen groups while fp32_len >= 8.
        assert_eq!(st.len(), 12);
        assert!(st.fp32_len() >= 4 && st.fp32_len() < 8);
        assert_eq!(st.frozen % 4, 0);
    }

    #[test]
    fn quantized_rows_cost_fewer_bytes() {
        let mut st = TokenQuantStore::new(64, Bits::B2, 16, 16);
        let mut rng = Rng::new(65);
        for _ in 0..128 {
            st.append(&rng.normal_vec(64, 1.0));
        }
        assert!(st.row_read_bytes(0) < st.row_read_bytes(st.len() - 1));
        // 2-bit: 64ch × 2/8 = 16B payload + 32B params amortized
        assert_eq!(st.row_read_bytes(0), 64 / 4 + (64 * 8) / 16);
    }

    #[test]
    fn affine_rate_tracks_live_nbytes() {
        // fixed (tail excess) + frozen rate · len must stay within the
        // ±group/2-token phase error of the metered nbytes(), at every
        // phase of the freeze cycle.
        // (The model is asymptotic: below window+group tokens nothing is
        // frozen yet and the fixed term over-charges — fine for admission,
        // so the bound is asserted from the first freeze onward.)
        let mut st = TokenQuantStore::new(32, Bits::B4, 16, 24);
        let mut rng = Rng::new(71);
        let phase_slack = (st.group / 2) * (st.dim * 4 - st.frozen_row_bytes());
        let steady = st.window + st.group;
        for len in 1..=200 {
            st.append(&rng.normal_vec(32, 1.0));
            if len < steady {
                continue;
            }
            let est = st.tail_excess_bytes() + st.frozen_row_bytes() * len;
            let live = st.nbytes();
            let err = est.abs_diff(live);
            assert!(err <= phase_slack, "len {len}: est {est} vs live {live} (err {err})");
        }
    }

    #[test]
    fn gather_rows_matches_per_row_get() {
        for bits in [Bits::B2, Bits::B4, Bits::B8] {
            let mut st = TokenQuantStore::new(6, bits, 8, 12);
            let mut rng = Rng::new(73);
            for _ in 0..70 {
                st.append(&rng.normal_vec(6, 1.0));
            }
            // Mixed selection: page-interior runs, page boundaries, a page
            // with a single row, and fp32 tail rows.
            let idx = [0usize, 1, 7, 8, 15, 16, 17, 30, 55, 60, 68, 69];
            let mut gathered = vec![0.0f32; idx.len() * 6];
            st.gather_rows(&idx, &mut gathered);
            let mut row = vec![0.0f32; 6];
            for (t, &j) in idx.iter().enumerate() {
                st.get(j, &mut row);
                assert_eq!(&gathered[t * 6..(t + 1) * 6], &row[..], "{bits:?} row {j}");
            }
        }
    }

    #[test]
    fn gather_rows_cols_matches_full_width_slices() {
        // Every (c0, c1) slice must equal the corresponding columns of the
        // full-width gather, for every bit width, across pages + tail.
        for bits in [Bits::B2, Bits::B4, Bits::B8] {
            let mut st = TokenQuantStore::new(8, bits, 8, 12);
            let mut rng = Rng::new(77);
            for _ in 0..70 {
                st.append(&rng.normal_vec(8, 1.0));
            }
            let idx = [0usize, 1, 7, 8, 15, 30, 55, 60, 68, 69];
            let mut full = vec![0.0f32; idx.len() * 8];
            st.gather_rows(&idx, &mut full);
            for (c0, c1) in [(0usize, 4usize), (4, 8), (2, 7), (0, 8)] {
                let w = c1 - c0;
                let mut sliced = vec![0.0f32; idx.len() * w];
                st.gather_rows_cols(&idx, c0, c1, &mut sliced);
                for (t, _) in idx.iter().enumerate() {
                    assert_eq!(
                        &sliced[t * w..(t + 1) * w],
                        &full[t * 8 + c0..t * 8 + c1],
                        "{bits:?} slice {c0}..{c1} row {t}"
                    );
                }
            }
        }
    }

    #[test]
    fn dequant_matmul_acc_bit_matches_staged_gather() {
        use crate::tensor::ops::matmul_acc;
        // The fused path must be bit-identical to gather-then-matmul_acc
        // for every bit width, coefficient-row count, and channel slice,
        // over a selection crossing pages, page boundaries, and the tail.
        for bits in [Bits::B2, Bits::B4, Bits::B8] {
            let mut st = TokenQuantStore::new(8, bits, 8, 12);
            let mut rng = Rng::new(83);
            for _ in 0..70 {
                st.append(&rng.normal_vec(8, 1.0));
            }
            let idx = [0usize, 1, 7, 8, 15, 30, 55, 60, 68, 69];
            let n = idx.len();
            for m in [1usize, 3] {
                for (c0, c1) in [(0usize, 4usize), (4, 8), (2, 7), (0, 8)] {
                    let w = c1 - c0;
                    let probs = rng.normal_vec(m * n, 1.0);
                    let mut panel = vec![0.0f32; n * w];
                    st.gather_rows_cols(&idx, c0, c1, &mut panel);
                    // Nonzero starting acc: both paths must accumulate on
                    // top, not overwrite.
                    let start = rng.normal_vec(m * w, 1.0);
                    let mut want = start.clone();
                    matmul_acc(&probs, &panel, &mut want, m, n, w);
                    let mut got = start;
                    let mut row_buf = Vec::new();
                    st.dequant_matmul_acc(&idx, c0, c1, &probs, m, &mut row_buf, &mut got);
                    assert_eq!(got, want, "{bits:?} m={m} slice {c0}..{c1}");
                }
            }
        }
    }

    #[test]
    fn dequant_matmul_acc_all_bit_matches_staged_read_all() {
        use crate::tensor::ops::matmul_acc;
        for bits in [Bits::B2, Bits::B4, Bits::B8] {
            let mut st = TokenQuantStore::new(6, bits, 4, 6);
            let mut rng = Rng::new(85);
            for _ in 0..37 {
                st.append(&rng.normal_vec(6, 1.0));
            }
            let n = st.len();
            let mut full = vec![0.0f32; n * 6];
            st.read_all(&mut full);
            for m in [1usize, 4] {
                for (c0, c1) in [(0usize, 3usize), (3, 6), (0, 6)] {
                    let w = c1 - c0;
                    let probs = rng.normal_vec(m * n, 1.0);
                    let mut sliced = vec![0.0f32; n * w];
                    for r in 0..n {
                        sliced[r * w..(r + 1) * w].copy_from_slice(&full[r * 6 + c0..r * 6 + c1]);
                    }
                    let mut want = vec![0.0f32; m * w];
                    matmul_acc(&probs, &sliced, &mut want, m, n, w);
                    let mut got = vec![0.0f32; m * w];
                    let mut row_buf = Vec::new();
                    st.dequant_matmul_acc_all(c0, c1, &probs, m, &mut row_buf, &mut got);
                    assert_eq!(got, want, "{bits:?} m={m} slice {c0}..{c1}");
                }
            }
        }
    }

    #[test]
    fn read_all_matches_per_row_get() {
        let mut st = TokenQuantStore::new(5, Bits::B4, 4, 6);
        let mut rng = Rng::new(79);
        for _ in 0..37 {
            st.append(&rng.normal_vec(5, 1.0));
        }
        let mut all = vec![0.0f32; 37 * 5];
        st.read_all(&mut all);
        let mut row = vec![0.0f32; 5];
        for j in 0..37 {
            st.get(j, &mut row);
            assert_eq!(&all[j * 5..(j + 1) * 5], &row[..], "row {j}");
        }
    }

    #[test]
    fn gather_read_bytes_charges_params_per_page() {
        let mut st = TokenQuantStore::new(32, Bits::B4, 16, 16);
        let mut rng = Rng::new(81);
        for _ in 0..128 {
            st.append(&rng.normal_vec(32, 1.0));
        }
        let payload = 32 * 4 / 8; // 16 B/row
        let params = 32 * 8; // 256 B/page (scale + zero)
        // Two rows in one page: params once.
        assert_eq!(st.gather_read_bytes(&[0, 1]), 2 * payload + params);
        // Two rows in two pages: params twice.
        assert_eq!(st.gather_read_bytes(&[0, 16]), 2 * payload + 2 * params);
        // Tail row: plain fp32.
        assert_eq!(st.gather_read_bytes(&[127]), 32 * 4);
        // read_all cost equals the resident store size.
        assert_eq!(st.read_all_bytes(), st.nbytes());
    }

    #[test]
    fn snapshot_adopt_matches_cold_store() {
        let mut rng = Rng::new(91);
        let rows: Vec<Vec<f32>> = (0..53).map(|_| rng.normal_vec(6, 1.0)).collect();
        let split = 29;
        let mut donor = TokenQuantStore::new(6, Bits::B4, 4, 6);
        for r in &rows[..split] {
            donor.append(r);
        }
        let snap = donor.snapshot();
        let mut forked = TokenQuantStore::new(6, Bits::B4, 4, 6);
        forked.adopt(&snap);
        assert!(forked.shared_bytes() > 0);
        let mut cold = TokenQuantStore::new(6, Bits::B4, 4, 6);
        for r in &rows {
            cold.append(r);
        }
        for r in &rows[split..] {
            forked.append(r);
        }
        assert_eq!(forked.len(), cold.len());
        assert_eq!(forked.frozen, cold.frozen);
        assert_eq!(forked.nbytes(), cold.nbytes());
        assert_eq!(forked.read_all_bytes(), cold.read_all_bytes());
        let (mut a, mut b) = (vec![0.0f32; 53 * 6], vec![0.0f32; 53 * 6]);
        cold.read_all(&mut a);
        forked.read_all(&mut b);
        assert_eq!(a, b, "adopted store must read bit-identically to cold");
        // The donor keeps appending privately past the fork; its shared
        // pages are untouched and it stays bit-identical too.
        for r in &rows[split..] {
            donor.append(r);
        }
        let mut c = vec![0.0f32; 53 * 6];
        donor.read_all(&mut c);
        assert_eq!(c, a);
        // Re-forking a pure adopter reuses the Arc (no page copies).
        let refork = {
            let mut early = TokenQuantStore::new(6, Bits::B4, 4, 6);
            early.adopt(&snap);
            early.snapshot()
        };
        assert_eq!(refork.shared_bytes(), snap.shared_bytes());
        assert_eq!(refork.len(), snap.len());
    }

    #[test]
    fn nbytes_smaller_than_fp32() {
        let mut st = TokenQuantStore::new(32, Bits::B2, 16, 16);
        let mut rng = Rng::new(67);
        for _ in 0..512 {
            st.append(&rng.normal_vec(32, 1.0));
        }
        assert!(st.nbytes() < 512 * 32 * 4 / 4, "nbytes {}", st.nbytes());
    }
}

//! Multi-turn chat workload: conversations whose every turn re-sends the
//! whole transcript so far plus a new user message — the canonical
//! shared-prefix traffic pattern prefix reuse exists for (each turn's
//! prompt is a strict extension of the previous turn's prompt ++ answer).
//!
//! The driver threads the turns through a [`Router`] with session
//! affinity (a conversation's warm prefix cache lives on one replica, so
//! bouncing turns across replicas would forfeit every adoption) and calls
//! [`Router::end_session`] when a conversation closes, so affinity
//! entries do not accumulate forever.

use crate::coordinator::{Engine, GenParams, Request, Router};
use crate::util::rng::Rng;

/// Shape of a synthetic chat workload.
#[derive(Clone, Debug)]
pub struct ChatSpec {
    pub n_sessions: usize,
    pub turns_per_session: usize,
    /// Tokens in the opening user message (the eventual shared prefix —
    /// chunk-aligned openings publish cleanly).
    pub first_turn_tokens: usize,
    /// Tokens each later user message appends.
    pub turn_tokens: usize,
    pub max_new_tokens: usize,
    pub vocab: usize,
    pub seed: u64,
}

/// Aggregate outcome of a chat run.
#[derive(Clone, Debug, Default)]
pub struct ChatStats {
    pub turns_completed: usize,
    /// Prompt tokens submitted across all turns (the transcript re-send
    /// traffic reuse is supposed to absorb).
    pub prompt_tokens: usize,
    /// Summed over replicas after the run.
    pub prefill_tokens_avoided: usize,
    pub prefix_adoptions: usize,
    /// Replica each session was pinned to (index = session).
    pub session_replica: Vec<usize>,
    /// Per-session final transcripts (prompt ++ every answer), for
    /// cross-run comparisons.
    pub transcripts: Vec<Vec<usize>>,
}

/// Drive a chat workload over engine replicas through the router, one
/// turn round at a time (every live session advances a turn, then its
/// replica runs to completion). Returns per-session transcripts and the
/// summed reuse metrics.
pub fn run_chat(spec: &ChatSpec, replicas: &mut [Engine], router: &mut Router) -> ChatStats {
    assert!(!replicas.is_empty() && router.replicas() == replicas.len());
    let mut rng = Rng::new(spec.seed);
    // A session's transcript: everything the model has seen + said; the
    // next turn's prompt is transcript ++ fresh user tokens.
    let mut transcripts: Vec<Vec<usize>> = (0..spec.n_sessions).map(|_| Vec::new()).collect();
    let mut stats = ChatStats {
        session_replica: vec![usize::MAX; spec.n_sessions],
        ..ChatStats::default()
    };
    let mut next_id = 0u64;
    for turn in 0..spec.turns_per_session {
        // (session, replica, dispatched request) in flight this round.
        let mut in_flight: Vec<(usize, usize, Request)> = Vec::new();
        for s in 0..spec.n_sessions {
            let user_tokens =
                if turn == 0 { spec.first_turn_tokens } else { spec.turn_tokens };
            for _ in 0..user_tokens {
                transcripts[s].push(rng.below(spec.vocab));
            }
            let req = Request::new(
                next_id,
                transcripts[s].clone(),
                GenParams { max_new_tokens: spec.max_new_tokens, stop_token: None },
            );
            next_id += 1;
            let r = router.route(&req, Some(s as u64));
            if stats.session_replica[s] == usize::MAX {
                stats.session_replica[s] = r;
            } else {
                assert_eq!(stats.session_replica[s], r, "affinity moved session {s}");
            }
            stats.prompt_tokens += req.prompt.len();
            replicas[r].submit(req.clone());
            in_flight.push((s, r, req));
        }
        for replica in replicas.iter_mut() {
            for resp in replica.run_to_completion() {
                let (s, r, req) =
                    in_flight.iter().find(|(_, _, rq)| rq.id == resp.id).expect("unknown id");
                transcripts[*s].extend_from_slice(&resp.tokens);
                router.complete(*r, req);
                stats.turns_completed += 1;
            }
        }
    }
    for s in 0..spec.n_sessions {
        router.end_session(s as u64);
    }
    for replica in replicas.iter() {
        stats.prefill_tokens_avoided += replica.metrics.prefill_tokens_avoided;
        stats.prefix_adoptions += replica.metrics.prefix_adoptions;
    }
    stats.transcripts = transcripts;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::coordinator::{EngineConfig, Policy};
    use crate::model::{BackendFactory, Model, ModelConfig, Weights};
    use std::sync::Arc;

    fn replicas(n: usize, reuse: bool) -> Vec<Engine> {
        (0..n)
            .map(|_| {
                let cfg = ModelConfig::tiny_mha(256);
                let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
                let shape = cfg.attn_shape();
                let factory: Box<BackendFactory> =
                    Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
                Engine::new(
                    model,
                    factory,
                    EngineConfig {
                        max_batch: 4,
                        prefill_chunk: 8,
                        page_bytes: 4096,
                        pool_budget: 1 << 26,
                        threads: 2,
                        prefix_reuse: reuse,
                    },
                )
            })
            .collect()
    }

    fn spec() -> ChatSpec {
        ChatSpec {
            n_sessions: 3,
            turns_per_session: 3,
            first_turn_tokens: 16,
            turn_tokens: 6,
            max_new_tokens: 4,
            vocab: 50,
            seed: 11,
        }
    }

    #[test]
    fn multi_turn_sessions_stay_pinned_and_complete() {
        let spec = spec();
        let mut engines = replicas(2, false);
        let mut router = Router::new(2, Policy::LeastLoaded);
        let stats = run_chat(&spec, &mut engines, &mut router);
        assert_eq!(stats.turns_completed, 9);
        assert!(stats.session_replica.iter().all(|&r| r < 2));
        // Every transcript holds all user tokens + all answers.
        let expect = 16 + 2 * 6 + 3 * 4;
        assert!(stats.transcripts.iter().all(|t| t.len() == expect));
        // end_session dropped the affinity: load fully drained means
        // complete() was called once per turn with the charged cost.
        assert_eq!(router.load_of(0) + router.load_of(1), 0);
    }

    #[test]
    fn prefix_reuse_absorbs_transcript_resends() {
        // Same trace with reuse on: turn k's prompt extends turn k-1's
        // published prefix, so later turns adopt instead of re-prefilling
        // the transcript — and the conversation itself is unchanged.
        let spec = spec();
        let mut cold_engines = replicas(2, false);
        let mut cold_router = Router::new(2, Policy::LeastLoaded);
        let cold = run_chat(&spec, &mut cold_engines, &mut cold_router);
        let mut warm_engines = replicas(2, true);
        let mut warm_router = Router::new(2, Policy::LeastLoaded);
        let warm = run_chat(&spec, &mut warm_engines, &mut warm_router);
        assert_eq!(cold.prefix_adoptions, 0);
        assert!(warm.prefix_adoptions > 0, "turn 2+ must adopt the published transcript");
        assert!(warm.prefill_tokens_avoided >= 8 * warm.prefix_adoptions);
        // Reuse must be semantically invisible: identical transcripts.
        assert_eq!(cold.transcripts, warm.transcripts);
    }
}

//! Multi-turn chat workload: conversations whose every turn re-sends the
//! whole transcript so far plus a new user message — the canonical
//! shared-prefix traffic pattern prefix reuse exists for (each turn's
//! prompt is a strict extension of the previous turn's prompt ++ answer).
//!
//! The driver submits turns to a serving [`Coordinator`] with session
//! tags: the cluster pins a conversation to one replica (its warm prefix
//! cache lives there, so bouncing turns across replicas would forfeit
//! every adoption), re-pins only when a preemption re-route moves the
//! request, and [`Coordinator::end_session`] is called when a
//! conversation closes so affinity entries do not accumulate forever.

use crate::coordinator::{Coordinator, GenParams, Request};
use crate::util::rng::Rng;

/// Shape of a synthetic chat workload.
#[derive(Clone, Debug)]
pub struct ChatSpec {
    pub n_sessions: usize,
    pub turns_per_session: usize,
    /// Tokens in the opening user message (the eventual shared prefix —
    /// chunk-aligned openings publish cleanly).
    pub first_turn_tokens: usize,
    /// Tokens each later user message appends.
    pub turn_tokens: usize,
    pub max_new_tokens: usize,
    pub vocab: usize,
    pub seed: u64,
}

/// Aggregate outcome of a chat run.
#[derive(Clone, Debug, Default)]
pub struct ChatStats {
    pub turns_completed: usize,
    /// Prompt tokens submitted across all turns (the transcript re-send
    /// traffic reuse is supposed to absorb).
    pub prompt_tokens: usize,
    /// Summed over replicas after the run.
    pub prefill_tokens_avoided: usize,
    pub prefix_adoptions: usize,
    /// Replica each session ended up pinned to (index = session).
    pub session_replica: Vec<usize>,
    /// Times any session's pinned replica changed between turns. Only a
    /// preemption re-route can move a pin, so a run without preemptions
    /// must report 0 — the affinity-stability invariant.
    pub affinity_moves: usize,
    /// Per-session final transcripts (prompt ++ every answer), for
    /// cross-run comparisons.
    pub transcripts: Vec<Vec<usize>>,
}

/// Drive a chat workload through the serving cluster, one turn round at a
/// time (every session advances a turn, then the cluster drains). Returns
/// per-session transcripts and the summed reuse metrics.
pub fn run_chat(spec: &ChatSpec, cluster: &mut Coordinator) -> ChatStats {
    let mut rng = Rng::new(spec.seed);
    // A session's transcript: everything the model has seen + said; the
    // next turn's prompt is transcript ++ fresh user tokens.
    let mut transcripts: Vec<Vec<usize>> = (0..spec.n_sessions).map(|_| Vec::new()).collect();
    let mut stats = ChatStats {
        session_replica: vec![usize::MAX; spec.n_sessions],
        ..ChatStats::default()
    };
    let mut next_id = 0u64;
    for turn in 0..spec.turns_per_session {
        // (request id, session) dispatched this round.
        let mut turn_ids: Vec<(u64, usize)> = Vec::new();
        for s in 0..spec.n_sessions {
            let user_tokens =
                if turn == 0 { spec.first_turn_tokens } else { spec.turn_tokens };
            for _ in 0..user_tokens {
                transcripts[s].push(rng.below(spec.vocab));
            }
            let req = Request::new(
                next_id,
                transcripts[s].clone(),
                GenParams { max_new_tokens: spec.max_new_tokens, stop_token: None },
            )
            .with_session(s as u64);
            stats.prompt_tokens += req.prompt.len();
            cluster.submit(req).expect("chat request ids are unique");
            turn_ids.push((next_id, s));
            next_id += 1;
        }
        for resp in cluster.run_to_completion() {
            let &(_, s) =
                turn_ids.iter().find(|(id, _)| *id == resp.id).expect("unknown response id");
            transcripts[s].extend_from_slice(&resp.tokens);
            stats.turns_completed += 1;
        }
        for s in 0..spec.n_sessions {
            let r = cluster
                .session_replica(s as u64)
                .expect("session must stay pinned while the conversation is live");
            if stats.session_replica[s] == usize::MAX {
                stats.session_replica[s] = r;
            } else if stats.session_replica[s] != r {
                // A preemption re-route moved the conversation — follow
                // it (the warm cache is on the new replica now).
                stats.affinity_moves += 1;
                stats.session_replica[s] = r;
            }
        }
    }
    for s in 0..spec.n_sessions {
        cluster.end_session(s as u64);
    }
    let agg = cluster.metrics().aggregate();
    stats.prefill_tokens_avoided = agg.prefill_tokens_avoided;
    stats.prefix_adoptions = agg.prefix_adoptions;
    stats.transcripts = transcripts;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::coordinator::{ClusterConfig, EngineConfig};
    use crate::model::{BackendFactory, Model, ModelConfig, Weights};
    use std::sync::Arc;

    fn cluster(n: usize, reuse: bool) -> Coordinator {
        let cfg = ModelConfig::tiny_mha(256);
        let model = Model::new(cfg.clone(), Arc::new(Weights::random(&cfg, 37)));
        let shape = cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
        Coordinator::new(
            model,
            factory,
            ClusterConfig {
                replicas: n,
                engine: EngineConfig {
                    max_batch: 4,
                    prefill_chunk: 8,
                    page_bytes: 4096,
                    pool_budget: 1 << 26,
                    threads: 2,
                    prefix_reuse: reuse,
                    eject_preempted: false, // forced on by the coordinator
                },
                bin_pack_window: 8,
            },
        )
    }

    fn spec() -> ChatSpec {
        ChatSpec {
            n_sessions: 3,
            turns_per_session: 3,
            first_turn_tokens: 16,
            turn_tokens: 6,
            max_new_tokens: 4,
            vocab: 50,
            seed: 11,
        }
    }

    #[test]
    fn multi_turn_sessions_stay_pinned_and_complete() {
        let spec = spec();
        let mut c = cluster(2, false);
        let stats = run_chat(&spec, &mut c);
        assert_eq!(stats.turns_completed, 9);
        assert!(stats.session_replica.iter().all(|&r| r < 2));
        // Ample pool ⇒ no preemptions ⇒ pins never move.
        assert_eq!(stats.affinity_moves, 0, "affinity moved without any preemption");
        assert_eq!(c.metrics().aggregate().preemptions, 0);
        // Every transcript holds all user tokens + all answers.
        let expect = 16 + 2 * 6 + 3 * 4;
        assert!(stats.transcripts.iter().all(|t| t.len() == expect));
        // Charge/drain symmetry: the run left nothing on any ledger.
        assert!(c.loads().iter().all(|&l| l == 0), "router ledger leaked load");
    }

    #[test]
    fn prefix_reuse_absorbs_transcript_resends() {
        // Same trace with reuse on: turn k's prompt extends turn k-1's
        // published prefix, so later turns adopt instead of re-prefilling
        // the transcript — and the conversation itself is unchanged.
        let spec = spec();
        let mut cold_cluster = cluster(2, false);
        let cold = run_chat(&spec, &mut cold_cluster);
        let mut warm_cluster = cluster(2, true);
        let warm = run_chat(&spec, &mut warm_cluster);
        assert_eq!(cold.prefix_adoptions, 0);
        assert!(warm.prefix_adoptions > 0, "turn 2+ must adopt the published transcript");
        assert!(warm.prefill_tokens_avoided >= 8 * warm.prefix_adoptions);
        // Reuse must be semantically invisible: identical transcripts.
        assert_eq!(cold.transcripts, warm.transcripts);
    }

    #[test]
    fn warm_turn_after_session_end_lands_on_publishing_replica() {
        // A conversation runs (publishing its transcript prefixes), then
        // ends — affinity dropped. A NEW session re-sending the same
        // transcript must be placed by the prefix index onto the replica
        // that holds the published cache, not wherever is emptiest.
        let spec = ChatSpec { n_sessions: 1, turns_per_session: 2, ..spec() };
        let mut c = cluster(2, true);
        let stats = run_chat(&spec, &mut c);
        assert_eq!(stats.turns_completed, 2);
        let home = stats.session_replica[0];
        let hints_before = c.metrics().prefix_hint_hits;
        // run_chat ended the session, so this placement cannot use
        // affinity — only the content-keyed prefix index.
        let req = Request::new(
            1000,
            stats.transcripts[0].clone(),
            GenParams { max_new_tokens: spec.max_new_tokens, stop_token: None },
        )
        .with_session(77);
        c.submit(req).expect("fresh id");
        assert_eq!(
            c.session_replica(77),
            Some(home),
            "warm re-send must land on the replica holding its published prefix"
        );
        assert_eq!(c.run_to_completion().len(), 1);
        let m = c.metrics();
        assert!(m.prefix_hint_hits > hints_before, "placement must be a prefix-index hit");
        assert!(m.aggregate().prefix_adoptions >= stats.prefix_adoptions + 1);
        c.end_session(77);
    }
}

//! RULER subtask generators (Hsieh et al., 2024) — Table 5's columns:
//! S1, S2 (single-needle), MK1, MK2 (multi-key), MV (multi-value),
//! MQ (multi-query), FEW (few-shot), QA1, QA2 (noisy-query QA proxies).

use super::Trial;
use crate::model::retrieval::RetrievalModel;
use crate::util::rng::Rng;

/// RULER subtask identifiers, in the paper's column order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RulerTask {
    S1,
    S2,
    Mk1,
    Mk2,
    Mv,
    Mq,
    Few,
    Qa1,
    Qa2,
}

impl RulerTask {
    pub fn all() -> [RulerTask; 9] {
        [
            RulerTask::S1,
            RulerTask::S2,
            RulerTask::Mk1,
            RulerTask::Mk2,
            RulerTask::Mv,
            RulerTask::Mq,
            RulerTask::Few,
            RulerTask::Qa1,
            RulerTask::Qa2,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            RulerTask::S1 => "S1",
            RulerTask::S2 => "S2",
            RulerTask::Mk1 => "MK1",
            RulerTask::Mk2 => "MK2",
            RulerTask::Mv => "MV",
            RulerTask::Mq => "MQ",
            RulerTask::Few => "FEW",
            RulerTask::Qa1 => "QA1",
            RulerTask::Qa2 => "QA2",
        }
    }
}

/// Generate one trial of the given subtask with context length `len`.
/// Multi-query tasks return several trials sharing one context.
pub fn generate(rm: &RetrievalModel, task: RulerTask, len: usize, rng: &mut Rng) -> Vec<Trial> {
    let nk = rm.spec.n_keys;
    let nv = rm.spec.n_vals;
    let key = rng.below(nk);
    let val = rng.below(nv);
    match task {
        // S1: single needle in *repetitive* filler (one filler token).
        RulerTask::S1 => {
            let mut ctx: Vec<usize> = vec![rm.filler_token(0); len];
            ctx[rng.below(len)] = rm.needle_token(key, val);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        // S2: single needle in random filler.
        RulerTask::S2 => {
            let ctx = super::plant_needles(rm, len, &[(key, val)], rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        // MK1: 4 distractor needles with other keys.
        RulerTask::Mk1 => {
            let mut needles = vec![(key, val)];
            while needles.len() < 5 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        // MK2: heavy distractor load (16 other-key needles) — the subtask
        // the paper sees degrade first under 12.5% compression.
        RulerTask::Mk2 => {
            let mut needles = vec![(key, val)];
            while needles.len() < 17 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        // MV: the same key maps to 4 values at different positions; any of
        // them counts (the constructed model blends them; retrieving any
        // planted value is correct, mirroring RULER's per-item scoring).
        RulerTask::Mv => {
            let vals: Vec<usize> = (0..4).map(|_| rng.below(nv)).collect();
            let needles: Vec<(usize, usize)> = vals.iter().map(|&v| (key, v)).collect();
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vals }]
        }
        // MQ: one context, 4 queries over 4 planted keys.
        RulerTask::Mq => {
            let mut keys = Vec::new();
            while keys.len() < 4 {
                let k = rng.below(nk);
                if !keys.contains(&k) {
                    keys.push(k);
                }
            }
            let needles: Vec<(usize, usize)> = keys.iter().map(|&k| (k, rng.below(nv))).collect();
            let ctx = super::plant_needles(rm, len, &needles, rng);
            needles
                .iter()
                .map(|&(k, v)| Trial { context: ctx.clone(), query_key: k, expected_values: vec![v] })
                .collect()
        }
        // FEW: few-shot pattern — several (key -> value) examples appear
        // early, the queried pair is repeated twice (seen pattern).
        RulerTask::Few => {
            let mut needles = vec![(key, val), (key, val)];
            for _ in 0..6 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        // QA1/QA2: same retrieval with raised filler interference — fillers
        // get denser (shorter context budget per filler id), QA2 adds more
        // distractor needles. Proxies the harder "reason over context" end.
        RulerTask::Qa1 => {
            let mut needles = vec![(key, val)];
            for _ in 0..2 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        RulerTask::Qa2 => {
            let mut needles = vec![(key, val)];
            for _ in 0..8 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::{RetrievalModel, RetrievalSpec};

    fn rm() -> RetrievalModel {
        RetrievalModel::build(RetrievalSpec {
            n_keys: 16,
            n_vals: 16,
            n_fill: 32,
            max_seq: 512,
            n_layers: 3,
            ..Default::default()
        })
    }

    #[test]
    fn all_tasks_generate_valid_trials() {
        let rm = rm();
        let mut rng = Rng::new(311);
        for task in RulerTask::all() {
            let trials = generate(&rm, task, 128, &mut rng);
            assert!(!trials.is_empty(), "{task:?}");
            for t in &trials {
                assert_eq!(t.context.len(), 128);
                assert!(t.context.iter().all(|&tok| tok < rm.cfg.vocab));
                assert!(t.query_key < rm.spec.n_keys);
                assert!(!t.expected_values.is_empty());
                // The expected needle must actually be in the context.
                assert!(t
                    .expected_values
                    .iter()
                    .any(|&v| t.context.contains(&rm.needle_token(t.query_key, v))));
            }
        }
    }

    #[test]
    fn mq_returns_four_trials_sharing_context() {
        let rm = rm();
        let mut rng = Rng::new(313);
        let trials = generate(&rm, RulerTask::Mq, 100, &mut rng);
        assert_eq!(trials.len(), 4);
        for t in &trials[1..] {
            assert_eq!(t.context, trials[0].context);
        }
    }

    #[test]
    fn mk2_has_many_distractors() {
        let rm = rm();
        let mut rng = Rng::new(317);
        let t = &generate(&rm, RulerTask::Mk2, 200, &mut rng)[0];
        let needles = t.context.iter().filter(|&&tok| rm.decode_needle(tok).is_some()).count();
        assert!(needles >= 17, "{needles}");
    }
}

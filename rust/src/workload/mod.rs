//! Synthetic workload generators mirroring the paper's benchmark families:
//! RULER retrieval subtasks (Table 5), LongBench category proxies
//! (Tables 3–4), and GSM8K/CoQA-style multi-step recall (Table 2).
//!
//! Every task is expressed against the constructed retrieval model
//! (`model::retrieval`): a token context with planted needles + a query,
//! with exact ground truth, so "accuracy" measures precisely what the
//! paper's retrieval benchmarks measure — does compressed attention still
//! find and read the right tokens?

pub mod chat;
pub mod longbench;
pub mod ruler;
pub mod runner;

pub use chat::{run_chat, ChatSpec, ChatStats};
pub use runner::{evaluate, TaskSuite, TaskTrial};

use crate::model::retrieval::RetrievalModel;
use crate::util::rng::Rng;

/// One retrieval trial: a context, the query key, and the expected value.
#[derive(Clone, Debug)]
pub struct Trial {
    pub context: Vec<usize>,
    pub query_key: usize,
    /// Acceptable answers (MV tasks have several).
    pub expected_values: Vec<usize>,
}

/// Insert `needles` (key, value) pairs into a filler context of length
/// `len` at random distinct positions.
pub fn plant_needles(
    rm: &RetrievalModel,
    len: usize,
    needles: &[(usize, usize)],
    rng: &mut Rng,
) -> Vec<usize> {
    assert!(needles.len() <= len);
    let mut ctx: Vec<usize> = (0..len).map(|_| rm.filler_token(rng.below(rm.spec.n_fill))).collect();
    let pos = rng.sample_indices(len, needles.len());
    for (&p, &(k, v)) in pos.iter().zip(needles) {
        ctx[p] = rm.needle_token(k, v);
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::{RetrievalModel, RetrievalSpec};

    #[test]
    fn plant_needles_places_all() {
        let rm = RetrievalModel::build(RetrievalSpec {
            n_keys: 8,
            n_vals: 8,
            n_fill: 16,
            max_seq: 256,
            n_layers: 3,
            ..Default::default()
        });
        let mut rng = Rng::new(301);
        let needles = [(1, 2), (3, 4), (5, 6)];
        let ctx = plant_needles(&rm, 100, &needles, &mut rng);
        assert_eq!(ctx.len(), 100);
        for &(k, v) in &needles {
            assert!(ctx.contains(&rm.needle_token(k, v)));
        }
    }
}

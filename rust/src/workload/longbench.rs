//! LongBench category proxies (Tables 3–4) and the GSM8K/CoQA-style
//! reasoning proxies (Table 2).
//!
//! LongBench's six categories probe different retrieval/aggregation
//! patterns; each proxy keeps the pattern while staying exactly scorable:
//!
//! * Single-QA      → one needle, moderate distractors (RULER-S2-like)
//! * Multi-QA       → two needles must BOTH be retrieved (2 queries/trial)
//! * Summarization  → several same-key values spread out; any counts
//! * Few-shot       → repeated (key→value) pattern, query a seen key
//! * Synthetic      → S1-style repetitive filler retrieval
//! * Code           → positional-locality pattern: needle keys cluster near
//!                    the end (recency-friendly) with exact-match queries
//!
//! GSM8K proxy = sequential multi-hop recall (the answer of hop i selects
//! the key of hop i+1 — errors compound, which is why Palu's reconstruction
//! noise collapses on it, Table 2); CoQA proxy = conversational recall with
//! a short dialogue-like context.

use super::Trial;
use crate::model::retrieval::RetrievalModel;
use crate::util::rng::Rng;

/// LongBench category identifiers, in the paper's column order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LongBenchTask {
    SingleQa,
    MultiQa,
    Summarization,
    FewShot,
    Synthetic,
    Code,
}

impl LongBenchTask {
    pub fn all() -> [LongBenchTask; 6] {
        [
            LongBenchTask::SingleQa,
            LongBenchTask::MultiQa,
            LongBenchTask::Summarization,
            LongBenchTask::FewShot,
            LongBenchTask::Synthetic,
            LongBenchTask::Code,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            LongBenchTask::SingleQa => "Single-QA",
            LongBenchTask::MultiQa => "Multi-QA",
            LongBenchTask::Summarization => "Summarization",
            LongBenchTask::FewShot => "Few-shot",
            LongBenchTask::Synthetic => "Synthetic",
            LongBenchTask::Code => "Code",
        }
    }
}

/// Generate trials for one LongBench category.
pub fn generate(rm: &RetrievalModel, task: LongBenchTask, len: usize, rng: &mut Rng) -> Vec<Trial> {
    let nk = rm.spec.n_keys;
    let nv = rm.spec.n_vals;
    let key = rng.below(nk);
    let val = rng.below(nv);
    match task {
        LongBenchTask::SingleQa => {
            let mut needles = vec![(key, val)];
            for _ in 0..3 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        LongBenchTask::MultiQa => {
            let key2 = (key + 1 + rng.below(nk - 1)) % nk;
            let val2 = rng.below(nv);
            let ctx = super::plant_needles(rm, len, &[(key, val), (key2, val2)], rng);
            vec![
                Trial { context: ctx.clone(), query_key: key, expected_values: vec![val] },
                Trial { context: ctx, query_key: key2, expected_values: vec![val2] },
            ]
        }
        LongBenchTask::Summarization => {
            let vals: Vec<usize> = (0..3).map(|_| rng.below(nv)).collect();
            let needles: Vec<(usize, usize)> = vals.iter().map(|&v| (key, v)).collect();
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vals }]
        }
        LongBenchTask::FewShot => {
            let mut needles = vec![(key, val), (key, val), (key, val)];
            for _ in 0..5 {
                let dk = rng.below(nk);
                if dk != key {
                    needles.push((dk, rng.below(nv)));
                }
            }
            let ctx = super::plant_needles(rm, len, &needles, rng);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        LongBenchTask::Synthetic => {
            let mut ctx: Vec<usize> = vec![rm.filler_token(1); len];
            ctx[rng.below(len)] = rm.needle_token(key, val);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
        LongBenchTask::Code => {
            // Needle in the last quarter (locality), exact-match query.
            let mut ctx: Vec<usize> =
                (0..len).map(|_| rm.filler_token(rng.below(rm.spec.n_fill))).collect();
            let lo = len - len / 4;
            let p = rng.range(lo, len);
            ctx[p] = rm.needle_token(key, val);
            vec![Trial { context: ctx, query_key: key, expected_values: vec![val] }]
        }
    }
}

/// GSM8K proxy: an h-hop chain k0→v0, where v_i selects k_{i+1} = v_i % nk.
/// Each hop is a separate query trial; the *chain* score (all hops correct)
/// is what the runner reports when `all_or_nothing` scoring is chosen.
pub fn gsm8k_chain(rm: &RetrievalModel, len: usize, hops: usize, rng: &mut Rng) -> Vec<Trial> {
    let nk = rm.spec.n_keys;
    let nv = rm.spec.n_vals;
    let mut key = rng.below(nk);
    let mut needles = Vec::new();
    let mut chain = Vec::new();
    for _ in 0..hops {
        let val = rng.below(nv);
        needles.push((key, val));
        chain.push((key, val));
        key = val % nk;
    }
    let ctx = super::plant_needles(rm, len, &needles, rng);
    chain
        .into_iter()
        .map(|(k, v)| Trial { context: ctx.clone(), query_key: k, expected_values: vec![v] })
        .collect()
}

/// CoQA proxy: short conversational context, recall of an earlier turn.
pub fn coqa_turns(rm: &RetrievalModel, len: usize, turns: usize, rng: &mut Rng) -> Vec<Trial> {
    let nk = rm.spec.n_keys;
    let nv = rm.spec.n_vals;
    let mut needles = Vec::new();
    for _ in 0..turns {
        needles.push((rng.below(nk), rng.below(nv)));
    }
    let ctx = super::plant_needles(rm, len, &needles, rng);
    // Query a random earlier turn. If a key repeats across turns, accept
    // any of its planted values.
    let (qk, _) = needles[rng.below(needles.len())];
    let expected: Vec<usize> =
        needles.iter().filter(|&&(k, _)| k == qk).map(|&(_, v)| v).collect();
    vec![Trial { context: ctx, query_key: qk, expected_values: expected }]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::{RetrievalModel, RetrievalSpec};

    fn rm() -> RetrievalModel {
        RetrievalModel::build(RetrievalSpec {
            n_keys: 16,
            n_vals: 16,
            n_fill: 32,
            max_seq: 512,
            n_layers: 3,
            ..Default::default()
        })
    }

    #[test]
    fn all_categories_generate() {
        let rm = rm();
        let mut rng = Rng::new(401);
        for task in LongBenchTask::all() {
            for t in generate(&rm, task, 96, &mut rng) {
                assert_eq!(t.context.len(), 96, "{task:?}");
                assert!(!t.expected_values.is_empty());
            }
        }
    }

    #[test]
    fn gsm8k_chain_links() {
        let rm = rm();
        let mut rng = Rng::new(403);
        let trials = gsm8k_chain(&rm, 128, 4, &mut rng);
        assert_eq!(trials.len(), 4);
        // Hop i+1's key is hop i's value mod n_keys.
        for w in trials.windows(2) {
            assert_eq!(w[1].query_key, w[0].expected_values[0] % rm.spec.n_keys);
        }
    }

    #[test]
    fn code_needle_in_tail() {
        let rm = rm();
        let mut rng = Rng::new(405);
        let t = &generate(&rm, LongBenchTask::Code, 100, &mut rng)[0];
        let pos = t
            .context
            .iter()
            .position(|&tok| rm.decode_needle(tok).is_some())
            .unwrap();
        assert!(pos >= 75, "{pos}");
    }
}

//! Task-suite evaluation: run a method over retrieval trials, score
//! accuracy, and meter cache traffic + resident bytes — the three columns
//! every accuracy table in the paper reports.

use super::Trial;
use crate::model::retrieval::RetrievalModel;
use crate::model::{BackendFactory, Model, Scratch, SequenceState};
use crate::util::threadpool;
use std::sync::Arc;

/// Alias used by benches.
pub type TaskTrial = Trial;

/// A named set of trials.
pub struct TaskSuite {
    pub name: String,
    pub trials: Vec<Trial>,
}

/// Evaluation result over a suite.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub n: usize,
    pub correct: usize,
    /// Total cache bytes read across all trials (attend + scoring reads).
    pub read_bytes: u64,
    /// Resident KV bytes at end of a trial, averaged.
    pub kv_bytes: f64,
}

impl EvalResult {
    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.correct as f64 / self.n as f64
        }
    }
}

/// Run every trial of a suite under the backend `factory`; greedy one-shot
/// scoring: prefill(context + query token), read logits, check the best
/// value for the queried key against the expected set.
pub fn evaluate(
    rm: &RetrievalModel,
    model: &Model,
    factory: &BackendFactory,
    trials: &[Trial],
    threads: usize,
) -> EvalResult {
    let results = threadpool::parallel_map(trials.len(), threads.max(1), |i| {
        let t = &trials[i];
        let mut state = SequenceState::new(&model.cfg, factory);
        let mut scratch = Scratch::new(&model.cfg);
        let mut prompt = t.context.clone();
        prompt.push(rm.query_token(t.query_key));
        let logits = model.prefill(&mut state, &mut scratch, &prompt);
        let got = rm.best_value_for_key(&logits, t.query_key);
        let ok = t.expected_values.contains(&got);
        let traffic = state.traffic();
        (ok, traffic.read, state.kv_bytes())
    });
    let mut out = EvalResult { n: results.len(), correct: 0, read_bytes: 0, kv_bytes: 0.0 };
    for (ok, read, kv) in &results {
        if *ok {
            out.correct += 1;
        }
        out.read_bytes += read;
        out.kv_bytes += *kv as f64;
    }
    if !results.is_empty() {
        out.kv_bytes /= results.len() as f64;
    }
    out
}

/// Build a model wrapper around the retrieval weights once.
pub fn retrieval_model_for(rm: &RetrievalModel) -> Model {
    Model::new(rm.cfg.clone(), Arc::new(rm.weights.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::model::retrieval::{RetrievalModel, RetrievalSpec};
    use crate::util::rng::Rng;
    use crate::workload::ruler::{generate, RulerTask};

    fn setup() -> (RetrievalModel, Model) {
        let rm = RetrievalModel::build(RetrievalSpec {
            n_keys: 16,
            n_vals: 16,
            n_fill: 32,
            max_seq: 256,
            n_layers: 3,
            ..Default::default()
        });
        let model = retrieval_model_for(&rm);
        (rm, model)
    }

    #[test]
    fn full_attention_scores_high_on_s2() {
        let (rm, model) = setup();
        let shape = rm.cfg.attn_shape();
        let factory: Box<BackendFactory> =
            Box::new(move |_| Box::new(FullAttention::new(shape)) as _);
        let mut rng = Rng::new(501);
        let mut trials = Vec::new();
        for _ in 0..10 {
            trials.extend(generate(&rm, RulerTask::S2, 96, &mut rng));
        }
        let res = evaluate(&rm, &model, &factory, &trials, 4);
        assert_eq!(res.n, 10);
        assert!(res.accuracy() >= 0.9, "accuracy {}", res.accuracy());
        assert!(res.read_bytes > 0);
        assert!(res.kv_bytes > 0.0);
    }

    #[test]
    fn random_guess_scores_low() {
        // A backend that returns zeros forces best_value_for_key to pick by
        // embedding-key logits alone -> accuracy ~ 1/n_vals.
        struct ZeroAttention {
            len: usize,
        }
        impl crate::attention::AttentionBackend for ZeroAttention {
            fn append(&mut self, _: &[f32], _: &[f32]) {
                self.len += 1;
            }
            fn attend(&mut self, _: &[f32], out: &mut [f32]) {
                out.fill(0.0);
            }
            fn len(&self) -> usize {
                self.len
            }
            fn traffic(&self) -> crate::attention::Traffic {
                crate::attention::Traffic::default()
            }
            fn kv_bytes(&self) -> usize {
                0
            }
            fn footprint(&self) -> crate::attention::FootprintModel {
                crate::attention::FootprintModel::default()
            }
            fn name(&self) -> &'static str {
                "zero"
            }
        }
        let (rm, model) = setup();
        let factory: Box<BackendFactory> = Box::new(|_| Box::new(ZeroAttention { len: 0 }) as _);
        let mut rng = Rng::new(503);
        let mut trials = Vec::new();
        for _ in 0..12 {
            trials.extend(generate(&rm, RulerTask::S2, 64, &mut rng));
        }
        let res = evaluate(&rm, &model, &factory, &trials, 2);
        assert!(res.accuracy() <= 0.5, "accuracy {}", res.accuracy());
    }
}

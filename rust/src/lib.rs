//! SALS: Sparse Attention in Latent Space for KV cache compression.
//!
//! Reproduction of "SALS: Sparse Attention in Latent Space for KV cache
//! Compression" (Mu et al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * Layer 3 (this crate): serving coordinator — request router, continuous
//!   batcher, paged latent KV-cache manager, prefill/decode scheduler —
//!   plus every substrate the paper depends on (low-rank calibration,
//!   quantization, RoPE, sparse-attention baselines, workload generators).
//! * Layer 2: JAX decode-step graphs (build-time python, `python/compile/`),
//!   lowered once to HLO text artifacts.
//! * Layer 1: Pallas kernels for latent scoring and the fused
//!   reconstruct-RoPE sparse attention (interpret mode on CPU).
//!
//! Python never runs on the request path: the Rust binary loads
//! `artifacts/*.hlo.txt` through PJRT (`xla` crate) and serves from there.

pub mod analyze;
pub mod attention;
pub mod coordinator;
pub mod harness;
pub mod kvcache;
pub mod linalg;
pub mod model;
pub mod lowrank;
pub mod quant;
pub mod rope;
pub mod runtime;
pub mod workload;
pub mod tensor;
pub mod util;

pub use util::error::{Error, Result};

//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them from
//! the Rust hot path. Python authored + lowered these at `make artifacts`
//! time; at serve time the binary is self-contained.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (jax ≥0.5 emits HloModuleProto with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! The PJRT client comes from the external `xla` crate, which the offline
//! build environment does not carry. The real implementation is therefore
//! compiled only with `--features xla` (vendored crate required); the
//! default build gets a stub whose constructor returns [`Error::Xla`], so
//! every caller (the `serve-xla` subcommand, the artifact integration
//! tests) degrades to a clean "built without xla" error instead of a
//! build break.

pub mod xla_model;

pub use xla_model::{ArtifactMeta, XlaModel, XlaVariant};

use crate::util::{Error, Result};
use std::path::{Path, PathBuf};

#[cfg(feature = "xla")]
use std::collections::HashMap;

/// A loaded-and-compiled artifact registry backed by one PJRT CPU client.
#[cfg(feature = "xla")]
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

#[cfg(feature = "xla")]
impl ArtifactRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactRuntime { client, executables: HashMap::new(), dir: dir.as_ref().to_path_buf() })
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<dir>/<name>.hlo.txt`, compile, and cache under `name`.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Config(format!(
                "artifact {} missing — run `make artifacts`",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Config("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Names of loaded artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a loaded artifact on f32 tensors.
    ///
    /// `inputs`: (data, dims) pairs; the jax side lowers with
    /// `return_tuple=True`, so the single output is a tuple whose elements
    /// are returned as flat f32 vectors (with their dims).
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| Error::Config(format!("artifact {name} not loaded")))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims_i64)?;
            literals.push(lit);
        }
        let mut result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            // Convert whatever dtype came back to f32 host data.
            let lit_f32 = lit.convert(xla::PrimitiveType::F32)?;
            out.push(lit_f32.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Execute returning raw literals (for non-f32 outputs like token ids).
    pub fn run_literals(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| Error::Config(format!("artifact {name} not loaded")))?;
        let mut result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.decompose_tuple()?)
    }
}

/// Stub runtime for builds without the `xla` feature: construction fails
/// with a descriptive error so callers surface "rebuild with xla" instead
/// of a link failure. Method signatures mirror the real client (minus the
/// literal-level entry points, which only gated code calls).
#[cfg(not(feature = "xla"))]
pub struct ArtifactRuntime {
    #[allow(dead_code)]
    dir: PathBuf,
}

#[cfg(not(feature = "xla"))]
impl ArtifactRuntime {
    const MSG: &str =
        "sals was built without the `xla` feature; the PJRT artifact runtime is unavailable";

    /// Always fails: no PJRT client in a default build.
    pub fn new(_dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        Err(Error::Xla(Self::MSG.into()))
    }

    /// Platform string (for logs).
    pub fn platform(&self) -> String {
        "stub (no xla feature)".to_string()
    }

    /// Unreachable in practice (`new` never succeeds); kept for API parity.
    pub fn load(&mut self, _name: &str) -> Result<()> {
        Err(Error::Xla(Self::MSG.into()))
    }

    /// Names of loaded artifacts (always empty in the stub).
    pub fn loaded(&self) -> Vec<&str> {
        Vec::new()
    }

    /// Unreachable in practice; kept for API parity.
    pub fn run_f32(&self, _name: &str, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(Error::Xla(Self::MSG.into()))
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use std::io::Write;

    /// Minimal HLO module computing (x+y,) over f32[2,2] — hand-written so
    /// the runtime tests don't depend on `make artifacts` having run.
    const ADD_HLO: &str = r#"HloModule add_mod, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.5 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.2 = f32[2,2]{1,0} parameter(1)
  add.3 = f32[2,2]{1,0} add(Arg_0.1, Arg_1.2)
  ROOT tuple.4 = (f32[2,2]{1,0}) tuple(add.3)
}
"#;

    fn write_artifact(dir: &Path, name: &str, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join(format!("{name}.hlo.txt"))).unwrap();
        f.write_all(text.as_bytes()).unwrap();
    }

    #[test]
    fn loads_and_runs_handwritten_hlo() {
        let dir = std::env::temp_dir().join("sals_runtime_test");
        write_artifact(&dir, "add", ADD_HLO);
        let mut rt = ArtifactRuntime::new(&dir).unwrap();
        rt.load("add").unwrap();
        assert!(rt.loaded().contains(&"add"));
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let out = rt.run_f32("add", &[(&x, &[2, 2]), (&y, &[2, 2])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn missing_artifact_is_config_error() {
        let dir = std::env::temp_dir().join("sals_runtime_test_missing");
        let mut rt = ArtifactRuntime::new(&dir).unwrap();
        let err = rt.load("nope").unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn run_unloaded_name_errors() {
        let dir = std::env::temp_dir().join("sals_runtime_test2");
        let rt = ArtifactRuntime::new(&dir).unwrap();
        assert!(rt.run_f32("ghost", &[]).is_err());
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = ArtifactRuntime::new("artifacts").unwrap_err();
        assert!(matches!(err, Error::Xla(_)), "{err}");
        assert!(err.to_string().contains("xla feature"), "{err}");
    }
}

//! Host-side driver for the AOT decode-step artifacts: owns the KV caches
//! and advances one token at a time through the compiled HLO.
//!
//! This is the piece that proves the three-layer composition: the HLO was
//! lowered from the L2 JAX graph whose attention stages are the L1 Pallas
//! kernels; this struct (L3) feeds it tokens from the serving loop.

use super::ArtifactRuntime;
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::path::Path;

/// Shape contract parsed from `artifacts/meta.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub rank: usize,
    pub r_star: usize,
    pub k_sel: usize,
}

impl ArtifactMeta {
    pub fn kv_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Parse `meta.txt` (see python/compile/aot.py).
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(dir.as_ref().join("meta.txt"))?;
        let mut kv = HashMap::new();
        let mut lines = text.lines();
        let magic = lines.next().unwrap_or("");
        if magic.trim() != "sals-artifacts v1" {
            return Err(Error::Config(format!("bad meta magic: {magic}")));
        }
        for line in lines {
            if let Some((k, v)) = line.split_once(' ') {
                kv.insert(k.to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| Error::Config(format!("meta missing field {k}")))
        };
        Ok(ArtifactMeta {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            max_seq: get("max_seq")?,
            rank: get("rank")?,
            r_star: get("r_star")?,
            k_sel: get("k_sel")?,
        })
    }
}

/// Which decode artifact to drive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XlaVariant {
    Sals,
    Dense,
}

impl XlaVariant {
    fn artifact(self) -> &'static str {
        match self {
            XlaVariant::Sals => "sals_decode",
            XlaVariant::Dense => "dense_decode",
        }
    }
}

/// One decoding sequence over a compiled decode-step executable.
pub struct XlaModel {
    pub meta: ArtifactMeta,
    variant: XlaVariant,
    /// (L, S, r) for SALS keys / (L, S, kv) dense keys — flat host buffer.
    k_cache: Vec<f32>,
    /// (L, S, kv) values.
    v_cache: Vec<f32>,
    pub pos: usize,
}

impl XlaModel {
    /// Prepare caches for a fresh sequence; loads the artifact if needed.
    pub fn new(rt: &mut ArtifactRuntime, dir: impl AsRef<Path>, variant: XlaVariant) -> Result<XlaModel> {
        let meta = ArtifactMeta::load(&dir)?;
        rt.load(variant.artifact())?;
        let k_width = match variant {
            XlaVariant::Sals => meta.rank,
            XlaVariant::Dense => meta.kv_dim(),
        };
        Ok(XlaModel {
            k_cache: vec![0.0; meta.n_layers * meta.max_seq * k_width],
            v_cache: vec![0.0; meta.n_layers * meta.max_seq * meta.kv_dim()],
            pos: 0,
            meta,
            variant,
        })
    }

    fn k_width(&self) -> usize {
        match self.variant {
            XlaVariant::Sals => self.meta.rank,
            XlaVariant::Dense => self.meta.kv_dim(),
        }
    }

    /// Resident KV bytes of this sequence's caches at the current length
    /// (latent keys are `rank/kv_dim` of dense — the Table 2/3 comp ratio).
    pub fn kv_bytes_at_len(&self) -> usize {
        self.pos * self.meta.n_layers * (self.k_width() + self.meta.kv_dim()) * 4
    }

    /// Feed one token; returns the next-token logits.
    #[cfg(feature = "xla")]
    pub fn step(&mut self, rt: &ArtifactRuntime, token: usize) -> Result<Vec<f32>> {
        if self.pos >= self.meta.max_seq {
            return Err(Error::Coordinator("sequence exceeds artifact max_seq".into()));
        }
        if token >= self.meta.vocab {
            return Err(Error::Config(format!("token {token} out of vocab")));
        }
        let m = &self.meta;
        let kw = self.k_width();
        let tok = xla::Literal::scalar(token as i32);
        let pos = xla::Literal::scalar(self.pos as i32);
        let kdims: Vec<i64> = vec![m.n_layers as i64, m.max_seq as i64, kw as i64];
        let vdims: Vec<i64> = vec![m.n_layers as i64, m.max_seq as i64, m.kv_dim() as i64];
        let kc = xla::Literal::vec1(self.k_cache.as_slice()).reshape(&kdims)?;
        let vc = xla::Literal::vec1(self.v_cache.as_slice()).reshape(&vdims)?;
        let outs = rt.run_literals(self.variant.artifact(), &[tok, pos, kc, vc])?;
        if outs.len() != 3 {
            return Err(Error::Xla(format!("expected 3 outputs, got {}", outs.len())));
        }
        let logits = outs[0].convert(xla::PrimitiveType::F32).map_err(|e| Error::Xla(e.to_string()))?.to_vec::<f32>()?;
        self.k_cache = outs[1].convert(xla::PrimitiveType::F32).map_err(|e| Error::Xla(e.to_string()))?.to_vec::<f32>()?;
        self.v_cache = outs[2].convert(xla::PrimitiveType::F32).map_err(|e| Error::Xla(e.to_string()))?.to_vec::<f32>()?;
        self.pos += 1;
        Ok(logits)
    }

    /// Feed one token (stub: the default build has no PJRT runtime).
    #[cfg(not(feature = "xla"))]
    pub fn step(&mut self, _rt: &ArtifactRuntime, _token: usize) -> Result<Vec<f32>> {
        Err(Error::Xla(
            "sals was built without the `xla` feature; XlaModel::step is unavailable".into(),
        ))
    }

    /// Greedy generation: prefill the prompt, then decode `n` tokens.
    pub fn generate(&mut self, rt: &ArtifactRuntime, prompt: &[usize], n: usize) -> Result<Vec<usize>> {
        assert!(!prompt.is_empty());
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.step(rt, t)?;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let next = crate::tensor::ops::argmax(&logits);
            out.push(next);
            if self.pos >= self.meta.max_seq {
                break;
            }
            logits = self.step(rt, next)?;
        }
        Ok(out)
    }

    /// Reset to an empty sequence (reuse the compiled executable).
    pub fn reset(&mut self) {
        self.k_cache.fill(0.0);
        self.v_cache.fill(0.0);
        self.pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_and_rejects_garbage() {
        let dir = std::env::temp_dir().join("sals_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.txt"),
            "sals-artifacts v1\nvocab 256\nd_model 128\nn_layers 4\nn_heads 4\nhead_dim 32\nmax_seq 512\nrank 32\nr_star 16\nk_sel 64\n",
        )
        .unwrap();
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.kv_dim(), 128);
        assert_eq!(m.max_seq, 512);
        std::fs::write(dir.join("meta.txt"), "not-a-meta\n").unwrap();
        assert!(ArtifactMeta::load(&dir).is_err());
    }
}

//! Runtime-dispatched SIMD microkernels for the decode hot path.
//!
//! Every kernel exists in (up to) three tiers, selected once per process:
//!
//! * **avx2+fma** — x86_64 with runtime-detected AVX2 + FMA
//!   (`is_x86_feature_detected!`).
//! * **neon** — aarch64 (ASIMD is architecturally mandatory there).
//! * **scalar** — the pre-SIMD reference loops, verbatim; always compiled,
//!   exported as [`scalar`] so tests and benches can pin the reference.
//!
//! `SALS_SIMD=scalar` in the environment forces the scalar tier (read once,
//! at first dispatch).
//!
//! # Parity contract (exact vs. reassociated)
//!
//! Kernels fall into two classes, and tests hold them to different bars:
//!
//! * **Exact class** — vectorized across *independent output elements* with
//!   the same per-element operation order as the scalar loop, and no FMA
//!   contraction (multiply and add stay separate instructions). These are
//!   **bit-identical** across all tiers: [`axpy`], [`row_set`], [`scale`],
//!   [`max`], [`weighted_scale`], and every `dequant_*` / `dequant_axpy_*`
//!   kernel. Hot paths built purely from this class (the matmul row
//!   kernels, the fused dequant-GEMV value path) therefore produce the
//!   same bits no matter which tier runs them.
//! * **Reassociated class** — horizontal reductions using multi-lane
//!   accumulators and FMA ([`dot`], [`sum_squares`]) or a polynomial exp
//!   ([`exp_sum`], Cephes on AVX2). These match the scalar reference to
//!   ≤1e-5 relative only; within a fixed tier they are still deterministic,
//!   so thread-count bit-invariance is unaffected.
//!
//! # SAFETY conventions
//!
//! The `target_feature` kernels live in private per-arch modules and are
//! `unsafe fn` solely because of the feature requirement — they have no
//! other preconditions beyond their (debug-)asserted slice shapes. The
//! *only* call sites are the dispatch `match`es in the public wrappers,
//! where the tier value proves the feature is present; each such `unsafe`
//! block carries a `// SAFETY:` comment (enforced by
//! `clippy::undocumented_unsafe_blocks`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel family dispatch selected for this process.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdTier {
    /// x86_64 with runtime-detected AVX2 and FMA.
    Avx2Fma,
    /// aarch64 ASIMD.
    Neon,
    /// Portable reference loops.
    Scalar,
}

impl SimdTier {
    /// Stable lowercase name, used in bench artifacts and logs.
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Avx2Fma => "avx2+fma",
            SimdTier::Neon => "neon",
            SimdTier::Scalar => "scalar",
        }
    }
}

/// 0 = undetected, 1 = avx2+fma, 2 = neon, 3 = scalar.
static TIER: AtomicU8 = AtomicU8::new(0);

/// The dispatched tier (detected once; benign race — all racers store the
/// same value, detection is deterministic per process).
#[inline]
pub fn tier() -> SimdTier {
    match TIER.load(Ordering::Relaxed) {
        1 => SimdTier::Avx2Fma,
        2 => SimdTier::Neon,
        3 => SimdTier::Scalar,
        _ => {
            let t = detect();
            let code = match t {
                SimdTier::Avx2Fma => 1,
                SimdTier::Neon => 2,
                SimdTier::Scalar => 3,
            };
            TIER.store(code, Ordering::Relaxed);
            t
        }
    }
}

/// [`SimdTier::name`] of the dispatched tier (bench-artifact convenience).
pub fn tier_name() -> &'static str {
    tier().name()
}

fn detect() -> SimdTier {
    // Escape hatch for parity debugging and scalar-reference benches.
    if matches!(std::env::var("SALS_SIMD").as_deref(), Ok("scalar")) {
        return SimdTier::Scalar;
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> SimdTier {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        SimdTier::Avx2Fma
    } else {
        SimdTier::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> SimdTier {
    SimdTier::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> SimdTier {
    SimdTier::Scalar
}

/// Scalar reference kernels — the exact loops the crate ran before the
/// SIMD tiers existed. Public so parity tests and the scalar-vs-SIMD
/// microbenches can call the reference directly regardless of dispatch.
pub mod scalar {
    /// Unit-stride dot product; 4-way unrolled accumulation (the pre-SIMD
    /// `ops::dot`, kept verbatim as the parity reference).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for c in 0..chunks {
            let i = c * 4;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        let mut s = s0 + s1 + s2 + s3;
        for i in chunks * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// y += alpha * x (exact class: one mul, one add per element).
    #[inline]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// y = alpha * x (the zero-fold first pass of the matmul row loop).
    #[inline]
    pub fn row_set(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi = alpha * xi;
        }
    }

    /// xs *= alpha in place.
    #[inline]
    pub fn scale(xs: &mut [f32], alpha: f32) {
        for x in xs {
            *x *= alpha;
        }
    }

    /// Max over a slice (−inf on empty; exact class — pure selection).
    #[inline]
    pub fn max(xs: &[f32]) -> f32 {
        xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }

    /// row[i] = exp(row[i] − m); returns the sum (the softmax middle scan).
    #[inline]
    pub fn exp_sum(row: &mut [f32], m: f32) -> f32 {
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - m).exp();
            sum += *x;
        }
        sum
    }

    /// Σ x², sequential (the rmsnorm mean-square scan).
    #[inline]
    pub fn sum_squares(xs: &[f32]) -> f32 {
        xs.iter().map(|v| v * v).sum::<f32>()
    }

    /// out[i] = x[i] * alpha * w[i] (the rmsnorm apply scan; exact class,
    /// left-associated multiplies like the original loop).
    #[inline]
    pub fn weighted_scale(x: &[f32], w: &[f32], alpha: f32, out: &mut [f32]) {
        debug_assert_eq!(x.len(), w.len());
        debug_assert_eq!(x.len(), out.len());
        for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w) {
            *o = xi * alpha * wi;
        }
    }

    /// 8-bit dequant: out[c] = codes[c]·scale[c] + zero[c].
    #[inline]
    pub fn dequant_b8(codes: &[u8], scale: &[f32], zero: &[f32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), out.len());
        for (((o, &c), &s), &z) in out.iter_mut().zip(codes).zip(scale).zip(zero) {
            *o = c as f32 * s + z;
        }
    }

    /// 4-bit dequant over packed codes starting at absolute code index
    /// `i0` (two codes per byte, low nibble first — the store's layout).
    #[inline]
    pub fn dequant_b4(codes: &[u8], i0: usize, scale: &[f32], zero: &[f32], out: &mut [f32]) {
        for (t, ((o, &s), &z)) in out.iter_mut().zip(scale).zip(zero).enumerate() {
            let i = i0 + t;
            let code = (codes[i >> 1] >> ((i & 1) as u32 * 4)) & 0x0F;
            *o = code as f32 * s + z;
        }
    }

    /// 2-bit dequant over packed codes starting at absolute code index
    /// `i0` (four codes per byte, lowest crumb first).
    #[inline]
    pub fn dequant_b2(codes: &[u8], i0: usize, scale: &[f32], zero: &[f32], out: &mut [f32]) {
        for (t, ((o, &s), &z)) in out.iter_mut().zip(scale).zip(zero).enumerate() {
            let i = i0 + t;
            let code = (codes[i >> 2] >> ((i & 3) as u32 * 2)) & 0x03;
            *o = code as f32 * s + z;
        }
    }

    /// Fused 8-bit dequant-axpy: acc[c] += p · (codes[c]·scale[c]+zero[c]).
    /// Exact class — bit-identical to `dequant_b8` followed by `axpy`.
    #[inline]
    pub fn dequant_axpy_b8(p: f32, codes: &[u8], scale: &[f32], zero: &[f32], acc: &mut [f32]) {
        debug_assert_eq!(codes.len(), acc.len());
        for (((a, &c), &s), &z) in acc.iter_mut().zip(codes).zip(scale).zip(zero) {
            *a += p * (c as f32 * s + z);
        }
    }

    /// Fused 4-bit dequant-axpy (see [`dequant_b4`] for the layout).
    #[inline]
    pub fn dequant_axpy_b4(
        p: f32,
        codes: &[u8],
        i0: usize,
        scale: &[f32],
        zero: &[f32],
        acc: &mut [f32],
    ) {
        for (t, ((a, &s), &z)) in acc.iter_mut().zip(scale).zip(zero).enumerate() {
            let i = i0 + t;
            let code = (codes[i >> 1] >> ((i & 1) as u32 * 4)) & 0x0F;
            *a += p * (code as f32 * s + z);
        }
    }

    /// Fused 2-bit dequant-axpy (see [`dequant_b2`] for the layout).
    #[inline]
    pub fn dequant_axpy_b2(
        p: f32,
        codes: &[u8],
        i0: usize,
        scale: &[f32],
        zero: &[f32],
        acc: &mut [f32],
    ) {
        for (t, ((a, &s), &z)) in acc.iter_mut().zip(scale).zip(zero).enumerate() {
            let i = i0 + t;
            let code = (codes[i >> 2] >> ((i & 3) as u32 * 2)) & 0x03;
            *a += p * (code as f32 * s + z);
        }
    }
}

/// AVX2(+FMA) kernels. `unsafe fn` solely for the `target_feature`
/// requirement; the dispatch wrappers are the only callers and gate on the
/// detected tier. Exact-class kernels keep multiply and add as separate
/// instructions so each lane reproduces the scalar bits; only `dot`,
/// `sum_squares` and `exp_sum` use FMA / multi-lane accumulators
/// (reassociated class). Cephes exp constants are written at published
/// precision, hence the literal-precision allow.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::excessive_precision)]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of all 8 lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    /// Horizontal max of all 8 lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn hmax(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_max_ps(lo, hi);
        let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_max_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            let (a1, b1) = (_mm256_loadu_ps(ap.add(i + 8)), _mm256_loadu_ps(bp.add(i + 8)));
            acc1 = _mm256_fmadd_ps(a1, b1, acc1);
            let (a2, b2) = (_mm256_loadu_ps(ap.add(i + 16)), _mm256_loadu_ps(bp.add(i + 16)));
            acc2 = _mm256_fmadd_ps(a2, b2, acc2);
            let (a3, b3) = (_mm256_loadu_ps(ap.add(i + 24)), _mm256_loadu_ps(bp.add(i + 24)));
            acc3 = _mm256_fmadd_ps(a3, b3, acc3);
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            // mul+add kept separate (no FMA): each lane matches scalar bits.
            let r = _mm256_add_ps(yv, _mm256_mul_ps(va, xv));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn row_set(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_mul_ps(va, _mm256_loadu_ps(x.as_ptr().add(i)));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            y[i] = alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(xs: &mut [f32], alpha: f32) {
        let n = xs.len();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_mul_ps(_mm256_loadu_ps(xs.as_ptr().add(i)), va);
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            xs[i] *= alpha;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn max(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut i = 0usize;
        let mut m = f32::NEG_INFINITY;
        if n >= 8 {
            let mut vm = _mm256_loadu_ps(xs.as_ptr());
            i = 8;
            while i + 8 <= n {
                vm = _mm256_max_ps(vm, _mm256_loadu_ps(xs.as_ptr().add(i)));
                i += 8;
            }
            m = hmax(vm);
        }
        while i < n {
            m = m.max(xs[i]);
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sum_squares(xs: &[f32]) -> f32 {
        let n = xs.len();
        let p = xs.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let v0 = _mm256_loadu_ps(p.add(i));
            let v1 = _mm256_loadu_ps(p.add(i + 8));
            acc0 = _mm256_fmadd_ps(v0, v0, acc0);
            acc1 = _mm256_fmadd_ps(v1, v1, acc1);
            i += 16;
        }
        while i + 8 <= n {
            let v = _mm256_loadu_ps(p.add(i));
            acc0 = _mm256_fmadd_ps(v, v, acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += xs[i] * xs[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn weighted_scale(x: &[f32], w: &[f32], alpha: f32, out: &mut [f32]) {
        debug_assert_eq!(x.len(), w.len());
        debug_assert_eq!(x.len(), out.len());
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0usize;
        while i + 8 <= n {
            // (x·alpha)·w, left-associated like the scalar loop.
            let xv = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(i)), va);
            let r = _mm256_mul_ps(xv, _mm256_loadu_ps(w.as_ptr().add(i)));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            out[i] = x[i] * alpha * w[i];
            i += 1;
        }
    }

    // ---- Cephes exp (vectorized f32 exp, ~1 ulp over the softmax range) ----

    const EXP_HI: f32 = 88.3762626647949;
    // Cephes uses −88.37…; tightened to −87 so floor(x·log2e + 0.5) can
    // never reach −128, which would overflow the 2^n exponent-bits trick
    // into the sign bit. exp(−87) ≈ 1.6e−38 ≈ 0 for softmax purposes.
    const EXP_LO: f32 = -87.0;
    const C1: f32 = 0.693359375;
    const C2: f32 = -2.12194440e-4;
    const P0: f32 = 1.9875691500e-4;
    const P1: f32 = 1.3981999507e-3;
    const P2: f32 = 8.3334519073e-3;
    const P3: f32 = 4.1665795894e-2;
    const P4: f32 = 1.6666665459e-1;
    const P5: f32 = 5.0000001201e-1;

    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp256(x: __m256) -> __m256 {
        let x = _mm256_max_ps(_mm256_min_ps(x, _mm256_set1_ps(EXP_HI)), _mm256_set1_ps(EXP_LO));
        // n = floor(x·log2(e) + 1/2); r = x − n·ln2 (split ln2 for accuracy).
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        let fx = _mm256_floor_ps(_mm256_fmadd_ps(x, log2e, _mm256_set1_ps(0.5)));
        let mut xr = _mm256_fnmadd_ps(fx, _mm256_set1_ps(C1), x);
        xr = _mm256_fnmadd_ps(fx, _mm256_set1_ps(C2), xr);
        // Degree-5 minimax polynomial for exp(r) on |r| ≤ ln2/2.
        let x2 = _mm256_mul_ps(xr, xr);
        let mut y = _mm256_set1_ps(P0);
        y = _mm256_fmadd_ps(y, xr, _mm256_set1_ps(P1));
        y = _mm256_fmadd_ps(y, xr, _mm256_set1_ps(P2));
        y = _mm256_fmadd_ps(y, xr, _mm256_set1_ps(P3));
        y = _mm256_fmadd_ps(y, xr, _mm256_set1_ps(P4));
        y = _mm256_fmadd_ps(y, xr, _mm256_set1_ps(P5));
        y = _mm256_fmadd_ps(y, x2, xr);
        y = _mm256_add_ps(y, _mm256_set1_ps(1.0));
        // Scale by 2^n via the exponent bits.
        let n = _mm256_cvtps_epi32(fx);
        let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(n, _mm256_set1_epi32(127)));
        _mm256_mul_ps(y, _mm256_castsi256_ps(bits))
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp_sum(row: &mut [f32], m: f32) -> f32 {
        let n = row.len();
        let vm = _mm256_set1_ps(m);
        let mut vsum = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(i)), vm);
            let e = exp256(x);
            _mm256_storeu_ps(row.as_mut_ptr().add(i), e);
            vsum = _mm256_add_ps(vsum, e);
            i += 8;
        }
        let mut sum = hsum(vsum);
        while i < n {
            let e = (row[i] - m).exp();
            row[i] = e;
            sum += e;
            i += 1;
        }
        sum
    }

    // ---- sub-byte unpack + dequant (exact class: mul+add per lane) ----

    /// 8 bytes (low half of `b`) → 8 f32 code values.
    #[target_feature(enable = "avx2")]
    unsafe fn bytes_to_ps(b: __m128i) -> __m256 {
        _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(b))
    }

    /// 8 packed-nibble bytes → 16 codes in stored order (low nibble first),
    /// as two 8-lane f32 vectors.
    #[target_feature(enable = "avx2")]
    unsafe fn unpack16_b4(bytes: __m128i) -> (__m256, __m256) {
        let mask = _mm_set1_epi8(0x0F);
        let lo = _mm_and_si128(bytes, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(bytes), mask);
        let codes = _mm_unpacklo_epi8(lo, hi);
        (bytes_to_ps(codes), bytes_to_ps(_mm_srli_si128::<8>(codes)))
    }

    /// 4 packed-crumb bytes (in the low 32 bits) → 16 codes in stored
    /// order (lowest crumb first), as two 8-lane f32 vectors.
    #[target_feature(enable = "avx2")]
    unsafe fn unpack16_b2(bytes: __m128i) -> (__m256, __m256) {
        let mask = _mm_set1_epi8(0x03);
        let t0 = _mm_and_si128(bytes, mask);
        let t1 = _mm_and_si128(_mm_srli_epi16::<2>(bytes), mask);
        let t2 = _mm_and_si128(_mm_srli_epi16::<4>(bytes), mask);
        let t3 = _mm_and_si128(_mm_srli_epi16::<6>(bytes), mask);
        let p01 = _mm_unpacklo_epi8(t0, t1);
        let p23 = _mm_unpacklo_epi8(t2, t3);
        let codes = _mm_unpacklo_epi16(p01, p23);
        (bytes_to_ps(codes), bytes_to_ps(_mm_srli_si128::<8>(codes)))
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_b8(codes: &[u8], scale: &[f32], zero: &[f32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), out.len());
        let n = out.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let b = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
            let v = bytes_to_ps(b);
            let s = _mm256_loadu_ps(scale.as_ptr().add(i));
            let z = _mm256_loadu_ps(zero.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(_mm256_mul_ps(v, s), z));
            i += 8;
        }
        while i < n {
            out[i] = codes[i] as f32 * scale[i] + zero[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_b4(
        codes: &[u8],
        i0: usize,
        scale: &[f32],
        zero: &[f32],
        out: &mut [f32],
    ) {
        let n = out.len();
        let mut t = 0usize;
        // Scalar prologue to an even packed-code index (byte-aligned).
        while t < n && (i0 + t) & 1 != 0 {
            let i = i0 + t;
            out[t] = ((codes[i >> 1] >> 4) & 0x0F) as f32 * scale[t] + zero[t];
            t += 1;
        }
        while t + 16 <= n {
            let byte = (i0 + t) >> 1;
            let b = _mm_loadl_epi64(codes.as_ptr().add(byte) as *const __m128i);
            let (c0, c1) = unpack16_b4(b);
            let r0 = _mm256_add_ps(
                _mm256_mul_ps(c0, _mm256_loadu_ps(scale.as_ptr().add(t))),
                _mm256_loadu_ps(zero.as_ptr().add(t)),
            );
            let r1 = _mm256_add_ps(
                _mm256_mul_ps(c1, _mm256_loadu_ps(scale.as_ptr().add(t + 8))),
                _mm256_loadu_ps(zero.as_ptr().add(t + 8)),
            );
            _mm256_storeu_ps(out.as_mut_ptr().add(t), r0);
            _mm256_storeu_ps(out.as_mut_ptr().add(t + 8), r1);
            t += 16;
        }
        while t < n {
            let i = i0 + t;
            let code = (codes[i >> 1] >> ((i & 1) as u32 * 4)) & 0x0F;
            out[t] = code as f32 * scale[t] + zero[t];
            t += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_b2(
        codes: &[u8],
        i0: usize,
        scale: &[f32],
        zero: &[f32],
        out: &mut [f32],
    ) {
        let n = out.len();
        let mut t = 0usize;
        // Scalar prologue to a byte-aligned packed-code index (i % 4 == 0).
        while t < n && (i0 + t) & 3 != 0 {
            let i = i0 + t;
            let code = (codes[i >> 2] >> ((i & 3) as u32 * 2)) & 0x03;
            out[t] = code as f32 * scale[t] + zero[t];
            t += 1;
        }
        while t + 16 <= n {
            let byte = (i0 + t) >> 2;
            let w = u32::from_le_bytes([
                codes[byte],
                codes[byte + 1],
                codes[byte + 2],
                codes[byte + 3],
            ]);
            let (c0, c1) = unpack16_b2(_mm_cvtsi32_si128(w as i32));
            let r0 = _mm256_add_ps(
                _mm256_mul_ps(c0, _mm256_loadu_ps(scale.as_ptr().add(t))),
                _mm256_loadu_ps(zero.as_ptr().add(t)),
            );
            let r1 = _mm256_add_ps(
                _mm256_mul_ps(c1, _mm256_loadu_ps(scale.as_ptr().add(t + 8))),
                _mm256_loadu_ps(zero.as_ptr().add(t + 8)),
            );
            _mm256_storeu_ps(out.as_mut_ptr().add(t), r0);
            _mm256_storeu_ps(out.as_mut_ptr().add(t + 8), r1);
            t += 16;
        }
        while t < n {
            let i = i0 + t;
            let code = (codes[i >> 2] >> ((i & 3) as u32 * 2)) & 0x03;
            out[t] = code as f32 * scale[t] + zero[t];
            t += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_axpy_b8(
        p: f32,
        codes: &[u8],
        scale: &[f32],
        zero: &[f32],
        acc: &mut [f32],
    ) {
        debug_assert_eq!(codes.len(), acc.len());
        let n = acc.len();
        let vp = _mm256_set1_ps(p);
        let mut i = 0usize;
        while i + 8 <= n {
            let b = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
            let s = _mm256_loadu_ps(scale.as_ptr().add(i));
            let z = _mm256_loadu_ps(zero.as_ptr().add(i));
            let v = _mm256_add_ps(_mm256_mul_ps(bytes_to_ps(b), s), z);
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            // acc + p·v with separate mul+add: matches scalar bits.
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, _mm256_mul_ps(vp, v)));
            i += 8;
        }
        while i < n {
            acc[i] += p * (codes[i] as f32 * scale[i] + zero[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_axpy_b4(
        p: f32,
        codes: &[u8],
        i0: usize,
        scale: &[f32],
        zero: &[f32],
        acc: &mut [f32],
    ) {
        let n = acc.len();
        let vp = _mm256_set1_ps(p);
        let mut t = 0usize;
        while t < n && (i0 + t) & 1 != 0 {
            let i = i0 + t;
            let code = ((codes[i >> 1] >> 4) & 0x0F) as f32;
            acc[t] += p * (code * scale[t] + zero[t]);
            t += 1;
        }
        while t + 16 <= n {
            let byte = (i0 + t) >> 1;
            let b = _mm_loadl_epi64(codes.as_ptr().add(byte) as *const __m128i);
            let (c0, c1) = unpack16_b4(b);
            let v0 = _mm256_add_ps(
                _mm256_mul_ps(c0, _mm256_loadu_ps(scale.as_ptr().add(t))),
                _mm256_loadu_ps(zero.as_ptr().add(t)),
            );
            let v1 = _mm256_add_ps(
                _mm256_mul_ps(c1, _mm256_loadu_ps(scale.as_ptr().add(t + 8))),
                _mm256_loadu_ps(zero.as_ptr().add(t + 8)),
            );
            let a0 = _mm256_loadu_ps(acc.as_ptr().add(t));
            let a1 = _mm256_loadu_ps(acc.as_ptr().add(t + 8));
            _mm256_storeu_ps(acc.as_mut_ptr().add(t), _mm256_add_ps(a0, _mm256_mul_ps(vp, v0)));
            let s1 = _mm256_add_ps(a1, _mm256_mul_ps(vp, v1));
            _mm256_storeu_ps(acc.as_mut_ptr().add(t + 8), s1);
            t += 16;
        }
        while t < n {
            let i = i0 + t;
            let code = (codes[i >> 1] >> ((i & 1) as u32 * 4)) & 0x0F;
            acc[t] += p * (code as f32 * scale[t] + zero[t]);
            t += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_axpy_b2(
        p: f32,
        codes: &[u8],
        i0: usize,
        scale: &[f32],
        zero: &[f32],
        acc: &mut [f32],
    ) {
        let n = acc.len();
        let vp = _mm256_set1_ps(p);
        let mut t = 0usize;
        while t < n && (i0 + t) & 3 != 0 {
            let i = i0 + t;
            let code = (codes[i >> 2] >> ((i & 3) as u32 * 2)) & 0x03;
            acc[t] += p * (code as f32 * scale[t] + zero[t]);
            t += 1;
        }
        while t + 16 <= n {
            let byte = (i0 + t) >> 2;
            let w = u32::from_le_bytes([
                codes[byte],
                codes[byte + 1],
                codes[byte + 2],
                codes[byte + 3],
            ]);
            let (c0, c1) = unpack16_b2(_mm_cvtsi32_si128(w as i32));
            let v0 = _mm256_add_ps(
                _mm256_mul_ps(c0, _mm256_loadu_ps(scale.as_ptr().add(t))),
                _mm256_loadu_ps(zero.as_ptr().add(t)),
            );
            let v1 = _mm256_add_ps(
                _mm256_mul_ps(c1, _mm256_loadu_ps(scale.as_ptr().add(t + 8))),
                _mm256_loadu_ps(zero.as_ptr().add(t + 8)),
            );
            let a0 = _mm256_loadu_ps(acc.as_ptr().add(t));
            let a1 = _mm256_loadu_ps(acc.as_ptr().add(t + 8));
            _mm256_storeu_ps(acc.as_mut_ptr().add(t), _mm256_add_ps(a0, _mm256_mul_ps(vp, v0)));
            let s1 = _mm256_add_ps(a1, _mm256_mul_ps(vp, v1));
            _mm256_storeu_ps(acc.as_mut_ptr().add(t + 8), s1);
            t += 16;
        }
        while t < n {
            let i = i0 + t;
            let code = (codes[i >> 2] >> ((i & 3) as u32 * 2)) & 0x03;
            acc[t] += p * (code as f32 * scale[t] + zero[t]);
            t += 1;
        }
    }
}

/// NEON kernels (aarch64; ASIMD is mandatory there). The set is smaller
/// than AVX2 — `exp_sum` and the sub-byte dequants fall back to scalar on
/// this tier (documented in DESIGN.md). Exact-class kernels use separate
/// `vmulq`/`vaddq` (no `vfmaq`) so lanes match the scalar bits; `dot` and
/// `sum_squares` use FMA accumulators (reassociated class).
#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            let (av, bv) = (vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4)));
            acc1 = vfmaq_f32(acc1, av, bv);
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            let xv = vld1q_f32(x.as_ptr().add(i));
            let yv = vld1q_f32(y.as_ptr().add(i));
            // Separate mul+add (no vfmaq): lanes match scalar bits.
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vmulq_f32(va, xv)));
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn row_set(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(y.as_mut_ptr().add(i), vmulq_f32(va, vld1q_f32(x.as_ptr().add(i))));
            i += 4;
        }
        while i < n {
            y[i] = alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale(xs: &mut [f32], alpha: f32) {
        let n = xs.len();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(xs.as_mut_ptr().add(i), vmulq_f32(vld1q_f32(xs.as_ptr().add(i)), va));
            i += 4;
        }
        while i < n {
            xs[i] *= alpha;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn max(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut i = 0usize;
        let mut m = f32::NEG_INFINITY;
        if n >= 4 {
            let mut vm = vld1q_f32(xs.as_ptr());
            i = 4;
            while i + 4 <= n {
                vm = vmaxq_f32(vm, vld1q_f32(xs.as_ptr().add(i)));
                i += 4;
            }
            m = vmaxvq_f32(vm);
        }
        while i < n {
            m = m.max(xs[i]);
            i += 1;
        }
        m
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum_squares(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let v = vld1q_f32(xs.as_ptr().add(i));
            acc = vfmaq_f32(acc, v, v);
            i += 4;
        }
        let mut s = vaddvq_f32(acc);
        while i < n {
            s += xs[i] * xs[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn weighted_scale(x: &[f32], w: &[f32], alpha: f32, out: &mut [f32]) {
        debug_assert_eq!(x.len(), w.len());
        debug_assert_eq!(x.len(), out.len());
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            let xv = vmulq_f32(vld1q_f32(x.as_ptr().add(i)), va);
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(xv, vld1q_f32(w.as_ptr().add(i))));
            i += 4;
        }
        while i < n {
            out[i] = x[i] * alpha * w[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_b8(codes: &[u8], scale: &[f32], zero: &[f32], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), out.len());
        let n = out.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let b = vld1_u8(codes.as_ptr().add(i));
            let wid = vmovl_u8(b);
            let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wid)));
            let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wid)));
            let r0 = vaddq_f32(
                vmulq_f32(lo, vld1q_f32(scale.as_ptr().add(i))),
                vld1q_f32(zero.as_ptr().add(i)),
            );
            let r1 = vaddq_f32(
                vmulq_f32(hi, vld1q_f32(scale.as_ptr().add(i + 4))),
                vld1q_f32(zero.as_ptr().add(i + 4)),
            );
            vst1q_f32(out.as_mut_ptr().add(i), r0);
            vst1q_f32(out.as_mut_ptr().add(i + 4), r1);
            i += 8;
        }
        while i < n {
            out[i] = codes[i] as f32 * scale[i] + zero[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_axpy_b8(
        p: f32,
        codes: &[u8],
        scale: &[f32],
        zero: &[f32],
        acc: &mut [f32],
    ) {
        debug_assert_eq!(codes.len(), acc.len());
        let n = acc.len();
        let vp = vdupq_n_f32(p);
        let mut i = 0usize;
        while i + 8 <= n {
            let b = vld1_u8(codes.as_ptr().add(i));
            let wid = vmovl_u8(b);
            let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(wid)));
            let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(wid)));
            let v0 = vaddq_f32(
                vmulq_f32(lo, vld1q_f32(scale.as_ptr().add(i))),
                vld1q_f32(zero.as_ptr().add(i)),
            );
            let v1 = vaddq_f32(
                vmulq_f32(hi, vld1q_f32(scale.as_ptr().add(i + 4))),
                vld1q_f32(zero.as_ptr().add(i + 4)),
            );
            let a0 = vld1q_f32(acc.as_ptr().add(i));
            let a1 = vld1q_f32(acc.as_ptr().add(i + 4));
            vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a0, vmulq_f32(vp, v0)));
            vst1q_f32(acc.as_mut_ptr().add(i + 4), vaddq_f32(a1, vmulq_f32(vp, v1)));
            i += 8;
        }
        while i < n {
            acc[i] += p * (codes[i] as f32 * scale[i] + zero[i]);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatched wrappers. Each is a plain safe fn; the `unsafe` blocks below
// are justified by the tier check (the only way to reach an arch arm is for
// `tier()` to have detected that arch's features at runtime).
// ---------------------------------------------------------------------------

/// Unit-stride dot product (reassociated class: ≤1e-5 vs. scalar).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::dot(a, b) },
        _ => scalar::dot(a, b),
    }
}

/// y += alpha · x (exact class: bit-identical across tiers).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::axpy(alpha, x, y) },
        _ => scalar::axpy(alpha, x, y),
    }
}

/// y = alpha · x (exact class; the matmul zero-fold first pass).
#[inline]
pub fn row_set(alpha: f32, x: &[f32], y: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::row_set(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::row_set(alpha, x, y) },
        _ => scalar::row_set(alpha, x, y),
    }
}

/// xs *= alpha in place (exact class).
#[inline]
pub fn scale(xs: &mut [f32], alpha: f32) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::scale(xs, alpha) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::scale(xs, alpha) },
        _ => scalar::scale(xs, alpha),
    }
}

/// Max over a slice, −inf on empty (exact class — pure selection).
#[inline]
pub fn max(xs: &[f32]) -> f32 {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::max(xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::max(xs) },
        _ => scalar::max(xs),
    }
}

/// row[i] = exp(row[i] − m), returning the sum (reassociated class — the
/// AVX2 tier uses a Cephes polynomial exp; NEON falls back to scalar).
#[inline]
pub fn exp_sum(row: &mut [f32], m: f32) -> f32 {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::exp_sum(row, m) },
        _ => scalar::exp_sum(row, m),
    }
}

/// Σ x² (reassociated class; the rmsnorm mean-square scan).
#[inline]
pub fn sum_squares(xs: &[f32]) -> f32 {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::sum_squares(xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::sum_squares(xs) },
        _ => scalar::sum_squares(xs),
    }
}

/// out[i] = x[i] · alpha · w[i] (exact class; the rmsnorm apply scan).
#[inline]
pub fn weighted_scale(x: &[f32], w: &[f32], alpha: f32, out: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::weighted_scale(x, w, alpha, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::weighted_scale(x, w, alpha, out) },
        _ => scalar::weighted_scale(x, w, alpha, out),
    }
}

/// 8-bit dequant of one contiguous channel run (exact class).
#[inline]
pub fn dequant_b8(codes: &[u8], scale: &[f32], zero: &[f32], out: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dequant_b8(codes, scale, zero, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::dequant_b8(codes, scale, zero, out) },
        _ => scalar::dequant_b8(codes, scale, zero, out),
    }
}

/// 4-bit dequant starting at absolute packed-code index `i0` (exact class).
#[inline]
pub fn dequant_b4(codes: &[u8], i0: usize, scale: &[f32], zero: &[f32], out: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dequant_b4(codes, i0, scale, zero, out) },
        _ => scalar::dequant_b4(codes, i0, scale, zero, out),
    }
}

/// 2-bit dequant starting at absolute packed-code index `i0` (exact class).
#[inline]
pub fn dequant_b2(codes: &[u8], i0: usize, scale: &[f32], zero: &[f32], out: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dequant_b2(codes, i0, scale, zero, out) },
        _ => scalar::dequant_b2(codes, i0, scale, zero, out),
    }
}

/// Fused 8-bit dequant-GEMV row: acc += p · dequant(codes) (exact class —
/// bit-identical to `dequant_b8` + `axpy`, with no staging write).
#[inline]
pub fn dequant_axpy_b8(p: f32, codes: &[u8], scale: &[f32], zero: &[f32], acc: &mut [f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dequant_axpy_b8(p, codes, scale, zero, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64.
        SimdTier::Neon => unsafe { neon::dequant_axpy_b8(p, codes, scale, zero, acc) },
        _ => scalar::dequant_axpy_b8(p, codes, scale, zero, acc),
    }
}

/// Fused 4-bit dequant-GEMV row (exact class).
#[inline]
pub fn dequant_axpy_b4(
    p: f32,
    codes: &[u8],
    i0: usize,
    scale: &[f32],
    zero: &[f32],
    acc: &mut [f32],
) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dequant_axpy_b4(p, codes, i0, scale, zero, acc) },
        _ => scalar::dequant_axpy_b4(p, codes, i0, scale, zero, acc),
    }
}

/// Fused 2-bit dequant-GEMV row (exact class).
#[inline]
pub fn dequant_axpy_b2(
    p: f32,
    codes: &[u8],
    i0: usize,
    scale: &[f32],
    zero: &[f32],
    acc: &mut [f32],
) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2Fma is only returned after runtime AVX2+FMA detection.
        SimdTier::Avx2Fma => unsafe { avx2::dequant_axpy_b2(p, codes, i0, scale, zero, acc) },
        _ => scalar::dequant_axpy_b2(p, codes, i0, scale, zero, acc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Adversarial lengths: empty, single element, below/at/above the 8-
    /// and 16-lane widths, and a long tail-bearing run.
    const LENS: [usize; 12] = [0, 1, 3, 5, 7, 8, 9, 15, 16, 17, 33, 100];

    fn bits_eq(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: lane {i}: {x} vs {y}");
        }
    }

    fn rel_close(a: f32, b: f32, tol: f32, ctx: &str) {
        let denom = a.abs().max(b.abs()).max(1.0);
        assert!((a - b).abs() <= tol * denom, "{ctx}: {a} vs {b}");
    }

    #[test]
    fn tier_is_stable_and_named() {
        let t = tier();
        assert_eq!(t, tier(), "tier must be sticky after first detection");
        assert!(["avx2+fma", "neon", "scalar"].contains(&tier_name()));
    }

    #[test]
    fn exact_kernels_bit_match_scalar() {
        // axpy / row_set / scale / max / weighted_scale are exact-class:
        // the dispatched tier must reproduce the scalar bits at every
        // length, including non-multiples of the lane width.
        let mut rng = Rng::new(0x51D0);
        for &n in &LENS {
            let x = rng.normal_vec(n, 1.0);
            let w = rng.normal_vec(n, 1.0);
            let y0 = rng.normal_vec(n, 1.0);
            let alpha = 0.37f32;

            let mut ya = y0.clone();
            let mut yb = y0.clone();
            axpy(alpha, &x, &mut ya);
            scalar::axpy(alpha, &x, &mut yb);
            bits_eq(&ya, &yb, &format!("axpy n={n}"));

            let mut ra = vec![0.0f32; n];
            let mut rb = vec![1.0f32; n]; // different init: row_set must overwrite
            row_set(alpha, &x, &mut ra);
            scalar::row_set(alpha, &x, &mut rb);
            bits_eq(&ra, &rb, &format!("row_set n={n}"));

            let mut sa = x.clone();
            let mut sb = x.clone();
            scale(&mut sa, alpha);
            scalar::scale(&mut sb, alpha);
            bits_eq(&sa, &sb, &format!("scale n={n}"));

            assert_eq!(max(&x).to_bits(), scalar::max(&x).to_bits(), "max n={n}");

            let mut oa = vec![0.0f32; n];
            let mut ob = vec![0.0f32; n];
            weighted_scale(&x, &w, alpha, &mut oa);
            scalar::weighted_scale(&x, &w, alpha, &mut ob);
            bits_eq(&oa, &ob, &format!("weighted_scale n={n}"));
        }
    }

    #[test]
    fn reassociated_kernels_match_scalar_within_1e5() {
        let mut rng = Rng::new(0x51D1);
        for &n in &LENS {
            let a = rng.normal_vec(n, 1.0);
            let b = rng.normal_vec(n, 1.0);
            rel_close(dot(&a, &b), scalar::dot(&a, &b), 1e-5, &format!("dot n={n}"));
            rel_close(sum_squares(&a), scalar::sum_squares(&a), 1e-5, &format!("ssq n={n}"));
        }
    }

    #[test]
    fn exp_sum_matches_scalar_on_softmax_range() {
        // Softmax-shaped inputs: row − max ∈ [−20, 0]. The AVX2 Cephes exp
        // must track libm to well under the crate's 1e-5 parity bar, both
        // per element and in the returned sum.
        let mut rng = Rng::new(0x51D2);
        for &n in &LENS {
            let base: Vec<f32> =
                rng.normal_vec(n, 1.0).iter().map(|v| -(v.abs().min(4.0) * 5.0)).collect();
            let mut ra = base.clone();
            let mut rb = base.clone();
            let sa = exp_sum(&mut ra, 0.0);
            let sb = scalar::exp_sum(&mut rb, 0.0);
            rel_close(sa, sb, 1e-5, &format!("exp_sum sum n={n}"));
            for (i, (x, y)) in ra.iter().zip(&rb).enumerate() {
                rel_close(*x, *y, 1e-5, &format!("exp_sum n={n} lane {i}"));
            }
            // A shifted max exercises the m-subtraction path.
            if n > 0 {
                let m = scalar::max(&base);
                let mut rc = base.clone();
                let mut rd = base.clone();
                rel_close(
                    exp_sum(&mut rc, m),
                    scalar::exp_sum(&mut rd, m),
                    1e-5,
                    &format!("exp_sum shifted n={n}"),
                );
            }
        }
    }

    #[test]
    fn dequant_kernels_bit_match_scalar_across_alignments() {
        // Sub-byte kernels must agree with the scalar unpack at every
        // starting alignment (page slices start at arbitrary channel
        // offsets) and every width class.
        let mut rng = Rng::new(0x51D3);
        let codes: Vec<u8> = (0..64).map(|_| rng.below(256) as u8).collect();
        for &w in &LENS {
            let scale: Vec<f32> = (0..w).map(|_| 0.01 * (rng.below(100) as f32 + 1.0)).collect();
            let zero = rng.normal_vec(w, 1.0);
            // b8: codes are one byte per channel, no alignment dimension.
            if w <= codes.len() {
                let mut oa = vec![0.0f32; w];
                let mut ob = vec![0.0f32; w];
                dequant_b8(&codes[..w], &scale, &zero, &mut oa);
                scalar::dequant_b8(&codes[..w], &scale, &zero, &mut ob);
                bits_eq(&oa, &ob, &format!("dequant_b8 w={w}"));
            }
            for i0 in 0..5usize {
                let mut oa = vec![0.0f32; w];
                let mut ob = vec![0.0f32; w];
                dequant_b4(&codes, i0, &scale, &zero, &mut oa);
                scalar::dequant_b4(&codes, i0, &scale, &zero, &mut ob);
                bits_eq(&oa, &ob, &format!("dequant_b4 w={w} i0={i0}"));

                let mut pa = vec![0.0f32; w];
                let mut pb = vec![0.0f32; w];
                dequant_b2(&codes, i0, &scale, &zero, &mut pa);
                scalar::dequant_b2(&codes, i0, &scale, &zero, &mut pb);
                bits_eq(&pa, &pb, &format!("dequant_b2 w={w} i0={i0}"));
            }
        }
    }

    #[test]
    fn dequant_axpy_is_bit_identical_to_dequant_then_axpy() {
        // The fusion contract: skipping the staging panel must not change
        // a single bit, at any tier — this is what lets the fused value
        // path keep every existing attention parity test green.
        let mut rng = Rng::new(0x51D4);
        let codes: Vec<u8> = (0..64).map(|_| rng.below(256) as u8).collect();
        let p = 0.123f32;
        for &w in &LENS {
            let scale: Vec<f32> = (0..w).map(|_| 0.01 * (rng.below(100) as f32 + 1.0)).collect();
            let zero = rng.normal_vec(w, 1.0);
            let acc0 = rng.normal_vec(w, 1.0);
            for i0 in 0..5usize {
                // b4
                let mut fused = acc0.clone();
                dequant_axpy_b4(p, &codes, i0, &scale, &zero, &mut fused);
                let mut staged = vec![0.0f32; w];
                dequant_b4(&codes, i0, &scale, &zero, &mut staged);
                let mut unfused = acc0.clone();
                axpy(p, &staged, &mut unfused);
                bits_eq(&fused, &unfused, &format!("fused b4 w={w} i0={i0}"));
                // b2
                let mut fused2 = acc0.clone();
                dequant_axpy_b2(p, &codes, i0, &scale, &zero, &mut fused2);
                let mut staged2 = vec![0.0f32; w];
                dequant_b2(&codes, i0, &scale, &zero, &mut staged2);
                let mut unfused2 = acc0.clone();
                axpy(p, &staged2, &mut unfused2);
                bits_eq(&fused2, &unfused2, &format!("fused b2 w={w} i0={i0}"));
            }
            if w <= codes.len() {
                let mut fused = acc0.clone();
                dequant_axpy_b8(p, &codes[..w], &scale, &zero, &mut fused);
                let mut staged = vec![0.0f32; w];
                dequant_b8(&codes[..w], &scale, &zero, &mut staged);
                let mut unfused = acc0.clone();
                axpy(p, &staged, &mut unfused);
                bits_eq(&fused, &unfused, &format!("fused b8 w={w}"));
            }
        }
    }

    #[test]
    fn prop_axpy_parity_random_shapes() {
        // Property form of the exact-class contract over random vectors.
        crate::util::prop::check(
            "simd-axpy-bit-parity",
            100,
            |r| {
                let n = r.below(70);
                r.normal_vec(n, 1.0)
            },
            |x| {
                let mut ya = vec![0.25f32; x.len()];
                let mut yb = ya.clone();
                axpy(1.5, x, &mut ya);
                scalar::axpy(1.5, x, &mut yb);
                ya.iter().zip(&yb).all(|(a, b)| a.to_bits() == b.to_bits())
            },
        );
    }

    #[test]
    fn prop_dot_parity_random_shapes() {
        crate::util::prop::check(
            "simd-dot-parity",
            100,
            |r| {
                let n = r.below(70);
                r.normal_vec(n, 1.0)
            },
            |x| {
                let d = dot(x, x);
                let s = scalar::dot(x, x);
                (d - s).abs() <= 1e-5 * d.abs().max(s.abs()).max(1.0)
            },
        );
    }
}

//! Slice-level numeric kernels (matmul, softmax, norms, elementwise).
//!
//! These operate on raw `&[f32]` so the KV-cache and attention hot paths can
//! run without constructing `Mat` wrappers or allocating.
//!
//! The two attention workhorses live here with caller-owned scratch:
//! [`causal_attend_chunk`] + [`ChunkAttendScratch`] for batched prefill
//! (many queries over a dense causal cache) and [`sparse_attend`] +
//! [`SparseAttendScratch`] for sparse decode (one query over a gathered
//! token subset). Both follow the same contract: strided per-KV-head
//! columns are packed once into contiguous panels, every matmul inner loop
//! is unit-stride, and repeated calls reuse the scratch so steady-state
//! decode performs zero heap allocations.

/// out[m,n] = a[m,k] @ b[k,n]   (row-major, out must be zeroed or will be overwritten)
///
/// i-k-j loop order keeps both the `b` row and `out` row unit-stride, which
/// is the standard cache-friendly ordering for row-major operands. The
/// inner loop is branch-free so LLVM can vectorize it; callers whose `a`
/// rows are mostly zero (masked probability rows) should use
/// [`matmul_masked`] instead.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// [`matmul`] variant that skips zero entries of `a`.
///
/// Same contract as `matmul`, but each `a[i,p] == 0.0` short-circuits the
/// whole `b` row. Only worth it when `a` rows are *structurally* sparse —
/// causally masked score rows, gathered token subsets — because the branch
/// defeats auto-vectorization on dense inputs.
pub fn matmul_masked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out[m,n] = a[m,k] @ b[n,k]ᵀ — both operands row-major; the inner loop is a
/// dot product of two unit-stride rows (ideal for auto-vectorization).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = dot(arow, brow);
        }
    }
}

/// Unit-stride dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation; lets LLVM vectorize without -ffast-math.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// In-place numerically-stable softmax over one row.
pub fn softmax(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Softmax over each row of an (m, n) row-major buffer.
pub fn softmax_rows(buf: &mut [f32], m: usize, n: usize) {
    assert_eq!(buf.len(), m * n);
    for r in 0..m {
        softmax(&mut buf[r * n..(r + 1) * n]);
    }
}

/// Reusable buffers for [`causal_attend_chunk`]: per-KV-head key/value
/// panels plus query/score/output tiles. Callers keep one per backend so
/// chunked prefill doesn't heap-allocate on every layer-chunk call (the
/// crate's hot paths are otherwise allocation-free); buffers grow to the
/// largest cache seen and are retained.
#[derive(Default)]
pub struct ChunkAttendScratch {
    khead: Vec<f32>,
    vhead: Vec<f32>,
    qtile: Vec<f32>,
    scores: Vec<f32>,
    otile: Vec<f32>,
}

/// Blocked causal multi-head attention for a chunk of queries over a dense
/// post-RoPE KV cache — the batched-prefill workhorse.
///
/// * `qs`: (n, n_heads·d) row-major **post-RoPE** queries; row `t` belongs
///   to absolute position `len - n + t`.
/// * `keys` / `values`: (len, n_kv_heads·d) row-major post-RoPE cache
///   (the chunk's own rows are already appended, i.e. `len` includes them).
/// * Causality: query row `t` attends to cache rows `0..=len - n + t`.
/// * `out`: (n, n_heads·d), overwritten.
///
/// Blocking scheme: per KV head the (strided) key/value columns are packed
/// once into contiguous (len, d) panels; query tiles of up to 16
/// rows then compute a (tile, visible) score panel with one [`matmul_tn`]
/// (QKᵀ), row-softmax over each row's causal prefix, and one PV
/// [`matmul_masked`] (the causally masked score tails are structural
/// zeros — exactly the sparse-row shape that kernel exists for). This
/// turns the token-at-a-time dot/axpy decode pattern into cache-friendly
/// matmuls with unit-stride inner loops.
#[allow(clippy::too_many_arguments)]
pub fn causal_attend_chunk(
    qs: &[f32],
    keys: &[f32],
    values: &[f32],
    n: usize,
    len: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    scratch: &mut ChunkAttendScratch,
    out: &mut [f32],
) {
    assert!(n > 0 && n <= len, "chunk {n} vs cache {len}");
    assert_eq!(n_heads % n_kv_heads, 0);
    let kvd = n_kv_heads * d;
    let qd = n_heads * d;
    assert_eq!(qs.len(), n * qd);
    assert_eq!(keys.len(), len * kvd);
    assert_eq!(values.len(), len * kvd);
    assert_eq!(out.len(), n * qd);
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();
    let start = len - n; // absolute position of query row 0

    const Q_TILE: usize = 16;
    let ChunkAttendScratch { khead, vhead, qtile, scores, otile } = scratch;
    khead.resize(len * d, 0.0);
    vhead.resize(len * d, 0.0);
    qtile.resize(Q_TILE * d, 0.0);
    scores.resize(Q_TILE * len, 0.0);
    otile.resize(Q_TILE * d, 0.0);

    for kvh in 0..n_kv_heads {
        // Pack this KV head's strided columns into contiguous panels once;
        // every query head of the group and every tile reuses them.
        for j in 0..len {
            let src = j * kvd + kvh * d;
            khead[j * d..(j + 1) * d].copy_from_slice(&keys[src..src + d]);
            vhead[j * d..(j + 1) * d].copy_from_slice(&values[src..src + d]);
        }
        for h in kvh * group..(kvh + 1) * group {
            let mut t0 = 0;
            while t0 < n {
                let tb = Q_TILE.min(n - t0);
                // Pre-scaled query tile: folds the 1/sqrt(d) into QKᵀ.
                for t in 0..tb {
                    let src = (t0 + t) * qd + h * d;
                    let dst = &mut qtile[t * d..(t + 1) * d];
                    dst.copy_from_slice(&qs[src..src + d]);
                    for x in dst.iter_mut() {
                        *x *= scale;
                    }
                }
                // Rows visible to the last query of the tile bound the panel.
                let vis_max = start + t0 + tb;
                matmul_tn(
                    &qtile[..tb * d],
                    &khead[..vis_max * d],
                    &mut scores[..tb * vis_max],
                    tb,
                    d,
                    vis_max,
                );
                for t in 0..tb {
                    let vis = start + t0 + t + 1;
                    let row = &mut scores[t * vis_max..(t + 1) * vis_max];
                    softmax(&mut row[..vis]);
                    row[vis..].fill(0.0); // mask future keys of later tile rows
                }
                // PV over rows whose masked tails are structural zeros.
                matmul_masked(
                    &scores[..tb * vis_max],
                    &vhead[..vis_max * d],
                    &mut otile[..tb * d],
                    tb,
                    vis_max,
                    d,
                );
                for t in 0..tb {
                    let dst = (t0 + t) * qd + h * d;
                    out[dst..dst + d].copy_from_slice(&otile[t * d..(t + 1) * d]);
                }
                t0 += tb;
            }
        }
    }
}

/// Reusable buffers for [`sparse_attend`]: per-KV-head key/value panels, a
/// pre-scaled query tile, and the score rows. One per backend — the decode
/// hot path must not heap-allocate per (layer, token) call (see the
/// crate-wide invariant in `attention/mod.rs`); buffers grow to the largest
/// selection seen and are retained.
#[derive(Default)]
pub struct SparseAttendScratch {
    khead: Vec<f32>,
    vhead: Vec<f32>,
    qtile: Vec<f32>,
    scores: Vec<f32>,
}

/// Packed exact sparse attention over a gathered token subset — the shared
/// decode epilogue of every token-sparse backend (SALS Eq. 5, and the
/// gathered-attention step of Quest/Loki/DoubleSparse/HShare/StreamingLLM;
/// KIVI/Palu use it over their full dequantized/reconstructed caches).
///
/// * `q`: **post-RoPE** stacked query, (n_heads·d).
/// * `keys` / `values`: (n_sel, n_kv_heads·d) row-major post-RoPE subset.
/// * `out`: (n_heads·d), overwritten. `n_sel == 0` writes zeros.
///
/// Blocking scheme (the decode-shaped sibling of [`causal_attend_chunk`]):
/// per KV head the strided key/value columns are packed **once** into
/// contiguous (n_sel, d) panels (skipped entirely when `n_kv_heads == 1`,
/// where the cache rows already are the panel); the group's query heads —
/// consecutive in `q` — form one pre-scaled (group, d) tile, so QKᵀ is a
/// single [`matmul_tn`], softmax is [`softmax_rows`], and PV is one
/// [`matmul`], all with unit-stride inner loops. This replaces the
/// per-head strided dot/axpy loop (and its per-call scores allocation)
/// that previously dominated the sparse decode profile.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attend(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    scratch: &mut SparseAttendScratch,
    out: &mut [f32],
) {
    assert_eq!(n_heads % n_kv_heads, 0);
    let kvd = n_kv_heads * d;
    let qd = n_heads * d;
    assert_eq!(q.len(), qd);
    assert_eq!(keys.len(), n_sel * kvd);
    assert_eq!(values.len(), n_sel * kvd);
    assert_eq!(out.len(), qd);
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();

    let SparseAttendScratch { khead, vhead, qtile, scores } = scratch;
    qtile.resize(group * d, 0.0);
    scores.resize(group * n_sel, 0.0);
    if n_kv_heads > 1 {
        khead.resize(n_sel * d, 0.0);
        vhead.resize(n_sel * d, 0.0);
    }

    for kvh in 0..n_kv_heads {
        // Contiguous (n_sel, d) panels for this KV head. A single-KV-head
        // cache IS the panel — no copy.
        let (kp, vp): (&[f32], &[f32]) = if n_kv_heads == 1 {
            (keys, values)
        } else {
            for j in 0..n_sel {
                let src = j * kvd + kvh * d;
                khead[j * d..(j + 1) * d].copy_from_slice(&keys[src..src + d]);
                vhead[j * d..(j + 1) * d].copy_from_slice(&values[src..src + d]);
            }
            (&khead[..], &vhead[..])
        };
        // The group's query heads are consecutive rows of q: one tile,
        // pre-scaled so 1/sqrt(d) folds into QKᵀ.
        let qbase = kvh * group * d;
        qtile.copy_from_slice(&q[qbase..qbase + group * d]);
        for x in qtile.iter_mut() {
            *x *= scale;
        }
        matmul_tn(qtile, kp, scores, group, d, n_sel);
        softmax_rows(scores, group, n_sel);
        matmul(scores, vp, &mut out[qbase..qbase + group * d], group, n_sel, d);
    }
}

/// Pack rows `idx` of a (·, row_len) row-major matrix into `out`
/// ((idx.len(), row_len), overwritten). The batched-decode embed: stacking
/// each sequence's current token embedding into one activation matrix is a
/// row gather over the embedding table.
pub fn gather_rows(src: &[f32], row_len: usize, idx: &[usize], out: &mut [f32]) {
    assert!(row_len > 0);
    assert_eq!(src.len() % row_len, 0);
    assert_eq!(out.len(), idx.len() * row_len);
    let n_rows = src.len() / row_len;
    for (t, &i) in idx.iter().enumerate() {
        assert!(i < n_rows, "gather_rows: row {i} out of range {n_rows}");
        out[t * row_len..(t + 1) * row_len].copy_from_slice(&src[i * row_len..(i + 1) * row_len]);
    }
}

/// Inverse of [`gather_rows`]: write the rows of `src`
/// ((idx.len(), row_len) row-major) to rows `idx` of `out`. Duplicate
/// indices are last-writer-wins (rows are processed in order).
pub fn scatter_rows(src: &[f32], row_len: usize, idx: &[usize], out: &mut [f32]) {
    assert!(row_len > 0);
    assert_eq!(src.len(), idx.len() * row_len);
    assert_eq!(out.len() % row_len, 0);
    let n_rows = out.len() / row_len;
    for (t, &i) in idx.iter().enumerate() {
        assert!(i < n_rows, "scatter_rows: row {i} out of range {n_rows}");
        out[i * row_len..(i + 1) * row_len].copy_from_slice(&src[t * row_len..(t + 1) * row_len]);
    }
}

/// Tied-embedding LM head over a batch of final hidden states:
/// `out[b, vocab] = x[b, d] @ embᵀ` where `emb` is the (vocab, d) embedding
/// matrix whose rows double as output projections. One [`matmul_tn`] —
/// the embedding table streams once for the whole batch instead of once
/// per sequence, which is the point of cross-sequence batched decode (the
/// LM head is the single largest weight matrix at decode time).
pub fn lm_head_batch(x: &[f32], emb: &[f32], out: &mut [f32], b: usize, d: usize, vocab: usize) {
    assert_eq!(emb.len(), vocab * d);
    matmul_tn(x, emb, out, b, d, vocab);
}

/// RMSNorm: x * w / sqrt(mean(x²) + eps). LLaMA-style (no mean subtraction).
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// SiLU (swish) activation: x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Scale a slice in place.
pub fn scale(xs: &mut [f32], alpha: f32) {
    for x in xs {
        *x *= alpha;
    }
}

/// argmax over a slice (first max wins). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1., 2., 3., 4.];
        let b = [1., 1., 1., 1.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, &mut out, 2, 2, 2);
        assert_eq!(out, [3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_tn_matches_matmul() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let (m, k, n) = (3, 17, 5);
        let a = rng.normal_vec(m * k, 1.0);
        let bt = rng.normal_vec(n * k, 1.0); // (n,k)
        // b = btᵀ as (k,n)
        let mut b = vec![0.0; k * n];
        for r in 0..n {
            for c in 0..k {
                b[c * n + r] = bt[r * k + c];
            }
        }
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        matmul(&a, &b, &mut o1, m, k, n);
        matmul_tn(&a, &bt, &mut o2, m, k, n);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_masked_matches_dense() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let (m, k, n) = (4, 9, 6);
        let mut a = rng.normal_vec(m * k, 1.0);
        // Inject structural zeros (masked tail of each row).
        for i in 0..m {
            for p in k - 3..k {
                a[i * k + p] = 0.0;
            }
        }
        let b = rng.normal_vec(k * n, 1.0);
        let mut dense = vec![0.0; m * n];
        let mut masked = vec![0.0; m * n];
        matmul(&a, &b, &mut dense, m, k, n);
        matmul_masked(&a, &b, &mut masked, m, k, n);
        for (x, y) in dense.iter().zip(&masked) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    /// Naive per-query reference for causal_attend_chunk.
    #[allow(clippy::too_many_arguments)]
    fn causal_reference(
        qs: &[f32],
        keys: &[f32],
        values: &[f32],
        n: usize,
        len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d: usize,
    ) -> Vec<f32> {
        let qd = n_heads * d;
        let kvd = n_kv_heads * d;
        let group = n_heads / n_kv_heads;
        let scale = 1.0 / (d as f32).sqrt();
        let start = len - n;
        let mut out = vec![0.0f32; n * qd];
        for t in 0..n {
            let vis = start + t + 1;
            for h in 0..n_heads {
                let kvh = h / group;
                let qh = &qs[t * qd + h * d..t * qd + (h + 1) * d];
                let mut s: Vec<f32> = (0..vis)
                    .map(|j| dot(qh, &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d]) * scale)
                    .collect();
                softmax(&mut s);
                let oh = &mut out[t * qd + h * d..t * qd + (h + 1) * d];
                for (j, &p) in s.iter().enumerate() {
                    axpy(p, &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d], oh);
                }
            }
        }
        out
    }

    #[test]
    fn causal_attend_chunk_matches_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        // n > Q_TILE to exercise multi-tile; GQA to exercise head groups;
        // start > 0 to exercise a pre-existing cache prefix.
        let (n_heads, n_kv_heads, d) = (4, 2, 8);
        let (len, n) = (41, 23);
        let qd = n_heads * d;
        let kvd = n_kv_heads * d;
        let qs = rng.normal_vec(n * qd, 1.0);
        let keys = rng.normal_vec(len * kvd, 1.0);
        let values = rng.normal_vec(len * kvd, 1.0);
        let mut out = vec![0.0f32; n * qd];
        let mut scratch = ChunkAttendScratch::default();
        causal_attend_chunk(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &mut scratch, &mut out);
        // Re-run with the now-warm scratch: reuse must not change results.
        let mut out2 = vec![0.0f32; n * qd];
        causal_attend_chunk(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &mut scratch, &mut out2);
        assert_eq!(out, out2);
        let reference = causal_reference(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn causal_attend_chunk_full_cache_single_token() {
        // n == len == 1: softmax over a singleton returns the value row.
        let d = 4;
        let qs = vec![0.3f32; d];
        let keys = vec![0.7f32; d];
        let values: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; d];
        let mut scratch = ChunkAttendScratch::default();
        causal_attend_chunk(&qs, &keys, &values, 1, 1, 1, 1, d, &mut scratch, &mut out);
        for (o, v) in out.iter().zip(&values) {
            assert!((o - v).abs() < 1e-6);
        }
    }

    /// Naive per-head reference for sparse_attend (the pre-packing decode
    /// pattern: strided dot/axpy per query head).
    fn sparse_reference(
        q: &[f32],
        keys: &[f32],
        values: &[f32],
        n_sel: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d: usize,
    ) -> Vec<f32> {
        let kvd = n_kv_heads * d;
        let group = n_heads / n_kv_heads;
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = vec![0.0f32; n_heads * d];
        let mut scores = vec![0.0f32; n_sel];
        for h in 0..n_heads {
            let kvh = h / group;
            let qh = &q[h * d..(h + 1) * d];
            for (j, s) in scores.iter_mut().enumerate() {
                *s = dot(qh, &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d]) * scale;
            }
            softmax(&mut scores);
            let oh = &mut out[h * d..(h + 1) * d];
            for (j, &p) in scores.iter().enumerate() {
                axpy(p, &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d], oh);
            }
        }
        out
    }

    #[test]
    fn sparse_attend_matches_reference_mha_and_gqa() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(29);
        for (n_heads, n_kv_heads, d, n_sel) in
            [(1usize, 1usize, 8usize, 13usize), (4, 4, 8, 7), (4, 2, 16, 21), (8, 2, 4, 1)]
        {
            let kvd = n_kv_heads * d;
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * kvd, 1.0);
            let values = rng.normal_vec(n_sel * kvd, 1.0);
            let mut out = vec![0.0f32; n_heads * d];
            let mut scratch = SparseAttendScratch::default();
            sparse_attend(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut scratch, &mut out);
            // Warm-scratch rerun must be identical (buffer reuse safety).
            let mut out2 = vec![0.0f32; n_heads * d];
            sparse_attend(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut scratch, &mut out2);
            assert_eq!(out, out2);
            let reference = sparse_reference(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d);
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "{n_heads}h/{n_kv_heads}kv: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_attend_empty_selection_zeroes_out() {
        let mut scratch = SparseAttendScratch::default();
        let q = vec![1.0f32; 8];
        let mut out = vec![7.0f32; 8];
        sparse_attend(&q, &[], &[], 0, 2, 1, 4, &mut scratch, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gather_scatter_rows_roundtrip() {
        // 5 rows of length 3.
        let src: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let idx = [4usize, 0, 2];
        let mut packed = vec![0.0f32; idx.len() * 3];
        gather_rows(&src, 3, &idx, &mut packed);
        assert_eq!(packed, vec![12., 13., 14., 0., 1., 2., 6., 7., 8.]);
        // Scatter back into a zeroed matrix: exactly the gathered rows land.
        let mut out = vec![0.0f32; 15];
        scatter_rows(&packed, 3, &idx, &mut out);
        for &i in &idx {
            assert_eq!(out[i * 3..(i + 1) * 3], src[i * 3..(i + 1) * 3]);
        }
        assert_eq!(out[3..6], [0.0, 0.0, 0.0]); // untouched row stays zero
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn gather_rows_rejects_out_of_range() {
        let src = [0.0f32; 6];
        let mut out = [0.0f32; 2];
        gather_rows(&src, 2, &[3], &mut out);
    }

    #[test]
    fn lm_head_batch_matches_per_row_dot() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let (b, d, vocab) = (3, 8, 11);
        let x = rng.normal_vec(b * d, 1.0);
        let emb = rng.normal_vec(vocab * d, 1.0);
        let mut out = vec![0.0f32; b * vocab];
        lm_head_batch(&x, &emb, &mut out, b, d, vocab);
        for r in 0..b {
            for t in 0..vocab {
                let reference = dot(&emb[t * d..(t + 1) * d], &x[r * d..(r + 1) * d]);
                assert_eq!(out[r * vocab + t], reference, "row {r} tok {t}");
            }
        }
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut row = [1.0f32, 2.0, 3.0];
        softmax(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut row = [1000.0f32, 1000.0, 999.0];
        softmax(&mut row);
        assert!(row.iter().all(|x| x.is_finite()));
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_weight_normalizes() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        let ms = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(10.0) > 9.9);
    }
}

//! Slice-level numeric kernels (matmul, softmax, norms, elementwise).
//!
//! These operate on raw `&[f32]` so the KV-cache and attention hot paths can
//! run without constructing `Mat` wrappers or allocating.

/// out[m,n] = a[m,k] @ b[k,n]   (row-major, out must be zeroed or will be overwritten)
///
/// i-k-j loop order keeps both the `b` row and `out` row unit-stride, which
/// is the standard cache-friendly ordering for row-major operands.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse rows (masked tokens) short-circuit
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out[m,n] = a[m,k] @ b[n,k]ᵀ — both operands row-major; the inner loop is a
/// dot product of two unit-stride rows (ideal for auto-vectorization).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = dot(arow, brow);
        }
    }
}

/// Unit-stride dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation; lets LLVM vectorize without -ffast-math.
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// In-place numerically-stable softmax over one row.
pub fn softmax(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Softmax over each row of an (m, n) row-major buffer.
pub fn softmax_rows(buf: &mut [f32], m: usize, n: usize) {
    assert_eq!(buf.len(), m * n);
    for r in 0..m {
        softmax(&mut buf[r * n..(r + 1) * n]);
    }
}

/// RMSNorm: x * w / sqrt(mean(x²) + eps). LLaMA-style (no mean subtraction).
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), out.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// SiLU (swish) activation: x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Scale a slice in place.
pub fn scale(xs: &mut [f32], alpha: f32) {
    for x in xs {
        *x *= alpha;
    }
}

/// argmax over a slice (first max wins). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1., 2., 3., 4.];
        let b = [1., 1., 1., 1.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, &mut out, 2, 2, 2);
        assert_eq!(out, [3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_tn_matches_matmul() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let (m, k, n) = (3, 17, 5);
        let a = rng.normal_vec(m * k, 1.0);
        let bt = rng.normal_vec(n * k, 1.0); // (n,k)
        // b = btᵀ as (k,n)
        let mut b = vec![0.0; k * n];
        for r in 0..n {
            for c in 0..k {
                b[c * n + r] = bt[r * k + c];
            }
        }
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        matmul(&a, &b, &mut o1, m, k, n);
        matmul_tn(&a, &bt, &mut o2, m, k, n);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut row = [1.0f32, 2.0, 3.0];
        softmax(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut row = [1000.0f32, 1000.0, 999.0];
        softmax(&mut row);
        assert!(row.iter().all(|x| x.is_finite()));
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_weight_normalizes() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        let ms = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(10.0) > 9.9);
    }
}

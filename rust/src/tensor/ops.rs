//! Slice-level numeric kernels (matmul, softmax, norms, elementwise).
//!
//! These operate on raw `&[f32]` so the KV-cache and attention hot paths can
//! run without constructing `Mat` wrappers or allocating.
//!
//! The attention workhorses live here with caller-owned scratch:
//! [`causal_attend_chunk`] + [`ChunkAttendScratch`] for batched prefill
//! (many queries over a dense causal cache), [`block_sparse_attend_chunk`]
//! + [`BlockSparseScratch`] for its block-sparse sibling (the same chunk
//! of queries visiting only selected key block ranges, folded through the
//! online-softmax accumulator), [`sparse_attend`] +
//! [`SparseAttendScratch`] for sparse decode over a *materialized*
//! gathered subset (with [`sparse_attend_threaded`] partitioning the
//! independent KV-head panels across workers), and [`fused_sparse_attend`]
//! + [`FusedAttendScratch`] for the §4.4-style fused decode where the
//! caller streams keys/values in L1-resident tiles (reconstruct + RoPE on
//! the fly) and an online softmax folds each tile in — the key panel and
//! the full score row never exist. All follow the same contract: strided
//! per-KV-head columns are packed once into contiguous panels (or arrive
//! per-head by construction), every matmul inner loop is unit-stride, and
//! repeated calls reuse the scratch so steady-state decode performs zero
//! heap allocations.
//!
//! The elementwise row kernels (dot, axpy, row-set, the softmax scans, the
//! rmsnorm scans) dispatch through [`crate::tensor::simd`] — runtime
//! AVX2+FMA / NEON with the pre-SIMD scalar loops retained as the parity
//! reference (see that module's exact-vs-reassociated contract).

use crate::tensor::simd;
use crate::util::threadpool::Workers;

/// out[m,n] = a[m,k] @ b[k,n]   (row-major, out is overwritten)
///
/// i-k-j loop order keeps both the `b` row and `out` row unit-stride, which
/// is the standard cache-friendly ordering for row-major operands. The row
/// kernels are the SIMD-dispatched [`simd::row_set`] / [`simd::axpy`]: the
/// `p == 0` pass *writes* each output row (folding the zeroing into the
/// first accumulation), so `out` streams once per call instead of being
/// cleared and then re-read. Callers whose `a` rows are mostly zero
/// (masked probability rows) should use [`matmul_masked`] instead.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        if k == 0 {
            orow.fill(0.0);
            continue;
        }
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            if p == 0 {
                simd::row_set(av, brow, orow);
            } else {
                simd::axpy(av, brow, orow);
            }
        }
    }
}

/// [`matmul`] variant that skips zero entries of `a`.
///
/// Same contract as `matmul`, but each `a[i,p] == 0.0` short-circuits the
/// whole `b` row. Only worth it when `a` rows are *structurally* sparse —
/// causally masked score rows, gathered token subsets. Like [`matmul`],
/// the first *surviving* row kernel writes the output row (zero-fold);
/// an all-zero `a` row falls back to an explicit fill.
pub fn matmul_masked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut init = false;
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            if init {
                simd::axpy(av, brow, orow);
            } else {
                simd::row_set(av, brow, orow);
                init = true;
            }
        }
        if !init {
            orow.fill(0.0);
        }
    }
}

/// out[m,n] += a[m,k] @ b[k,n] — the accumulate variant of [`matmul`].
///
/// Same loop structure, but `out` is NOT cleared first: this is the PV
/// partial-sum primitive of the flash-style online-softmax accumulator in
/// [`fused_sparse_attend`], where each key/value tile folds its
/// probability-weighted values into a running (rescaled) output.
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            simd::axpy(av, &b[p * n..(p + 1) * n], orow);
        }
    }
}

/// out[m,n] = a[m,k] @ b[n,k]ᵀ — both operands row-major; the inner loop is a
/// dot product of two unit-stride rows (ideal for auto-vectorization).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = dot(arow, brow);
        }
    }
}

/// Unit-stride dot product (SIMD-dispatched; see [`simd::dot`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

/// y += alpha * x (SIMD-dispatched; bit-identical across tiers).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    simd::axpy(alpha, x, y)
}

/// In-place numerically-stable softmax over one row: the max scan, the
/// exp/sum scan, and the 1/sum scale all dispatch through [`simd`].
pub fn softmax(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let max = simd::max(row);
    let sum = simd::exp_sum(row, max);
    simd::scale(row, 1.0 / sum);
}

/// Softmax over each row of an (m, n) row-major buffer.
pub fn softmax_rows(buf: &mut [f32], m: usize, n: usize) {
    assert_eq!(buf.len(), m * n);
    for r in 0..m {
        softmax(&mut buf[r * n..(r + 1) * n]);
    }
}

/// Reusable buffers for [`causal_attend_chunk`]: per-KV-head key/value
/// panels plus query/score/output tiles. Callers keep one per backend so
/// chunked prefill doesn't heap-allocate on every layer-chunk call (the
/// crate's hot paths are otherwise allocation-free); buffers grow to the
/// largest cache seen and are retained.
#[derive(Default)]
pub struct ChunkAttendScratch {
    khead: Vec<f32>,
    vhead: Vec<f32>,
    qtile: Vec<f32>,
    scores: Vec<f32>,
    otile: Vec<f32>,
}

/// Blocked causal multi-head attention for a chunk of queries over a dense
/// post-RoPE KV cache — the batched-prefill workhorse.
///
/// * `qs`: (n, n_heads·d) row-major **post-RoPE** queries; row `t` belongs
///   to absolute position `len - n + t`.
/// * `keys` / `values`: (len, n_kv_heads·d) row-major post-RoPE cache
///   (the chunk's own rows are already appended, i.e. `len` includes them).
/// * Causality: query row `t` attends to cache rows `0..=len - n + t`.
/// * `out`: (n, n_heads·d), overwritten.
///
/// Blocking scheme: per KV head the (strided) key/value columns are packed
/// once into contiguous (len, d) panels; query tiles of up to 16
/// rows then compute a (tile, visible) score panel with one [`matmul_tn`]
/// (QKᵀ), row-softmax over each row's causal prefix, and one PV
/// [`matmul_masked`] (the causally masked score tails are structural
/// zeros — exactly the sparse-row shape that kernel exists for). This
/// turns the token-at-a-time dot/axpy decode pattern into cache-friendly
/// matmuls with unit-stride inner loops.
#[allow(clippy::too_many_arguments)]
pub fn causal_attend_chunk(
    qs: &[f32],
    keys: &[f32],
    values: &[f32],
    n: usize,
    len: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    scratch: &mut ChunkAttendScratch,
    out: &mut [f32],
) {
    causal_attend_chunk_seg(qs, &[keys], &[values], n, len, n_heads, n_kv_heads, d, scratch, out);
}

/// [`causal_attend_chunk`] over a cache stored as consecutive row
/// **segments** (each `(rows_i, n_kv_heads·d)` row-major; segments
/// concatenate to the `(len, kv_dim)` cache). The kernel packs strided
/// key/value columns into contiguous per-head panels before any
/// arithmetic, so feeding the pack loop from several contiguous pieces is
/// bit-identical to one flat buffer — this is what lets a shared-prefix
/// cache (immutable `Arc` panel + private tail, see
/// `attention::SharedVec`) run blocked prefill without re-materializing a
/// flat copy of the prefix.
#[allow(clippy::too_many_arguments)]
pub fn causal_attend_chunk_seg(
    qs: &[f32],
    key_segs: &[&[f32]],
    val_segs: &[&[f32]],
    n: usize,
    len: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    scratch: &mut ChunkAttendScratch,
    out: &mut [f32],
) {
    assert!(n > 0 && n <= len, "chunk {n} vs cache {len}");
    assert_eq!(n_heads % n_kv_heads, 0);
    let kvd = n_kv_heads * d;
    let qd = n_heads * d;
    assert_eq!(qs.len(), n * qd);
    assert_eq!(key_segs.len(), val_segs.len());
    let seg_rows: usize = key_segs.iter().map(|s| s.len() / kvd).sum();
    assert_eq!(seg_rows, len, "segments must cover the cache");
    for (ks, vs) in key_segs.iter().zip(val_segs) {
        assert_eq!(ks.len() % kvd, 0);
        assert_eq!(ks.len(), vs.len());
    }
    assert_eq!(out.len(), n * qd);
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();
    let start = len - n; // absolute position of query row 0

    const Q_TILE: usize = 16;
    let ChunkAttendScratch { khead, vhead, qtile, scores, otile } = scratch;
    khead.resize(len * d, 0.0);
    vhead.resize(len * d, 0.0);
    qtile.resize(Q_TILE * d, 0.0);
    scores.resize(Q_TILE * len, 0.0);
    otile.resize(Q_TILE * d, 0.0);

    for kvh in 0..n_kv_heads {
        // Pack this KV head's strided columns into contiguous panels once;
        // every query head of the group and every tile reuses them. Rows
        // stream segment by segment — same row order as a flat cache.
        let mut j0 = 0usize;
        for (ks, vs) in key_segs.iter().zip(val_segs) {
            let rows = ks.len() / kvd;
            for j in 0..rows {
                let src = j * kvd + kvh * d;
                let dst = (j0 + j) * d;
                khead[dst..dst + d].copy_from_slice(&ks[src..src + d]);
                vhead[dst..dst + d].copy_from_slice(&vs[src..src + d]);
            }
            j0 += rows;
        }
        for h in kvh * group..(kvh + 1) * group {
            let mut t0 = 0;
            while t0 < n {
                let tb = Q_TILE.min(n - t0);
                // Pre-scaled query tile: folds the 1/sqrt(d) into QKᵀ.
                for t in 0..tb {
                    let src = (t0 + t) * qd + h * d;
                    let dst = &mut qtile[t * d..(t + 1) * d];
                    dst.copy_from_slice(&qs[src..src + d]);
                    for x in dst.iter_mut() {
                        *x *= scale;
                    }
                }
                // Rows visible to the last query of the tile bound the panel.
                let vis_max = start + t0 + tb;
                matmul_tn(
                    &qtile[..tb * d],
                    &khead[..vis_max * d],
                    &mut scores[..tb * vis_max],
                    tb,
                    d,
                    vis_max,
                );
                for t in 0..tb {
                    let vis = start + t0 + t + 1;
                    let row = &mut scores[t * vis_max..(t + 1) * vis_max];
                    softmax(&mut row[..vis]);
                    row[vis..].fill(0.0); // mask future keys of later tile rows
                }
                // PV over rows whose masked tails are structural zeros.
                matmul_masked(
                    &scores[..tb * vis_max],
                    &vhead[..vis_max * d],
                    &mut otile[..tb * d],
                    tb,
                    vis_max,
                    d,
                );
                for t in 0..tb {
                    let dst = (t0 + t) * qd + h * d;
                    out[dst..dst + d].copy_from_slice(&otile[t * d..(t + 1) * d]);
                }
                t0 += tb;
            }
        }
    }
}

/// One KV head's working set for [`block_sparse_attend_chunk`]: packed
/// key/value panels over the selected blocks, the pre-scaled query tile,
/// the per-key-tile score block, the online-softmax state (running max /
/// denominator / PV partial per query row of the tile), and the head's
/// private output panel. One lane **per KV head** (not per worker):
/// chunk output rows interleave heads, so each lane accumulates into its
/// own (n, group·d) panel and a serial epilogue scatters — the fan-out
/// shares no buffers and stays bit-invariant in the thread count.
#[derive(Default)]
struct BlockSparseLane {
    khead: Vec<f32>,
    vhead: Vec<f32>,
    qtile: Vec<f32>,
    scores: Vec<f32>,
    m: Vec<f32>,
    l: Vec<f32>,
    acc: Vec<f32>,
    ohead: Vec<f32>,
}

/// Reusable buffers for [`block_sparse_attend_chunk`]: the shared
/// visible-prefix table plus one [`BlockSparseLane`] per KV head. This is
/// prefill-sized scratch (panels scale with the selected rows of the full
/// cache) — backends drop it in `end_prefill`, exactly like
/// [`ChunkAttendScratch`]; within a prefill it grows to high-water marks
/// and is retained so repeated chunk calls do not heap-allocate.
#[derive(Default)]
pub struct BlockSparseScratch {
    vis: Vec<usize>,
    lanes: Vec<BlockSparseLane>,
}

/// Block-sparse causal multi-head attention for a chunk of queries — the
/// prefill sibling of [`causal_attend_chunk`] that visits only a selected
/// set of key *block ranges* instead of the whole cache.
///
/// * `qs`: (n, n_heads·d) **post-RoPE** queries; row `t` is absolute
///   position `len - n + t`.
/// * `keys` / `values`: (len, n_kv_heads·d) post-RoPE cache (the chunk's
///   own rows already appended).
/// * `blocks`: sorted, disjoint, half-open `[lo, hi)` cache-row ranges
///   with `hi <= len`. Query row `t` attends to the intersection of
///   `∪ blocks` with its causal prefix `0..=len-n+t`. The caller is
///   responsible for including each query's own diagonal block (the SALS
///   selector always retains sink + diagonal-window blocks); a row whose
///   visible selection is empty gets a zero output row, mirroring
///   [`fused_sparse_attend`]'s empty-selection contract.
/// * `workers`: per-KV-head fan-out handle (serial handle = inline).
///   Per-head arithmetic is fixed and the output scatter is serial, so
///   results are **bit-invariant in the handle width and backing pool**.
/// * `out`: (n, n_heads·d), overwritten.
///
/// Because `blocks` is sorted, the packed panel's rows are in ascending
/// cache order and each query's visible selection is a *prefix* of the
/// packed panel — so causal masking stays a per-row prefix bound (the
/// `vis` table), exactly as in the dense kernel. The packed prefix is
/// folded in [`FUSED_TILE`]-column tiles through the flash-style online
/// softmax (running max `m`, rescaled denominator `l`, rescaled PV
/// partial `acc` per query row), so a (tile, n_sel) score row never
/// materializes and partial block sets are numerically stable no matter
/// how score magnitudes are distributed across blocks.
#[allow(clippy::too_many_arguments)]
pub fn block_sparse_attend_chunk(
    qs: &[f32],
    keys: &[f32],
    values: &[f32],
    n: usize,
    len: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    blocks: &[(usize, usize)],
    workers: &Workers,
    scratch: &mut BlockSparseScratch,
    out: &mut [f32],
) {
    assert!(n > 0 && n <= len, "chunk {n} vs cache {len}");
    assert_eq!(n_heads % n_kv_heads, 0);
    let kvd = n_kv_heads * d;
    let qd = n_heads * d;
    assert_eq!(qs.len(), n * qd);
    assert_eq!(keys.len(), len * kvd);
    assert_eq!(values.len(), len * kvd);
    assert_eq!(out.len(), n * qd);
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();
    let start = len - n;
    let mut n_sel = 0usize;
    {
        let mut prev_hi = 0usize;
        for (i, &(lo, hi)) in blocks.iter().enumerate() {
            assert!(lo < hi && hi <= len, "block {i} [{lo},{hi}) vs cache {len}");
            assert!(i == 0 || lo >= prev_hi, "block {i} [{lo},{hi}) overlaps/unsorted");
            prev_hi = hi;
            n_sel += hi - lo;
        }
    }

    const Q_TILE: usize = 16;
    let BlockSparseScratch { vis, lanes } = scratch;

    // Per-query visible-prefix lengths over the packed panel: row t (abs
    // pos start+t) sees the packed rows whose cache index is ≤ start+t —
    // a prefix, since blocks are sorted. Monotone two-pointer sweep.
    vis.clear();
    vis.reserve(n);
    {
        let mut cum = 0usize;
        let mut b = 0usize;
        for t in 0..n {
            let p = start + t; // inclusive causal limit
            while b < blocks.len() && blocks[b].1 <= p + 1 {
                cum += blocks[b].1 - blocks[b].0;
                b += 1;
            }
            let partial = match blocks.get(b) {
                Some(&(lo, _)) if lo <= p => p + 1 - lo,
                _ => 0,
            };
            vis.push(cum + partial);
        }
    }
    let vis: &[usize] = vis;

    let run = |kvh: usize, lane: &mut BlockSparseLane| {
        // Pack this head's selected key/value rows once; block ranges are
        // contiguous cache rows, so each copies as a strided row run.
        lane.khead.resize(n_sel * d, 0.0);
        lane.vhead.resize(n_sel * d, 0.0);
        let mut p = 0usize;
        for &(lo, hi) in blocks {
            for j in lo..hi {
                let src = j * kvd + kvh * d;
                lane.khead[p * d..(p + 1) * d].copy_from_slice(&keys[src..src + d]);
                lane.vhead[p * d..(p + 1) * d].copy_from_slice(&values[src..src + d]);
                p += 1;
            }
        }
        lane.qtile.resize(Q_TILE * d, 0.0);
        lane.scores.resize(Q_TILE * FUSED_TILE, 0.0);
        lane.m.resize(Q_TILE, 0.0);
        lane.l.resize(Q_TILE, 0.0);
        lane.acc.resize(Q_TILE * d, 0.0);
        lane.ohead.resize(n * group * d, 0.0);
        for g in 0..group {
            let h = kvh * group + g;
            let mut t0 = 0;
            while t0 < n {
                let tb = Q_TILE.min(n - t0);
                // Pre-scaled query tile: folds 1/sqrt(d) into QKᵀ.
                for t in 0..tb {
                    let src = (t0 + t) * qd + h * d;
                    let dst = &mut lane.qtile[t * d..(t + 1) * d];
                    dst.copy_from_slice(&qs[src..src + d]);
                    simd::scale(dst, scale);
                }
                lane.m[..tb].fill(f32::NEG_INFINITY);
                lane.l[..tb].fill(0.0);
                lane.acc[..tb * d].fill(0.0);
                // Packed columns visible to the tile's last row bound the
                // key-tile sweep; earlier rows mask within each tile.
                let vis_hi = vis[t0 + tb - 1];
                let mut klo = 0;
                while klo < vis_hi {
                    let khi = (klo + FUSED_TILE).min(vis_hi);
                    let kt = khi - klo;
                    matmul_tn(
                        &lane.qtile[..tb * d],
                        &lane.khead[klo * d..khi * d],
                        &mut lane.scores[..tb * kt],
                        tb,
                        d,
                        kt,
                    );
                    for t in 0..tb {
                        let c = vis[t0 + t].saturating_sub(klo).min(kt);
                        let row = &mut lane.scores[t * kt..(t + 1) * kt];
                        if c == 0 {
                            // Entire tile is future keys for this row —
                            // zero so the PV matmul adds nothing.
                            row.fill(0.0);
                            continue;
                        }
                        let tile_max = simd::max(&row[..c]);
                        if tile_max > lane.m[t] {
                            // Rescale history to the new max (first tile:
                            // m = -inf so corr = 0 on all-zero l/acc).
                            let corr = (lane.m[t] - tile_max).exp();
                            lane.l[t] *= corr;
                            simd::scale(&mut lane.acc[t * d..(t + 1) * d], corr);
                            lane.m[t] = tile_max;
                        }
                        lane.l[t] += simd::exp_sum(&mut row[..c], lane.m[t]);
                        row[c..].fill(0.0); // mask this row's future columns
                    }
                    matmul_acc(
                        &lane.scores[..tb * kt],
                        &lane.vhead[klo * d..khi * d],
                        &mut lane.acc[..tb * d],
                        tb,
                        kt,
                        d,
                    );
                    klo = khi;
                }
                for t in 0..tb {
                    let inv = if lane.l[t] > 0.0 { 1.0 / lane.l[t] } else { 0.0 };
                    let dst = ((t0 + t) * group + g) * d;
                    for (o, &a) in lane.ohead[dst..dst + d]
                        .iter_mut()
                        .zip(&lane.acc[t * d..(t + 1) * d])
                    {
                        *o = a * inv;
                    }
                }
                t0 += tb;
            }
        }
    };

    // One lane per HEAD (grow-only): lanes carry private output panels
    // because chunk output rows interleave heads, so disjoint `out`
    // slices per worker don't exist. Prefill-sized scratch; dropped by
    // backends in end_prefill.
    if lanes.len() < n_kv_heads {
        lanes.resize_with(n_kv_heads, BlockSparseLane::default);
    }
    workers.for_each_mut(&mut lanes[..n_kv_heads], run);
    // Serial scatter of each head's private panel into the interleaved
    // output — fixed order, so the parallel section can't affect results.
    for (kvh, lane) in lanes[..n_kv_heads].iter().enumerate() {
        for t in 0..n {
            for g in 0..group {
                let src = (t * group + g) * d;
                let dst = t * qd + (kvh * group + g) * d;
                out[dst..dst + d].copy_from_slice(&lane.ohead[src..src + d]);
            }
        }
    }
}

/// One worker's worth of [`SparseAttendScratch`]: key/value panels, a
/// pre-scaled query tile, and the score rows. Lanes are what makes the
/// per-KV-head parallel partition safe — each worker owns exactly one
/// lane plus its head chunk's disjoint slice of `out` (reusing the lane
/// serially across its heads), so no buffer is shared.
#[derive(Default)]
struct SparseAttendLane {
    khead: Vec<f32>,
    vhead: Vec<f32>,
    qtile: Vec<f32>,
    scores: Vec<f32>,
}

/// Reusable buffers for [`sparse_attend`]: one [`SparseAttendLane`] per
/// **worker** (serial runs keep exactly one lane, as before the parallel
/// partition — a lane's panels are (n_sel, d), so per-head lanes would
/// multiply the retained high-water scratch by n_kv_heads for dense-read
/// backends like KIVI). One scratch per backend — the decode hot path
/// must not heap-allocate per (layer, token) call (see the crate-wide
/// invariant in `attention/mod.rs`); lanes grow to the largest selection
/// seen and are retained.
#[derive(Default)]
pub struct SparseAttendScratch {
    lanes: Vec<SparseAttendLane>,
}

/// Below this much per-head work (`n_sel · group · d` MACs per score pass)
/// the fan-out overhead of [`sparse_attend_threaded`] outweighs the win;
/// the kernel silently runs serial. Partitioning is by KV head and
/// per-lane arithmetic is fixed, so the guard (like the worker handle
/// itself) cannot change results. Re-derived for pool dispatch (measured
/// sub-microsecond handoff vs ~10µs scoped spawn — see the
/// `sals_hotpath` dispatch microbench): half the old scoped-spawn floor.
const SPARSE_ATTEND_PAR_MIN_WORK: usize = 1024;

/// Packed exact sparse attention over a gathered token subset — the shared
/// decode epilogue of every token-sparse backend (SALS Eq. 5, and the
/// gathered-attention step of Quest/Loki/DoubleSparse/HShare/StreamingLLM;
/// KIVI/Palu use it over their full dequantized/reconstructed caches).
///
/// * `q`: **post-RoPE** stacked query, (n_heads·d).
/// * `keys` / `values`: (n_sel, n_kv_heads·d) row-major post-RoPE subset.
/// * `out`: (n_heads·d), overwritten. `n_sel == 0` writes zeros.
///
/// Blocking scheme (the decode-shaped sibling of [`causal_attend_chunk`]):
/// per KV head the strided key/value columns are packed **once** into
/// contiguous (n_sel, d) panels (skipped entirely when `n_kv_heads == 1`,
/// where the cache rows already are the panel); the group's query heads —
/// consecutive in `q` — form one pre-scaled (group, d) tile, so QKᵀ is a
/// single [`matmul_tn`], softmax is [`softmax_rows`], and PV is one
/// [`matmul`], all with unit-stride inner loops. This replaces the
/// per-head strided dot/axpy loop (and its per-call scores allocation)
/// that previously dominated the sparse decode profile.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attend(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    scratch: &mut SparseAttendScratch,
    out: &mut [f32],
) {
    sparse_attend_threaded(
        q,
        keys,
        values,
        n_sel,
        n_heads,
        n_kv_heads,
        d,
        &Workers::serial(),
        scratch,
        out,
    );
}

/// [`sparse_attend`] with the per-KV-head loop partitioned across the
/// `workers` handle (persistent pool lanes or scoped spawns). KV-head
/// panels are fully independent — each worker owns a contiguous head
/// chunk, one lane, and the chunk's disjoint `out` slice — so the
/// fan-out is lock-free and, because each head's arithmetic is identical
/// no matter which worker (or how many) runs it, **bit-invariant in the
/// handle width**. Work below [`SPARSE_ATTEND_PAR_MIN_WORK`] runs serial
/// regardless (the dispatch overhead would dominate), as does
/// `n_kv_heads == 1` (nothing to partition here; the fused kernel's
/// split-KV decomposition covers that shape).
#[allow(clippy::too_many_arguments)]
pub fn sparse_attend_threaded(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    workers: &Workers,
    scratch: &mut SparseAttendScratch,
    out: &mut [f32],
) {
    assert_eq!(n_heads % n_kv_heads, 0);
    let kvd = n_kv_heads * d;
    assert_eq!(values.len(), n_sel * kvd);
    let group = n_heads / n_kv_heads;
    // Default PV stage: pack this head's value columns into a contiguous
    // panel (the single-KV-head cache IS the panel) and run one matmul —
    // the same packing + arithmetic the pre-split kernel performed.
    let pv = |kvh: usize, scores: &[f32], staging: &mut Vec<f32>, ohead: &mut [f32]| {
        let vp: &[f32] = if n_kv_heads == 1 {
            values
        } else {
            staging.resize(n_sel * d, 0.0);
            for j in 0..n_sel {
                let src = j * kvd + kvh * d;
                staging[j * d..(j + 1) * d].copy_from_slice(&values[src..src + d]);
            }
            &staging[..]
        };
        matmul(scores, vp, ohead, group, n_sel, d);
    };
    sparse_attend_pv(q, keys, n_sel, n_heads, n_kv_heads, d, workers, pv, scratch, out)
}

/// [`sparse_attend_threaded`] with a caller-supplied PV stage — the
/// materialized-score sibling of [`fused_sparse_attend_with`].
///
/// The kernel packs this head's *key* panel, computes the (group, n_sel)
/// softmaxed score block, then hands `pv(kvh, scores, staging, ohead)`
/// the job of producing `ohead = scores @ V_head`. `staging` is the
/// lane's retained value-panel buffer, free for the closure to use as
/// scratch (the default PV packs the fp32 value panel into it; KIVI's
/// fused dequant-GEMV path streams quantized rows directly into `ohead`
/// and never stages). `pv` runs from worker threads and must be pure
/// w.r.t. its arguments; per-head arithmetic stays partition-independent,
/// so results remain bit-invariant in the handle width.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attend_pv(
    q: &[f32],
    keys: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    workers: &Workers,
    pv: impl Fn(usize, &[f32], &mut Vec<f32>, &mut [f32]) + Sync,
    scratch: &mut SparseAttendScratch,
    out: &mut [f32],
) {
    assert_eq!(n_heads % n_kv_heads, 0);
    let kvd = n_kv_heads * d;
    let qd = n_heads * d;
    assert_eq!(q.len(), qd);
    assert_eq!(keys.len(), n_sel * kvd);
    assert_eq!(out.len(), qd);
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();

    let per_head = |kvh: usize, lane: &mut SparseAttendLane, ohead: &mut [f32]| {
        lane.qtile.resize(group * d, 0.0);
        lane.scores.resize(group * n_sel, 0.0);
        // Contiguous (n_sel, d) key panel for this KV head. A
        // single-KV-head cache IS the panel — no copy.
        let kp: &[f32] = if n_kv_heads == 1 {
            keys
        } else {
            lane.khead.resize(n_sel * d, 0.0);
            for j in 0..n_sel {
                let src = j * kvd + kvh * d;
                lane.khead[j * d..(j + 1) * d].copy_from_slice(&keys[src..src + d]);
            }
            &lane.khead[..]
        };
        // The group's query heads are consecutive rows of q: one tile,
        // pre-scaled so 1/sqrt(d) folds into QKᵀ.
        let qbase = kvh * group * d;
        lane.qtile.copy_from_slice(&q[qbase..qbase + group * d]);
        simd::scale(&mut lane.qtile, scale);
        matmul_tn(&lane.qtile, kp, &mut lane.scores, group, d, n_sel);
        softmax_rows(&mut lane.scores, group, n_sel);
        pv(kvh, &lane.scores, &mut lane.vhead, ohead);
    };

    // One lane per WORKER, not per head: workers own contiguous head
    // chunks and reuse their lane across them (each head's pass fully
    // overwrites the lane, so reuse is deterministic), keeping serial
    // runs at exactly one (n_sel, d) panel pair as before the partition.
    let width = workers.width();
    let n_workers =
        if width <= 1 || n_kv_heads <= 1 || n_sel * group * d < SPARSE_ATTEND_PAR_MIN_WORK {
            1
        } else {
            width.min(n_kv_heads)
        };
    // Grow-only: shrinking would free panels a later parallel call has to
    // re-grow (the zero-alloc steady-state invariant).
    if scratch.lanes.len() < n_workers {
        scratch.lanes.resize_with(n_workers, SparseAttendLane::default);
    }
    workers.units_mut(&mut scratch.lanes[..n_workers], out, group * d, n_kv_heads, per_head);
}

/// Row count of one [`fused_sparse_attend`] key/value tile. Each tile is
/// 32·d·4 B (16 KiB at head_dim 128), so the K/V tile pair stays
/// L1-resident while amortizing the per-tile online-softmax bookkeeping.
pub const FUSED_TILE: usize = 32;

/// One worker's working set for [`fused_sparse_attend`]: the caller-filled
/// key/value tiles plus the kernel's online-softmax state. Each parallel
/// worker owns exactly one lane plus its head chunk's disjoint `out`
/// slice (reinitializing the lane per head), so the per-KV-head fan-out
/// shares no buffers.
#[derive(Default)]
pub struct FusedLane {
    /// (tile, d) **post-RoPE** key tile for the current selection block —
    /// written by the caller's `fill` closure, consumed by QKᵀ.
    pub ktile: Vec<f32>,
    /// (tile, d) value tile for the current selection block — written by
    /// `fill`, consumed by the default PV partial sum. Custom `pv`
    /// closures ([`fused_sparse_attend_with`]) that stream values from
    /// another representation (e.g. the fused dequant-GEMV path) may
    /// repurpose this buffer as per-row staging scratch instead.
    pub vtile: Vec<f32>,
    /// Pre-scaled (group, d) query tile for this head's query group.
    qtile: Vec<f32>,
    /// (group, tile) exp-score block of the current tile — by the time
    /// `pv` runs, row g holds `exp(s_j − m_g)` for the tile's columns.
    pub scores: Vec<f32>,
    /// Per-query-head running max of all scores seen so far.
    m: Vec<f32>,
    /// Per-query-head running softmax denominator (rescaled to `m`).
    l: Vec<f32>,
    /// (group, d) running PV partial, rescaled to `m`; `out = acc / l`.
    /// `pv` accumulates the current tile's probability-weighted values
    /// on top of it.
    pub acc: Vec<f32>,
}

/// Fixed selection-segment length of the split-KV decomposition: a
/// multiple of [`FUSED_TILE`], so the segmented fold tiles the selection
/// at exactly the same absolute boundaries as the unsegmented one (the
/// `fill`/`pv` closures see identical `(kvh, lo, hi)` calls either way).
/// A **constant**, never derived from the worker count: the
/// decomposition and its merge order must be identical for every pool
/// size so outputs stay bit-identical across pool sizes.
pub const SPLIT_KV_SEG: usize = 2 * FUSED_TILE;

/// Split-KV engages only when the per-KV-head partition can't feed a
/// pool on its own: at or below this many KV heads (MQA `n_kv_heads==1`
/// is the motivating shape; 2 still leaves most of a pool idle).
pub const SPLIT_KV_MAX_HEADS: usize = 2;

/// ... and only when the selection is long enough that the per-segment
/// partial copies and the serial merge are noise next to the tile folds
/// (at least two full segments per KV head).
pub const SPLIT_KV_MIN_SEL: usize = 2 * SPLIT_KV_SEG;

/// True when [`fused_sparse_attend_with`] uses the split-KV (flash-
/// decoding-style) decomposition: selection segments × KV heads instead
/// of whole KV heads. A function of the problem *shape only* — never of
/// the worker handle — so whether the fold is segmented cannot vary with
/// pool size (the bit-invariance contract).
pub fn split_kv_engages(n_kv_heads: usize, n_sel: usize) -> bool {
    n_kv_heads <= SPLIT_KV_MAX_HEADS && n_sel >= SPLIT_KV_MIN_SEL
}

/// Reusable per-backend scratch for [`fused_sparse_attend`]: one
/// [`FusedLane`] per worker (serial runs keep exactly one) plus the
/// split-KV partial panel, grown to high-water marks and retained —
/// steady-state decode performs zero heap allocations (dispatch through
/// a persistent [`Workers`] pool is allocation-free per call).
#[derive(Default)]
pub struct FusedAttendScratch {
    lanes: Vec<FusedLane>,
    /// Split-KV per-unit online-softmax partials, one
    /// `group · (d + 2)`-float record per (KV head, segment) unit:
    /// `[m(group) | l(group) | acc(group·d)]`, merged serially in fixed
    /// segment order after the parallel fold.
    partials: Vec<f32>,
}

/// Fused tile-streaming sparse attention — the paper's §4.4 decode kernel
/// shape: the caller materializes keys/values only in [`FUSED_TILE`]-row,
/// L1-resident tiles (reconstructing + rotating them on the fly), and the
/// kernel folds each tile's QKᵀ block into a flash-attention-style online
/// softmax (running max `m`, rescaled denominator `l`, rescaled PV partial
/// `acc`), so **neither the (n_sel, kv_dim) key panel nor the full score
/// row ever exists in memory**.
///
/// * `q`: **post-RoPE** stacked query, (n_heads·d).
/// * `fill(kvh, lo, hi, lane)`: write selection rows `lo..hi` of KV head
///   `kvh` into `lane.ktile`/`lane.vtile` (both pre-sized to
///   ((hi-lo), d)). Keys must arrive post-RoPE. The closure must touch
///   only those two buffers and must be pure w.r.t. `(kvh, lo, hi)` — it
///   runs from worker threads (any shared staging it reads must be
///   prepared before the kernel call and borrowed immutably).
/// * `workers`: fan-out handle (callers gate on work size; the kernel
///   honors the width as given so tests can force the parallel path).
///   The decomposition — per KV head, or split-KV selection segments
///   when [`split_kv_engages`] — depends on the problem shape only, and
///   per-lane arithmetic is identical regardless of which worker runs
///   it, so results are **bit-invariant in the handle width and backing
///   pool size**.
/// * `out`: (n_heads·d), overwritten; `n_sel == 0` writes zeros.
///
/// The online update per tile and query head g (the standard
/// flash-attention recurrence): with tile max `t`, when `t > m`:
/// `l ← l·exp(m−t)`, `acc ← acc·exp(m−t)`, `m ← t`; then
/// `p_j = exp(s_j − m)`, `l ← l + Σp_j`, `acc ← acc + p·V_tile`; epilogue
/// `out = acc / l`. Mathematically exact softmax attention — only fp
/// summation order differs from the materialized kernel (≤1e-4 parity,
/// pinned by tests and the SALS staged-pipeline proptest).
#[allow(clippy::too_many_arguments)]
pub fn fused_sparse_attend(
    q: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    workers: &Workers,
    fill: impl Fn(usize, usize, usize, &mut FusedLane) + Sync,
    scratch: &mut FusedAttendScratch,
    out: &mut [f32],
) {
    let group = n_heads / n_kv_heads;
    fused_sparse_attend_with(
        q,
        n_sel,
        n_heads,
        n_kv_heads,
        d,
        workers,
        fill,
        |_kvh, lo, hi, lane: &mut FusedLane| {
            let t = hi - lo;
            let FusedLane { scores, vtile, acc, .. } = lane;
            matmul_acc(&scores[..group * t], &vtile[..t * d], acc, group, t, d);
        },
        scratch,
        out,
    )
}

/// [`fused_sparse_attend`] with a caller-supplied PV stage.
///
/// `pv(kvh, lo, hi, lane)` runs once per tile, after the online-softmax
/// update: `lane.scores[..group·(hi−lo)]` holds the tile's exp-scores and
/// `lane.acc` the (already rescaled) running partial. The closure must
/// accumulate the tile's probability-weighted values onto `lane.acc` —
/// the default is `matmul_acc(scores, vtile, acc)`, but the SALS decode
/// path instead streams quantized value rows straight into `acc` via the
/// fused dequant-GEMV ([`crate::quant::TokenQuantStore::dequant_matmul_acc`]),
/// so the fp32 value tile never exists. Like `fill`, `pv` runs from
/// worker threads and must be pure w.r.t. `(kvh, lo, hi)`.
///
/// When [`split_kv_engages`] (few KV heads, long selection — the MQA
/// shape the per-head partition can't split), the kernel switches to the
/// flash-decoding-style **split-KV** decomposition: the selection is cut
/// into fixed [`SPLIT_KV_SEG`]-row segments, each (KV head, segment)
/// unit folds its rows through a private online-softmax partial
/// `(m, l, acc)` in parallel, and the partials are merged serially in
/// ascending segment order. The segmentation is shape-only and the merge
/// order fixed, so outputs are identical for every worker-handle width —
/// they differ from the *unsegmented* fold only in fp summation order
/// (≤1e-4, same class of difference as fused-vs-staged, pinned by
/// tests).
#[allow(clippy::too_many_arguments)]
pub fn fused_sparse_attend_with(
    q: &[f32],
    n_sel: usize,
    n_heads: usize,
    n_kv_heads: usize,
    d: usize,
    workers: &Workers,
    fill: impl Fn(usize, usize, usize, &mut FusedLane) + Sync,
    pv: impl Fn(usize, usize, usize, &mut FusedLane) + Sync,
    scratch: &mut FusedAttendScratch,
    out: &mut [f32],
) {
    assert_eq!(n_heads % n_kv_heads, 0);
    let qd = n_heads * d;
    assert_eq!(q.len(), qd);
    assert_eq!(out.len(), qd);
    if n_sel == 0 {
        out.fill(0.0);
        return;
    }
    let group = n_heads / n_kv_heads;
    let scale = 1.0 / (d as f32).sqrt();

    // Shared tile fold: (re)initialize the lane's online-softmax state,
    // then fold selection rows [seg_lo, seg_hi) of KV head `kvh` through
    // it. Tile boundaries are absolute (multiples of FUSED_TILE from
    // selection row 0; SPLIT_KV_SEG is such a multiple), so `fill`/`pv`
    // observe the same (kvh, lo, hi) calls whether or not the fold is
    // segmented.
    let fold = |kvh: usize, seg_lo: usize, seg_hi: usize, lane: &mut FusedLane| {
        lane.qtile.resize(group * d, 0.0);
        lane.qtile.copy_from_slice(&q[kvh * group * d..(kvh + 1) * group * d]);
        simd::scale(&mut lane.qtile, scale);
        lane.scores.resize(group * FUSED_TILE, 0.0);
        lane.m.clear();
        lane.m.resize(group, f32::NEG_INFINITY);
        lane.l.clear();
        lane.l.resize(group, 0.0);
        lane.acc.clear();
        lane.acc.resize(group * d, 0.0);
        let mut lo = seg_lo;
        while lo < seg_hi {
            let hi = (lo + FUSED_TILE).min(seg_hi);
            let t = hi - lo;
            lane.ktile.resize(t * d, 0.0);
            lane.vtile.resize(t * d, 0.0);
            fill(kvh, lo, hi, lane);
            matmul_tn(
                &lane.qtile,
                &lane.ktile[..t * d],
                &mut lane.scores[..group * t],
                group,
                d,
                t,
            );
            for g in 0..group {
                let row = &mut lane.scores[g * t..(g + 1) * t];
                let tile_max = simd::max(row);
                if tile_max > lane.m[g] {
                    // Rescale history to the new max. First tile: m = -inf
                    // so corr = 0 on (l, acc) that are already zero.
                    let corr = (lane.m[g] - tile_max).exp();
                    lane.l[g] *= corr;
                    simd::scale(&mut lane.acc[g * d..(g + 1) * d], corr);
                    lane.m[g] = tile_max;
                }
                lane.l[g] += simd::exp_sum(row, lane.m[g]);
            }
            pv(kvh, lo, hi, lane);
            lo = hi;
        }
    };

    let FusedAttendScratch { lanes, partials } = scratch;
    let width = workers.width();

    if !split_kv_engages(n_kv_heads, n_sel) {
        // Per-KV-head decomposition: one fold per head, epilogue
        // normalizes straight into the head's disjoint `out` slice. One
        // lane per WORKER (grow-only): each worker owns a contiguous
        // head chunk and reuses its lane across heads — `fold`
        // reinitializes the accumulator state per head, so reuse is
        // deterministic and the serial path keeps exactly one lane.
        let run = |kvh: usize, lane: &mut FusedLane, ohead: &mut [f32]| {
            fold(kvh, 0, n_sel, lane);
            for g in 0..group {
                let inv = if lane.l[g] > 0.0 { 1.0 / lane.l[g] } else { 0.0 };
                for (o, &a) in
                    ohead[g * d..(g + 1) * d].iter_mut().zip(&lane.acc[g * d..(g + 1) * d])
                {
                    *o = a * inv;
                }
            }
        };
        let n_workers = if width <= 1 || n_kv_heads <= 1 { 1 } else { width.min(n_kv_heads) };
        if lanes.len() < n_workers {
            lanes.resize_with(n_workers, FusedLane::default);
        }
        workers.units_mut(&mut lanes[..n_workers], out, group * d, n_kv_heads, run);
        return;
    }

    // Split-KV: (KV head, segment) units fold private partials in
    // parallel; fixed-order serial merge below.
    let n_segs = n_sel.div_ceil(SPLIT_KV_SEG);
    let n_units = n_kv_heads * n_segs;
    let stride = group * (d + 2);
    // Grow-only, like the lanes (zero-alloc steady state).
    if partials.len() < n_units * stride {
        partials.resize(n_units * stride, 0.0);
    }
    let run = |unit: usize, lane: &mut FusedLane, pbuf: &mut [f32]| {
        let kvh = unit / n_segs;
        let seg = unit % n_segs;
        let seg_lo = seg * SPLIT_KV_SEG;
        let seg_hi = (seg_lo + SPLIT_KV_SEG).min(n_sel);
        fold(kvh, seg_lo, seg_hi, lane);
        let (mbuf, rest) = pbuf.split_at_mut(group);
        let (lbuf, abuf) = rest.split_at_mut(group);
        mbuf.copy_from_slice(&lane.m);
        lbuf.copy_from_slice(&lane.l);
        abuf.copy_from_slice(&lane.acc[..group * d]);
    };
    let n_workers = width.min(n_units).max(1);
    if lanes.len() < n_workers {
        lanes.resize_with(n_workers, FusedLane::default);
    }
    workers.units_mut(
        &mut lanes[..n_workers],
        &mut partials[..n_units * stride],
        stride,
        n_units,
        run,
    );

    // Fixed-order merge on the caller: per KV head, fold the segment
    // partials in ascending segment order — the standard two-accumulator
    // online-softmax combine. Both the decomposition (shape-only) and
    // this serial merge are independent of the worker count, so outputs
    // are bit-identical for every pool size. Reuses lane 0 as the merge
    // accumulator (it is scratch; the parallel section is over).
    let mlane = &mut lanes[0];
    for kvh in 0..n_kv_heads {
        mlane.m.clear();
        mlane.m.resize(group, f32::NEG_INFINITY);
        mlane.l.clear();
        mlane.l.resize(group, 0.0);
        mlane.acc.clear();
        mlane.acc.resize(group * d, 0.0);
        for seg in 0..n_segs {
            let p = &partials[(kvh * n_segs + seg) * stride..(kvh * n_segs + seg + 1) * stride];
            let (pm, rest) = p.split_at(group);
            let (pl, pacc) = rest.split_at(group);
            for g in 0..group {
                if pl[g] <= 0.0 {
                    // A non-empty segment always has l ≥ 1 (its own max
                    // contributes exp(0)); defensive skip only.
                    continue;
                }
                if pm[g] > mlane.m[g] {
                    // Rescale the merged history to the segment's max
                    // (first segment: m = -inf so corr = 0 on zero state).
                    let corr = (mlane.m[g] - pm[g]).exp();
                    mlane.l[g] *= corr;
                    simd::scale(&mut mlane.acc[g * d..(g + 1) * d], corr);
                    mlane.m[g] = pm[g];
                }
                let c = (pm[g] - mlane.m[g]).exp();
                mlane.l[g] += pl[g] * c;
                simd::axpy(c, &pacc[g * d..(g + 1) * d], &mut mlane.acc[g * d..(g + 1) * d]);
            }
        }
        let ohead = &mut out[kvh * group * d..(kvh + 1) * group * d];
        for g in 0..group {
            let inv = if mlane.l[g] > 0.0 { 1.0 / mlane.l[g] } else { 0.0 };
            for (o, &a) in ohead[g * d..(g + 1) * d].iter_mut().zip(&mlane.acc[g * d..(g + 1) * d])
            {
                *o = a * inv;
            }
        }
    }
}

/// Pack rows `idx` of a (·, row_len) row-major matrix into `out`
/// ((idx.len(), row_len), overwritten). The batched-decode embed: stacking
/// each sequence's current token embedding into one activation matrix is a
/// row gather over the embedding table.
pub fn gather_rows(src: &[f32], row_len: usize, idx: &[usize], out: &mut [f32]) {
    assert!(row_len > 0);
    assert_eq!(src.len() % row_len, 0);
    assert_eq!(out.len(), idx.len() * row_len);
    let n_rows = src.len() / row_len;
    for (t, &i) in idx.iter().enumerate() {
        assert!(i < n_rows, "gather_rows: row {i} out of range {n_rows}");
        out[t * row_len..(t + 1) * row_len].copy_from_slice(&src[i * row_len..(i + 1) * row_len]);
    }
}

/// Inverse of [`gather_rows`]: write the rows of `src`
/// ((idx.len(), row_len) row-major) to rows `idx` of `out`. Duplicate
/// indices are last-writer-wins (rows are processed in order).
pub fn scatter_rows(src: &[f32], row_len: usize, idx: &[usize], out: &mut [f32]) {
    assert!(row_len > 0);
    assert_eq!(src.len(), idx.len() * row_len);
    assert_eq!(out.len() % row_len, 0);
    let n_rows = out.len() / row_len;
    for (t, &i) in idx.iter().enumerate() {
        assert!(i < n_rows, "scatter_rows: row {i} out of range {n_rows}");
        out[i * row_len..(i + 1) * row_len].copy_from_slice(&src[t * row_len..(t + 1) * row_len]);
    }
}

/// Tied-embedding LM head over a batch of final hidden states:
/// `out[b, vocab] = x[b, d] @ embᵀ` where `emb` is the (vocab, d) embedding
/// matrix whose rows double as output projections. One [`matmul_tn`] —
/// the embedding table streams once for the whole batch instead of once
/// per sequence, which is the point of cross-sequence batched decode (the
/// LM head is the single largest weight matrix at decode time).
pub fn lm_head_batch(x: &[f32], emb: &[f32], out: &mut [f32], b: usize, d: usize, vocab: usize) {
    assert_eq!(emb.len(), vocab * d);
    matmul_tn(x, emb, out, b, d, vocab);
}

/// RMSNorm: x * w / sqrt(mean(x²) + eps). LLaMA-style (no mean subtraction).
/// Both scans (Σx² and the apply pass) dispatch through [`simd`].
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), out.len());
    let ms = simd::sum_squares(x) / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    simd::weighted_scale(x, w, inv, out);
}

/// SiLU (swish) activation: x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Scale a slice in place (SIMD-dispatched; bit-identical across tiers).
pub fn scale(xs: &mut [f32], alpha: f32) {
    simd::scale(xs, alpha)
}

/// argmax over a slice (first max wins). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1., 2., 3., 4.];
        let b = [1., 1., 1., 1.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, &mut out, 2, 2, 2);
        assert_eq!(out, [3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_tn_matches_matmul() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let (m, k, n) = (3, 17, 5);
        let a = rng.normal_vec(m * k, 1.0);
        let bt = rng.normal_vec(n * k, 1.0); // (n,k)
        // b = btᵀ as (k,n)
        let mut b = vec![0.0; k * n];
        for r in 0..n {
            for c in 0..k {
                b[c * n + r] = bt[r * k + c];
            }
        }
        let mut o1 = vec![0.0; m * n];
        let mut o2 = vec![0.0; m * n];
        matmul(&a, &b, &mut o1, m, k, n);
        matmul_tn(&a, &bt, &mut o2, m, k, n);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_masked_matches_dense() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let (m, k, n) = (4, 9, 6);
        let mut a = rng.normal_vec(m * k, 1.0);
        // Inject structural zeros (masked tail of each row).
        for i in 0..m {
            for p in k - 3..k {
                a[i * k + p] = 0.0;
            }
        }
        let b = rng.normal_vec(k * n, 1.0);
        let mut dense = vec![0.0; m * n];
        let mut masked = vec![0.0; m * n];
        matmul(&a, &b, &mut dense, m, k, n);
        matmul_masked(&a, &b, &mut masked, m, k, n);
        for (x, y) in dense.iter().zip(&masked) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    /// Naive per-query reference for causal_attend_chunk.
    #[allow(clippy::too_many_arguments)]
    fn causal_reference(
        qs: &[f32],
        keys: &[f32],
        values: &[f32],
        n: usize,
        len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d: usize,
    ) -> Vec<f32> {
        let qd = n_heads * d;
        let kvd = n_kv_heads * d;
        let group = n_heads / n_kv_heads;
        let scale = 1.0 / (d as f32).sqrt();
        let start = len - n;
        let mut out = vec![0.0f32; n * qd];
        for t in 0..n {
            let vis = start + t + 1;
            for h in 0..n_heads {
                let kvh = h / group;
                let qh = &qs[t * qd + h * d..t * qd + (h + 1) * d];
                let mut s: Vec<f32> = (0..vis)
                    .map(|j| dot(qh, &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d]) * scale)
                    .collect();
                softmax(&mut s);
                let oh = &mut out[t * qd + h * d..t * qd + (h + 1) * d];
                for (j, &p) in s.iter().enumerate() {
                    axpy(p, &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d], oh);
                }
            }
        }
        out
    }

    #[test]
    fn causal_attend_chunk_matches_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        // n > Q_TILE to exercise multi-tile; GQA to exercise head groups;
        // start > 0 to exercise a pre-existing cache prefix.
        let (n_heads, n_kv_heads, d) = (4, 2, 8);
        let (len, n) = (41, 23);
        let qd = n_heads * d;
        let kvd = n_kv_heads * d;
        let qs = rng.normal_vec(n * qd, 1.0);
        let keys = rng.normal_vec(len * kvd, 1.0);
        let values = rng.normal_vec(len * kvd, 1.0);
        let mut out = vec![0.0f32; n * qd];
        let mut scratch = ChunkAttendScratch::default();
        causal_attend_chunk(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &mut scratch, &mut out);
        // Re-run with the now-warm scratch: reuse must not change results.
        let mut out2 = vec![0.0f32; n * qd];
        causal_attend_chunk(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &mut scratch, &mut out2);
        assert_eq!(out, out2);
        let reference = causal_reference(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn causal_attend_chunk_seg_bit_matches_flat() {
        // Splitting the cache into segments only changes where the pack
        // loop copies FROM — every downstream tile computation sees the
        // same packed panels, so any segmentation must be BIT-identical
        // to the flat call (the shared-prefix adopt contract relies on
        // this).
        use crate::util::rng::Rng;
        let mut rng = Rng::new(23);
        let (n_heads, n_kv_heads, d) = (4, 2, 8);
        let (len, n) = (37, 19);
        let qd = n_heads * d;
        let kvd = n_kv_heads * d;
        let qs = rng.normal_vec(n * qd, 1.0);
        let keys = rng.normal_vec(len * kvd, 1.0);
        let values = rng.normal_vec(len * kvd, 1.0);
        let mut flat = vec![0.0f32; n * qd];
        let mut scratch = ChunkAttendScratch::default();
        causal_attend_chunk(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &mut scratch, &mut flat);
        for split in [0usize, 1, 16, 18, 36, 37] {
            let b = split * kvd;
            let mut seg = vec![0.0f32; n * qd];
            causal_attend_chunk_seg(
                &qs,
                &[&keys[..b], &keys[b..]],
                &[&values[..b], &values[b..]],
                n,
                len,
                n_heads,
                n_kv_heads,
                d,
                &mut scratch,
                &mut seg,
            );
            assert_eq!(seg, flat, "split at row {split} must be bit-identical");
        }
    }

    #[test]
    fn causal_attend_chunk_full_cache_single_token() {
        // n == len == 1: softmax over a singleton returns the value row.
        let d = 4;
        let qs = vec![0.3f32; d];
        let keys = vec![0.7f32; d];
        let values: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; d];
        let mut scratch = ChunkAttendScratch::default();
        causal_attend_chunk(&qs, &keys, &values, 1, 1, 1, 1, d, &mut scratch, &mut out);
        for (o, v) in out.iter().zip(&values) {
            assert!((o - v).abs() < 1e-6);
        }
    }

    /// Naive per-query reference for block_sparse_attend_chunk: exact
    /// softmax attention over each row's (selected ∩ causal-prefix) set.
    #[allow(clippy::too_many_arguments)]
    fn block_sparse_reference(
        qs: &[f32],
        keys: &[f32],
        values: &[f32],
        n: usize,
        len: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d: usize,
        blocks: &[(usize, usize)],
    ) -> Vec<f32> {
        let qd = n_heads * d;
        let kvd = n_kv_heads * d;
        let group = n_heads / n_kv_heads;
        let scale = 1.0 / (d as f32).sqrt();
        let start = len - n;
        let mut out = vec![0.0f32; n * qd];
        for t in 0..n {
            let sel: Vec<usize> = blocks
                .iter()
                .flat_map(|&(lo, hi)| lo..hi)
                .filter(|&j| j <= start + t)
                .collect();
            for h in 0..n_heads {
                let kvh = h / group;
                let qh = &qs[t * qd + h * d..t * qd + (h + 1) * d];
                let oh = &mut out[t * qd + h * d..t * qd + (h + 1) * d];
                if sel.is_empty() {
                    oh.fill(0.0);
                    continue;
                }
                let mut s: Vec<f32> = sel
                    .iter()
                    .map(|&j| dot(qh, &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d]) * scale)
                    .collect();
                softmax(&mut s);
                for (&j, &p) in sel.iter().zip(&s) {
                    axpy(p, &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d], oh);
                }
            }
        }
        out
    }

    #[test]
    fn block_sparse_all_blocks_matches_causal_chunk() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(61);
        // Full coverage (τ=1.0 selection) must reproduce the dense causal
        // kernel ≤1e-4, whether the cover is one range or split into
        // several — the online-softmax fold only reorders fp summation.
        for (n_heads, n_kv_heads, d, len, n) in
            [(4usize, 2usize, 8usize, 41usize, 23usize), (2, 2, 8, 90, 90), (8, 2, 4, 70, 17)]
        {
            let (qd, kvd) = (n_heads * d, n_kv_heads * d);
            let qs = rng.normal_vec(n * qd, 1.0);
            let keys = rng.normal_vec(len * kvd, 1.0);
            let values = rng.normal_vec(len * kvd, 1.0);
            let mut dense = vec![0.0f32; n * qd];
            let mut cs = ChunkAttendScratch::default();
            causal_attend_chunk(
                &qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &mut cs, &mut dense,
            );
            let covers: [Vec<(usize, usize)>; 2] =
                [vec![(0, len)], vec![(0, 7), (7, 20), (20, len)]];
            for blocks in &covers {
                let mut out = vec![0.0f32; n * qd];
                let mut scratch = BlockSparseScratch::default();
                block_sparse_attend_chunk(
                    &qs, &keys, &values, n, len, n_heads, n_kv_heads, d, blocks, &Workers::serial(),
                    &mut scratch, &mut out,
                );
                for (a, b) in out.iter().zip(&dense) {
                    assert!((a - b).abs() < 1e-4, "{n_heads}h/{n_kv_heads}kv: {a} vs {b}");
                }
                // Warm-scratch rerun must be identical (buffer reuse safety).
                let mut out2 = vec![0.0f32; n * qd];
                block_sparse_attend_chunk(
                    &qs, &keys, &values, n, len, n_heads, n_kv_heads, d, blocks, &Workers::serial(),
                    &mut scratch, &mut out2,
                );
                assert_eq!(out, out2);
            }
        }
    }

    #[test]
    fn block_sparse_partial_blocks_match_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(63);
        // Genuinely sparse selection: sink block + a middle block + the
        // diagonal window. Rows whose prefix ends mid-block and key tiles
        // crossing block boundaries are both exercised (len > 2·FUSED_TILE).
        let (n_heads, n_kv_heads, d) = (4usize, 2usize, 8usize);
        let (len, n) = (3 * FUSED_TILE + 11, 29);
        let (qd, kvd) = (n_heads * d, n_kv_heads * d);
        let qs = rng.normal_vec(n * qd, 1.0);
        let keys = rng.normal_vec(len * kvd, 1.0);
        let values = rng.normal_vec(len * kvd, 1.0);
        let blocks = vec![(0usize, 8usize), (40, 56), (len - n - 3, len)];
        let mut out = vec![0.0f32; n * qd];
        let mut scratch = BlockSparseScratch::default();
        block_sparse_attend_chunk(
            &qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &blocks, &Workers::serial(),
            &mut scratch, &mut out,
        );
        let reference =
            block_sparse_reference(&qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &blocks);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn block_sparse_thread_count_is_bit_invariant() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(67);
        let (n_heads, n_kv_heads, d) = (8usize, 4usize, 8usize);
        let (len, n) = (120usize, 37usize);
        let (qd, kvd) = (n_heads * d, n_kv_heads * d);
        let qs = rng.normal_vec(n * qd, 1.0);
        let keys = rng.normal_vec(len * kvd, 1.0);
        let values = rng.normal_vec(len * kvd, 1.0);
        let blocks = vec![(0usize, 16usize), (48, 64), (80, len)];
        let mut serial = vec![0.0f32; n * qd];
        let mut scratch = BlockSparseScratch::default();
        block_sparse_attend_chunk(
            &qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &blocks, &Workers::serial(),
            &mut scratch, &mut serial,
        );
        // Scoped widths and pool sizes {1, 2, 8}: all bit-identical.
        let handles = [
            Workers::scoped(2),
            Workers::scoped(3),
            Workers::scoped(8),
            Workers::pooled(1),
            Workers::pooled(2),
            Workers::pooled(8),
        ];
        for workers in &handles {
            let mut out = vec![0.0f32; n * qd];
            let mut s = BlockSparseScratch::default();
            block_sparse_attend_chunk(
                &qs, &keys, &values, n, len, n_heads, n_kv_heads, d, &blocks, workers, &mut s,
                &mut out,
            );
            assert_eq!(out, serial, "{workers:?} must be bit-identical");
        }
    }

    #[test]
    fn block_sparse_empty_selection_zeroes_out() {
        let d = 4;
        let qs = vec![1.0f32; 2 * d];
        let keys = vec![0.5f32; 8 * d];
        let values = vec![0.5f32; 8 * d];
        let mut out = vec![7.0f32; 2 * d];
        let mut scratch = BlockSparseScratch::default();
        block_sparse_attend_chunk(
            &qs, &keys, &values, 2, 8, 1, 1, d, &[], &Workers::serial(), &mut scratch, &mut out,
        );
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_sparse_online_softmax_is_stable_across_blocks() {
        // Later blocks carry much larger scores than earlier ones: the
        // running-max rescale must keep everything finite and concentrate
        // weight on the large-score block (mirrors the fused decode test).
        let d = 4;
        let len = 3 * FUSED_TILE;
        let n = 1; // single query at the end sees all three blocks
        let qs = vec![10.0f32; d];
        let mut keys = vec![0.0f32; len * d];
        let mut values = vec![0.0f32; len * d];
        for j in 0..len {
            let mag = (j / FUSED_TILE) as f32 * 30.0; // 0, 30, 60 per block
            for c in 0..d {
                keys[j * d + c] = mag;
                values[j * d + c] = j as f32;
            }
        }
        let blocks =
            vec![(0usize, FUSED_TILE), (FUSED_TILE, 2 * FUSED_TILE), (2 * FUSED_TILE, len)];
        let mut out = vec![0.0f32; d];
        let mut scratch = BlockSparseScratch::default();
        block_sparse_attend_chunk(
            &qs, &keys, &values, n, len, 1, 1, d, &blocks, &Workers::serial(), &mut scratch, &mut out,
        );
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(out[0] >= 2.0 * FUSED_TILE as f32 - 1.0, "out {out:?}");
    }

    /// Naive per-head reference for sparse_attend (the pre-packing decode
    /// pattern: strided dot/axpy per query head).
    fn sparse_reference(
        q: &[f32],
        keys: &[f32],
        values: &[f32],
        n_sel: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d: usize,
    ) -> Vec<f32> {
        let kvd = n_kv_heads * d;
        let group = n_heads / n_kv_heads;
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = vec![0.0f32; n_heads * d];
        let mut scores = vec![0.0f32; n_sel];
        for h in 0..n_heads {
            let kvh = h / group;
            let qh = &q[h * d..(h + 1) * d];
            for (j, s) in scores.iter_mut().enumerate() {
                *s = dot(qh, &keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d]) * scale;
            }
            softmax(&mut scores);
            let oh = &mut out[h * d..(h + 1) * d];
            for (j, &p) in scores.iter().enumerate() {
                axpy(p, &values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d], oh);
            }
        }
        out
    }

    #[test]
    fn sparse_attend_matches_reference_mha_and_gqa() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(29);
        for (n_heads, n_kv_heads, d, n_sel) in
            [(1usize, 1usize, 8usize, 13usize), (4, 4, 8, 7), (4, 2, 16, 21), (8, 2, 4, 1)]
        {
            let kvd = n_kv_heads * d;
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * kvd, 1.0);
            let values = rng.normal_vec(n_sel * kvd, 1.0);
            let mut out = vec![0.0f32; n_heads * d];
            let mut scratch = SparseAttendScratch::default();
            sparse_attend(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut scratch, &mut out);
            // Warm-scratch rerun must be identical (buffer reuse safety).
            let mut out2 = vec![0.0f32; n_heads * d];
            sparse_attend(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut scratch, &mut out2);
            assert_eq!(out, out2);
            let reference = sparse_reference(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d);
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "{n_heads}h/{n_kv_heads}kv: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_attend_empty_selection_zeroes_out() {
        let mut scratch = SparseAttendScratch::default();
        let q = vec![1.0f32; 8];
        let mut out = vec![7.0f32; 8];
        sparse_attend(&q, &[], &[], 0, 2, 1, 4, &mut scratch, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sparse_attend_threaded_bit_matches_serial() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(33);
        // Big enough to clear SPARSE_ATTEND_PAR_MIN_WORK (n_sel·group·d):
        // 80 · 2 · 16 = 2560, with 4 KV heads to partition.
        let (n_heads, n_kv_heads, d, n_sel) = (8usize, 4usize, 16usize, 80usize);
        let kvd = n_kv_heads * d;
        let q = rng.normal_vec(n_heads * d, 1.0);
        let keys = rng.normal_vec(n_sel * kvd, 1.0);
        let values = rng.normal_vec(n_sel * kvd, 1.0);
        let mut serial = vec![0.0f32; n_heads * d];
        let mut scratch = SparseAttendScratch::default();
        sparse_attend(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut scratch, &mut serial);
        let handles = [
            Workers::scoped(2),
            Workers::scoped(3),
            Workers::scoped(8),
            Workers::pooled(1),
            Workers::pooled(2),
            Workers::pooled(8),
        ];
        for workers in &handles {
            let mut out = vec![0.0f32; n_heads * d];
            let mut s = SparseAttendScratch::default();
            sparse_attend_threaded(
                &q, &keys, &values, n_sel, n_heads, n_kv_heads, d, workers, &mut s, &mut out,
            );
            assert_eq!(out, serial, "{workers:?} must be bit-identical");
        }
    }

    #[test]
    fn sparse_attend_pv_custom_stage_matches_default() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(35);
        // A streaming PV (zero + per-row axpy, never staging a panel) must
        // agree with the default packed-matmul PV — this is the contract
        // the fused dequant-GEMV path builds on. Work size clears
        // SPARSE_ATTEND_PAR_MIN_WORK so the parallel partition runs too.
        let (n_heads, n_kv_heads, d, n_sel) = (8usize, 4usize, 16usize, 80usize);
        let kvd = n_kv_heads * d;
        let group = n_heads / n_kv_heads;
        let q = rng.normal_vec(n_heads * d, 1.0);
        let keys = rng.normal_vec(n_sel * kvd, 1.0);
        let values = rng.normal_vec(n_sel * kvd, 1.0);
        let mut reference = vec![0.0f32; n_heads * d];
        let mut scratch = SparseAttendScratch::default();
        sparse_attend_threaded(
            &q,
            &keys,
            &values,
            n_sel,
            n_heads,
            n_kv_heads,
            d,
            &Workers::serial(),
            &mut scratch,
            &mut reference,
        );
        let pv = |kvh: usize, scores: &[f32], _staging: &mut Vec<f32>, ohead: &mut [f32]| {
            ohead.fill(0.0);
            for g in 0..group {
                let og = &mut ohead[g * d..(g + 1) * d];
                for j in 0..n_sel {
                    let src = j * kvd + kvh * d;
                    axpy(scores[g * n_sel + j], &values[src..src + d], og);
                }
            }
        };
        let mut first = Vec::new();
        let handles =
            [Workers::serial(), Workers::scoped(2), Workers::pooled(2), Workers::pooled(8)];
        for (i, workers) in handles.iter().enumerate() {
            let mut out = vec![0.0f32; n_heads * d];
            let mut s = SparseAttendScratch::default();
            sparse_attend_pv(
                &q, &keys, n_sel, n_heads, n_kv_heads, d, workers, &pv, &mut s, &mut out,
            );
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "{workers:?}: {a} vs {b}");
            }
            if i == 0 {
                first = out;
            } else {
                assert_eq!(out, first, "{workers:?} must be bit-identical");
            }
        }
    }

    #[test]
    fn matmul_zero_fold_overwrites_stale_out() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(36);
        let (m, k, n) = (3, 5, 4);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut clean = vec![0.0f32; m * n];
        matmul(&a, &b, &mut clean, m, k, n);
        // Stale garbage in `out` must be overwritten, not accumulated onto.
        let mut stale = vec![999.0f32; m * n];
        matmul(&a, &b, &mut stale, m, k, n);
        assert_eq!(stale, clean);
        // k == 0 zero-fills.
        let mut empty = vec![7.0f32; m * n];
        matmul(&[], &[], &mut empty, m, 0, n);
        assert!(empty.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn matmul_masked_zero_fold_handles_fully_masked_rows() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(38);
        let (m, k, n) = (3, 6, 5);
        let mut a = rng.normal_vec(m * k, 1.0);
        // Row 1 fully masked: every coefficient structurally zero.
        for p in 0..k {
            a[k + p] = 0.0;
        }
        let b = rng.normal_vec(k * n, 1.0);
        let mut dense = vec![0.0f32; m * n];
        matmul(&a, &b, &mut dense, m, k, n);
        let mut masked = vec![999.0f32; m * n]; // stale garbage must vanish
        matmul_masked(&a, &b, &mut masked, m, k, n);
        assert!(masked[n..2 * n].iter().all(|&x| x == 0.0), "masked row must be zeroed");
        for (x, y) in dense.iter().zip(&masked) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    /// Dense-panel fill for fused_sparse_attend: slice KV head `kvh`'s
    /// columns of pre-built (n_sel, kvd) panels into the tile buffers —
    /// the minimal tile source, so the test isolates the online-softmax
    /// accumulator against the materialized kernel.
    fn panel_fill<'a>(
        keys: &'a [f32],
        values: &'a [f32],
        kvd: usize,
        d: usize,
    ) -> impl Fn(usize, usize, usize, &mut FusedLane) + Sync + 'a {
        move |kvh: usize, lo: usize, hi: usize, lane: &mut FusedLane| {
            for (row, j) in (lo..hi).enumerate() {
                let src = j * kvd + kvh * d;
                lane.ktile[row * d..(row + 1) * d].copy_from_slice(&keys[src..src + d]);
                lane.vtile[row * d..(row + 1) * d].copy_from_slice(&values[src..src + d]);
            }
        }
    }

    #[test]
    fn fused_sparse_attend_matches_materialized_kernel() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(37);
        // Shapes cross MHA/GQA and tile boundaries: n_sel below, at, and
        // well past FUSED_TILE (multi-tile online-softmax rescaling).
        for (n_heads, n_kv_heads, d, n_sel) in [
            (1usize, 1usize, 8usize, 13usize),
            (4, 4, 8, 32),
            (4, 2, 16, 33),
            (8, 2, 4, 100),
            (6, 3, 8, 95),
        ] {
            let kvd = n_kv_heads * d;
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * kvd, 1.0);
            let values = rng.normal_vec(n_sel * kvd, 1.0);
            let mut reference = vec![0.0f32; n_heads * d];
            let mut sscratch = SparseAttendScratch::default();
            sparse_attend(
                &q, &keys, &values, n_sel, n_heads, n_kv_heads, d, &mut sscratch, &mut reference,
            );
            let mut out = vec![0.0f32; n_heads * d];
            let mut scratch = FusedAttendScratch::default();
            let fill = panel_fill(&keys, &values, kvd, d);
            let serial = Workers::serial();
            fused_sparse_attend(
                &q, n_sel, n_heads, n_kv_heads, d, &serial, &fill, &mut scratch, &mut out,
            );
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "{n_heads}h/{n_kv_heads}kv/{n_sel}sel: {a} vs {b}");
            }
            // Warm-scratch rerun must be identical (buffer reuse safety).
            let mut out2 = vec![0.0f32; n_heads * d];
            fused_sparse_attend(
                &q, n_sel, n_heads, n_kv_heads, d, &serial, &fill, &mut scratch, &mut out2,
            );
            assert_eq!(out, out2);
            // Worker handle must be invisible bit-for-bit (per-lane
            // arithmetic is fixed; only the lane→worker mapping changes).
            for workers in [Workers::scoped(2), Workers::pooled(2), Workers::pooled(8)] {
                let mut outn = vec![0.0f32; n_heads * d];
                let mut sn = FusedAttendScratch::default();
                fused_sparse_attend(
                    &q, n_sel, n_heads, n_kv_heads, d, &workers, &fill, &mut sn, &mut outn,
                );
                assert_eq!(out, outn, "{workers:?}");
            }
        }
    }

    #[test]
    fn fused_sparse_attend_empty_selection_zeroes_out() {
        let mut scratch = FusedAttendScratch::default();
        let q = vec![1.0f32; 8];
        let mut out = vec![7.0f32; 8];
        fused_sparse_attend(
            &q,
            0,
            2,
            1,
            4,
            &Workers::serial(),
            |_, _, _, _: &mut FusedLane| panic!("fill must not run on empty selection"),
            &mut scratch,
            &mut out,
        );
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fused_sparse_attend_with_custom_pv_bit_matches_default() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(39);
        // A custom PV that streams the tile row-by-row (axpy of exp-score
        // times value row — the shape of the fused dequant-GEMV closure)
        // is element-order-identical to matmul_acc over the same tile, so
        // the wrapper and the custom path must agree bit-for-bit.
        let (n_heads, n_kv_heads, d, n_sel) = (4usize, 2usize, 8usize, 77usize);
        let group = n_heads / n_kv_heads;
        let kvd = n_kv_heads * d;
        let q = rng.normal_vec(n_heads * d, 1.0);
        let keys = rng.normal_vec(n_sel * kvd, 1.0);
        let values = rng.normal_vec(n_sel * kvd, 1.0);
        let fill = panel_fill(&keys, &values, kvd, d);
        let mut reference = vec![0.0f32; n_heads * d];
        let mut scratch = FusedAttendScratch::default();
        fused_sparse_attend(
            &q,
            n_sel,
            n_heads,
            n_kv_heads,
            d,
            &Workers::serial(),
            &fill,
            &mut scratch,
            &mut reference,
        );
        let pv = |_kvh: usize, lo: usize, hi: usize, lane: &mut FusedLane| {
            let t = hi - lo;
            let FusedLane { scores, vtile, acc, .. } = lane;
            for g in 0..group {
                let ag = &mut acc[g * d..(g + 1) * d];
                for r in 0..t {
                    axpy(scores[g * t + r], &vtile[r * d..(r + 1) * d], ag);
                }
            }
        };
        for workers in [Workers::serial(), Workers::scoped(4), Workers::pooled(4)] {
            let mut out = vec![0.0f32; n_heads * d];
            let mut s = FusedAttendScratch::default();
            fused_sparse_attend_with(
                &q, n_sel, n_heads, n_kv_heads, d, &workers, &fill, &pv, &mut s, &mut out,
            );
            assert_eq!(out, reference, "{workers:?}");
        }
    }

    #[test]
    fn fused_online_softmax_is_stable_for_large_logits() {
        // Keys engineered so later tiles carry much larger scores than the
        // first: the running-max rescale path must keep everything finite.
        let d = 4;
        let n_sel = 3 * FUSED_TILE;
        let q = vec![10.0f32; d];
        let mut keys = vec![0.0f32; n_sel * d];
        let mut values = vec![0.0f32; n_sel * d];
        for j in 0..n_sel {
            let mag = (j / FUSED_TILE) as f32 * 30.0; // 0, 30, 60 per tile
            for c in 0..d {
                keys[j * d + c] = mag;
                values[j * d + c] = j as f32;
            }
        }
        let mut out = vec![0.0f32; d];
        let mut scratch = FusedAttendScratch::default();
        let fill = panel_fill(&keys, &values, d, d);
        fused_sparse_attend(&q, n_sel, 1, 1, d, &Workers::serial(), &fill, &mut scratch, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        // All weight concentrates on the last (largest-score) tile, whose
        // values are ≥ 2·FUSED_TILE.
        assert!(out[0] >= 2.0 * FUSED_TILE as f32 - 1.0, "out {out:?}");
    }

    #[test]
    fn split_kv_engagement_is_shape_only() {
        // The split decision must depend on (n_kv_heads, n_sel) alone —
        // never on the worker handle — so outputs are a function of shape.
        assert!(split_kv_engages(1, SPLIT_KV_MIN_SEL));
        assert!(split_kv_engages(2, 10_000));
        assert!(!split_kv_engages(1, SPLIT_KV_MIN_SEL - 1));
        assert!(!split_kv_engages(3, 10_000));
        // Segment length is a whole number of fused tiles, so split and
        // unsplit folds see identical (kvh, lo, hi) tile calls.
        assert_eq!(SPLIT_KV_SEG % FUSED_TILE, 0);
    }

    #[test]
    fn split_kv_matches_materialized_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(43);
        // MQA (n_kv_heads=1) and narrow-GQA shapes past SPLIT_KV_MIN_SEL,
        // including a ragged final segment (200 = 3·64 + 8).
        for (n_heads, n_kv_heads, d, n_sel) in
            [(4usize, 1usize, 16usize, 200usize), (1, 1, 8, 256), (8, 2, 16, 131)]
        {
            assert!(split_kv_engages(n_kv_heads, n_sel), "shape must engage the split path");
            let kvd = n_kv_heads * d;
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * kvd, 1.0);
            let values = rng.normal_vec(n_sel * kvd, 1.0);
            let reference = sparse_reference(&q, &keys, &values, n_sel, n_heads, n_kv_heads, d);
            let fill = panel_fill(&keys, &values, kvd, d);
            let serial = Workers::serial();
            let mut out = vec![0.0f32; n_heads * d];
            let mut scratch = FusedAttendScratch::default();
            fused_sparse_attend(
                &q, n_sel, n_heads, n_kv_heads, d, &serial, &fill, &mut scratch, &mut out,
            );
            for (a, b) in out.iter().zip(&reference) {
                assert!((a - b).abs() < 1e-4, "{n_heads}h/{n_kv_heads}kv/{n_sel}sel: {a} vs {b}");
            }
            // Warm-scratch rerun (partials buffer reuse) must be identical.
            let mut out2 = vec![0.0f32; n_heads * d];
            fused_sparse_attend(
                &q, n_sel, n_heads, n_kv_heads, d, &serial, &fill, &mut scratch, &mut out2,
            );
            assert_eq!(out, out2);
        }
    }

    #[test]
    fn split_kv_pool_size_bit_invariant() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(47);
        let (n_heads, n_kv_heads, d, n_sel) = (4usize, 1usize, 16usize, 200usize);
        let kvd = n_kv_heads * d;
        let q = rng.normal_vec(n_heads * d, 1.0);
        let keys = rng.normal_vec(n_sel * kvd, 1.0);
        let values = rng.normal_vec(n_sel * kvd, 1.0);
        let fill = panel_fill(&keys, &values, kvd, d);
        let mut serial = vec![0.0f32; n_heads * d];
        let mut scratch = FusedAttendScratch::default();
        fused_sparse_attend(
            &q, n_sel, n_heads, n_kv_heads, d, &Workers::serial(), &fill, &mut scratch, &mut serial,
        );
        let handles = [
            Workers::scoped(2),
            Workers::scoped(8),
            Workers::pooled(1),
            Workers::pooled(2),
            Workers::pooled(8),
        ];
        for workers in &handles {
            let mut out = vec![0.0f32; n_heads * d];
            let mut s = FusedAttendScratch::default();
            fused_sparse_attend(&q, n_sel, n_heads, n_kv_heads, d, workers, &fill, &mut s, &mut out);
            assert_eq!(out, serial, "{workers:?} must be bit-identical on the split path");
        }
    }

    #[test]
    fn split_kv_partitions_across_pool_workers() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(53);
        let (n_heads, d) = (4usize, 16usize);
        let workers = Workers::pooled(8);
        // n_kv_heads=1 below the split threshold: the per-KV-head partition
        // has nothing to split, so the call must stay serial (no dispatch).
        {
            let n_sel = SPLIT_KV_MIN_SEL - 1;
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * d, 1.0);
            let values = rng.normal_vec(n_sel * d, 1.0);
            let fill = panel_fill(&keys, &values, d, d);
            let mut out = vec![0.0f32; n_heads * d];
            let mut s = FusedAttendScratch::default();
            let before = workers.pool_dispatch_count().unwrap();
            fused_sparse_attend(&q, n_sel, n_heads, 1, d, &workers, &fill, &mut s, &mut out);
            assert_eq!(
                workers.pool_dispatch_count().unwrap(),
                before,
                "below-threshold MQA attend must not fan out"
            );
        }
        // Past the threshold the selection ranges fan out across workers.
        {
            let n_sel = 4 * SPLIT_KV_SEG;
            let q = rng.normal_vec(n_heads * d, 1.0);
            let keys = rng.normal_vec(n_sel * d, 1.0);
            let values = rng.normal_vec(n_sel * d, 1.0);
            let fill = panel_fill(&keys, &values, d, d);
            let mut out = vec![0.0f32; n_heads * d];
            let mut s = FusedAttendScratch::default();
            let before = workers.pool_dispatch_count().unwrap();
            fused_sparse_attend(&q, n_sel, n_heads, 1, d, &workers, &fill, &mut s, &mut out);
            let dispatched = workers.pool_dispatch_count().unwrap() - before;
            assert_eq!(dispatched, 3, "4 segments on width 8 → 3 worker dispatches + caller");
        }
    }

    #[test]
    fn matmul_acc_accumulates_on_top() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(41);
        let (m, k, n) = (3, 7, 5);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut fresh = vec![0.0f32; m * n];
        matmul(&a, &b, &mut fresh, m, k, n);
        let mut acc = vec![1.0f32; m * n];
        matmul_acc(&a, &b, &mut acc, m, k, n);
        for (x, y) in acc.iter().zip(&fresh) {
            assert!((x - (y + 1.0)).abs() < 1e-5, "{x} vs {y}+1");
        }
    }

    #[test]
    fn gather_scatter_rows_roundtrip() {
        // 5 rows of length 3.
        let src: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let idx = [4usize, 0, 2];
        let mut packed = vec![0.0f32; idx.len() * 3];
        gather_rows(&src, 3, &idx, &mut packed);
        assert_eq!(packed, vec![12., 13., 14., 0., 1., 2., 6., 7., 8.]);
        // Scatter back into a zeroed matrix: exactly the gathered rows land.
        let mut out = vec![0.0f32; 15];
        scatter_rows(&packed, 3, &idx, &mut out);
        for &i in &idx {
            assert_eq!(out[i * 3..(i + 1) * 3], src[i * 3..(i + 1) * 3]);
        }
        assert_eq!(out[3..6], [0.0, 0.0, 0.0]); // untouched row stays zero
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn gather_rows_rejects_out_of_range() {
        let src = [0.0f32; 6];
        let mut out = [0.0f32; 2];
        gather_rows(&src, 2, &[3], &mut out);
    }

    #[test]
    fn lm_head_batch_matches_per_row_dot() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let (b, d, vocab) = (3, 8, 11);
        let x = rng.normal_vec(b * d, 1.0);
        let emb = rng.normal_vec(vocab * d, 1.0);
        let mut out = vec![0.0f32; b * vocab];
        lm_head_batch(&x, &emb, &mut out, b, d, vocab);
        for r in 0..b {
            for t in 0..vocab {
                let reference = dot(&emb[t * d..(t + 1) * d], &x[r * d..(r + 1) * d]);
                assert_eq!(out[r * vocab + t], reference, "row {r} tok {t}");
            }
        }
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut row = [1.0f32, 2.0, 3.0];
        softmax(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut row = [1000.0f32, 1000.0, 999.0];
        softmax(&mut row);
        assert!(row.iter().all(|x| x.is_finite()));
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_weight_normalizes() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        let ms = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(10.0) > 9.9);
    }
}

//! Top-k index selection — the critical-token selection primitive (§4.3).
//!
//! Decode-time selection runs per (layer, head-group, step), so this is a
//! hot path: we use a bounded binary min-heap over (score, index) instead of
//! sorting the whole score vector.

/// Indices of the k largest entries of `scores`, in DESCENDING score order.
/// Ties break toward the lower index. If k >= len, returns all indices
/// sorted by score.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(k.min(scores.len()));
    top_k_indices_into(scores, k, &mut out);
    out
}

/// Same as [`top_k_indices`] but reuses `out`'s allocation.
pub fn top_k_indices_into(scores: &[f32], k: usize, out: &mut Vec<usize>) {
    out.clear();
    let k = k.min(scores.len());
    if k == 0 {
        return;
    }
    // Min-heap of the best k seen so far, keyed by (score, Reverse(index))
    // so that on equal scores the LOWER index is considered better and kept.
    // Heap root = current worst of the best-k.
    let mut heap: Vec<(f32, usize)> = Vec::with_capacity(k);

    #[inline]
    fn better(a: (f32, usize), b: (f32, usize)) -> bool {
        // is a better (larger) than b?
        a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
    }
    #[inline]
    fn sift_down(heap: &mut [(f32, usize)], mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < heap.len() && better(heap[smallest], heap[l]) {
                smallest = l;
            }
            if r < heap.len() && better(heap[smallest], heap[r]) {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            heap.swap(i, smallest);
            i = smallest;
        }
    }
    #[inline]
    fn sift_up(heap: &mut [(f32, usize)], mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if better(heap[p], heap[i]) {
                heap.swap(p, i);
                i = p;
            } else {
                return;
            }
        }
    }

    for (i, &s) in scores.iter().enumerate() {
        let cand = (s, i);
        if heap.len() < k {
            heap.push(cand);
            let last = heap.len() - 1;
            sift_up(&mut heap, last);
        } else if better(cand, heap[0]) {
            heap[0] = cand;
            sift_down(&mut heap, 0);
        }
    }

    // Extract in descending order.
    heap.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    out.extend(heap.iter().map(|&(_, i)| i));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn picks_largest_descending() {
        let s = [0.1f32, 5.0, 3.0, 4.0, -1.0];
        assert_eq!(top_k_indices(&s, 3), vec![1, 3, 2]);
    }

    #[test]
    fn k_larger_than_len() {
        let s = [2.0f32, 1.0];
        assert_eq!(top_k_indices(&s, 10), vec![0, 1]);
    }

    #[test]
    fn k_zero_and_empty() {
        assert!(top_k_indices(&[1.0], 0).is_empty());
        assert!(top_k_indices(&[], 3).is_empty());
    }

    #[test]
    fn ties_prefer_lower_index() {
        let s = [7.0f32, 7.0, 7.0, 7.0];
        assert_eq!(top_k_indices(&s, 2), vec![0, 1]);
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let n = rng.range(1, 200);
            let k = rng.range(1, n + 1);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let fast = top_k_indices(&scores, k);
            let mut all: Vec<usize> = (0..n).collect();
            all.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
            });
            assert_eq!(fast, all[..k].to_vec());
        }
    }
}

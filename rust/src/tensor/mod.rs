//! Dense row-major f32 tensor substrate.
//!
//! All host-side math in the coordinator, the baselines and the CPU model
//! goes through this module. The hot paths (`matmul`, `matmul_tn`,
//! `softmax_rows`) are written for cache-friendliness: the inner loops are
//! unit-stride and `matmul` packs the RHS when it pays off.

pub mod ops;
pub mod simd;
pub mod topk;

pub use ops::*;
pub use topk::{top_k_indices, top_k_indices_into};

use crate::util::rng::Rng;

/// Row-major 2-D matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from existing data (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec length mismatch");
        Mat { rows, cols, data }
    }

    /// Gaussian-initialized matrix with std `std`.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, std) }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Immutable row view.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row view.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Element accessor (debug/test convenience).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// `self @ other` — see [`ops::matmul`].
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        ops::matmul(&self.data, &other.data, &mut out.data, self.rows, self.cols, other.cols);
        out
    }

    /// `self @ otherᵀ` — other is (n, k) with k == self.cols.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let mut out = Mat::zeros(self.rows, other.rows);
        ops::matmul_tn(&self.data, &other.data, &mut out.data, self.rows, self.cols, other.rows);
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// Select rows by index into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(4, 4, 1.0, &mut rng);
        let i = Mat::eye(4);
        let ai = a.matmul(&i);
        for (x, y) in ai.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        let b = Mat::randn(3, 7, 1.0, &mut rng);
        let via_t = a.matmul_t(&b);
        let explicit = a.matmul(&b.transpose());
        for (x, y) in via_t.data.iter().zip(&explicit.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(6, 2, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gather_rows_picks() {
        let m = Mat::from_vec(3, 2, vec![0., 1., 10., 11., 20., 21.]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![20., 21., 0., 1.]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_checked() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}

//! Full (dense) attention backend — the accuracy baseline and the
//! FlashAttention-2 stand-in for the latency tables.
//!
//! Keys are cached **post-RoPE** (standard serving practice: rotate once at
//! append). `attend` makes a single streaming pass per head with an online
//! softmax (the FlashAttention recurrence), so its traffic is exactly the
//! `2·s·d` elements §4.5 charges full attention with.
//!
//! The batched-prefill path (`append_batch`/`prefill_attend`) rotates a
//! whole chunk of keys/queries in one sweep and runs the blocked causal
//! kernel [`crate::tensor::ops::causal_attend_chunk`] — tiled QKᵀ,
//! row-softmax, PV — instead of n streaming decode passes.

use super::{AttentionBackend, AttnShape, FootprintModel, Traffic};
use crate::rope::RopeTable;

/// Dense KV cache + streaming-softmax attention.
pub struct FullAttention {
    shape: AttnShape,
    rope: RopeTable,
    /// (len, kv_dim) post-RoPE keys, row-major, grown by append.
    keys: Vec<f32>,
    /// (len, kv_dim) values.
    values: Vec<f32>,
    len: usize,
    traffic: Traffic,
    /// Scratch: per-head accumulator + rotated query (hot path must not
    /// allocate — §Perf L3 iteration 1).
    scratch_acc: Vec<f32>,
    scratch_qr: Vec<f32>,
    /// Panel/tile buffers for the blocked batched-prefill kernel.
    scratch_chunk: crate::tensor::ops::ChunkAttendScratch,
}

impl FullAttention {
    pub fn new(shape: AttnShape) -> FullAttention {
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        FullAttention {
            shape,
            rope,
            keys: Vec::new(),
            values: Vec::new(),
            len: 0,
            traffic: Traffic::default(),
            scratch_acc: vec![0.0; shape.head_dim],
            scratch_qr: Vec::new(),
            scratch_chunk: crate::tensor::ops::ChunkAttendScratch::default(),
        }
    }

    /// Read-only view of the cached post-RoPE keys (used by analyses).
    pub fn keys(&self) -> &[f32] {
        &self.keys
    }
}

impl AttentionBackend for FullAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        assert_eq!(k.len(), kvd);
        assert_eq!(v.len(), kvd);
        let pos = self.len;
        let mut kr = k.to_vec();
        self.rope.apply_multihead(&mut kr, pos);
        self.keys.extend_from_slice(&kr);
        self.values.extend_from_slice(v);
        self.len += 1;
        self.traffic.write_f32(2 * kvd);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        let d = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        assert_eq!(q.len(), self.shape.q_dim());
        assert_eq!(out.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let pos = self.len - 1;
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        let qr = &mut self.scratch_qr;
        self.rope.apply_multihead(qr, pos);

        let scale = 1.0 / (d as f32).sqrt();
        let group = self.shape.group_size();
        out.fill(0.0);
        for h in 0..self.shape.n_heads {
            let kvh = h / group;
            let qh = &qr[h * d..(h + 1) * d];
            // Online softmax (FlashAttention recurrence): single pass,
            // running max m, running denom l, running weighted value acc.
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            let acc = &mut self.scratch_acc;
            acc.fill(0.0);
            for j in 0..self.len {
                let krow = &self.keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
                let s = crate::tensor::ops::dot(qh, krow) * scale;
                let m_new = m.max(s);
                let corr = (m - m_new).exp();
                let p = (s - m_new).exp();
                l = l * corr + p;
                let vrow = &self.values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
                for (a, &vv) in acc.iter_mut().zip(vrow) {
                    *a = *a * corr + p * vv;
                }
                m = m_new;
            }
            let inv = 1.0 / l;
            let oh = &mut out[h * d..(h + 1) * d];
            for (o, a) in oh.iter_mut().zip(acc.iter()) {
                *o = a * inv;
            }
        }
        // Each kv row (key + value) is streamed once per kv head-group pass;
        // query heads sharing a kv head reread it (group× for GQA) but we
        // meter the §4.5 canonical cost: 2·s·kv_dim per decode.
        self.traffic.read_f32(2 * self.len * kvd);
    }

    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        let kvd = self.shape.kv_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        let start = self.len;
        let base = self.keys.len();
        self.keys.extend_from_slice(ks);
        // Batched RoPE: one sweep over the chunk's rows at their positions.
        self.rope.apply_rows_offset(&mut self.keys[base..], kvd, start);
        self.values.extend_from_slice(vs);
        self.len += n;
        self.traffic.write_f32(2 * n * kvd);
    }

    fn prefill_attend(&mut self, qs: &[f32], n: usize, out: &mut [f32]) {
        let d = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        let qd = self.shape.q_dim();
        assert!(n > 0 && n <= self.len, "chunk {n} vs cache {}", self.len);
        assert_eq!(qs.len(), n * qd);
        assert_eq!(out.len(), n * qd);
        let start = self.len - n;
        // Batched query RoPE into scratch.
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(qs);
        self.rope.apply_rows_offset(&mut self.scratch_qr, qd, start);
        crate::tensor::ops::causal_attend_chunk(
            &self.scratch_qr,
            &self.keys,
            &self.values,
            n,
            self.len,
            self.shape.n_heads,
            self.shape.n_kv_heads,
            d,
            &mut self.scratch_chunk,
            out,
        );
        // Canonical metering: each query row pays what its single-token
        // attend would have — 2·(visible rows)·kv_dim.
        let visible_rows: usize = (0..n).map(|t| start + t + 1).sum();
        self.traffic.read_f32(2 * visible_rows * kvd);
    }

    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        self.append_batch(ks, vs, n);
        self.prefill_attend(qs, n, out);
    }

    fn end_prefill(&mut self) {
        // The chunk panels scale with the full cache length (≈2·len·d
        // floats per layer) and decode never reads them — release them.
        // scratch_qr grew to chunk·q_dim during prefill; decode only needs
        // q_dim, so shrink to that (not drop: decode's attend() reuses it
        // every step under the no-alloc hot-path invariant).
        self.scratch_chunk = crate::tensor::ops::ChunkAttendScratch::default();
        self.scratch_qr.clear();
        self.scratch_qr.shrink_to(self.shape.q_dim());
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }

    fn footprint(&self) -> FootprintModel {
        // Dense fp32: one key + one value row per token, no fixed state.
        FootprintModel::linear(0, 2 * self.shape.kv_dim() * 4)
    }

    fn name(&self) -> &'static str {
        "full"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(b: &mut FullAttention, n: usize, rng: &mut Rng) {
        let kvd = b.shape.kv_dim();
        for _ in 0..n {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            b.append(&k, &v);
        }
    }

    #[test]
    fn single_token_attention_is_value() {
        let shape = AttnShape::mha(2, 8, 32);
        let mut b = FullAttention::new(shape);
        let k = vec![0.5f32; 16];
        let v: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        b.append(&k, &v);
        let q = vec![1.0f32; 16];
        let mut out = vec![0.0f32; 16];
        b.attend(&q, &mut out);
        for (o, vv) in out.iter().zip(&v) {
            assert!((o - vv).abs() < 1e-5);
        }
    }

    #[test]
    fn online_softmax_matches_exact() {
        let shape = AttnShape::mha(4, 16, 128);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(51);
        fill(&mut b, 100, &mut rng);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0f32; shape.q_dim()];
        b.attend(&q, &mut out);

        // Exact two-pass computation on the same (post-RoPE) cache.
        let mut qr = q.clone();
        b.rope.apply_multihead(&mut qr, b.len - 1);
        let mut exact = vec![0.0f32; shape.q_dim()];
        super::super::exact_attention(&shape, &qr, &b.keys, &b.values, b.len, &mut exact);
        for (a, e) in out.iter().zip(&exact) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
    }

    #[test]
    fn traffic_grows_linearly_with_len() {
        let shape = AttnShape::mha(1, 4, 64);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(53);
        fill(&mut b, 10, &mut rng);
        let q = rng.normal_vec(4, 1.0);
        let mut out = vec![0.0f32; 4];
        let t0 = b.traffic();
        b.attend(&q, &mut out);
        let dt = b.traffic().read - t0.read;
        assert_eq!(dt, (2 * 10 * 4 * 4) as u64);
    }

    #[test]
    fn batched_prefill_matches_sequential_and_meters_identically() {
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let (kvd, qd) = (shape.kv_dim(), shape.q_dim());
        let mut rng = Rng::new(57);
        let mut seq = FullAttention::new(shape);
        let mut bat = FullAttention::new(shape);
        // Warm prefix.
        for _ in 0..5 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            seq.append(&k, &v);
            bat.append(&k, &v);
        }
        let n = 21;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut o_seq = vec![0.0f32; n * qd];
        for t in 0..n {
            seq.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            seq.attend(&qs[t * qd..(t + 1) * qd], &mut o_seq[t * qd..(t + 1) * qd]);
        }
        let mut o_bat = vec![0.0f32; n * qd];
        bat.forward_batch(&ks, &vs, &qs, n, &mut o_bat);
        for (a, b) in o_seq.iter().zip(&o_bat) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // Cache contents and canonical traffic metering agree exactly.
        assert_eq!(seq.len, bat.len);
        for (a, b) in seq.keys.iter().zip(&bat.keys) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(seq.traffic().read, bat.traffic().read);
        assert_eq!(seq.traffic().written, bat.traffic().written);
    }

    #[test]
    fn gqa_runs() {
        let shape = AttnShape::gqa(8, 2, 8, 64);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(55);
        fill(&mut b, 20, &mut rng);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0f32; shape.q_dim()];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}

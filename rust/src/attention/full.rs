//! Full (dense) attention backend — the accuracy baseline and the
//! FlashAttention-2 stand-in for the latency tables.
//!
//! Keys are cached **post-RoPE** (standard serving practice: rotate once at
//! append). `attend` makes a single streaming pass per head with an online
//! softmax (the FlashAttention recurrence), so its traffic is exactly the
//! `2·s·d` elements §4.5 charges full attention with.

use super::{AttentionBackend, AttnShape, Traffic};
use crate::rope::RopeTable;

/// Dense KV cache + streaming-softmax attention.
pub struct FullAttention {
    shape: AttnShape,
    rope: RopeTable,
    /// (len, kv_dim) post-RoPE keys, row-major, grown by append.
    keys: Vec<f32>,
    /// (len, kv_dim) values.
    values: Vec<f32>,
    len: usize,
    traffic: Traffic,
    /// Scratch: per-head accumulator + rotated query (hot path must not
    /// allocate — §Perf L3 iteration 1).
    scratch_acc: Vec<f32>,
    scratch_qr: Vec<f32>,
}

impl FullAttention {
    pub fn new(shape: AttnShape) -> FullAttention {
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        FullAttention {
            shape,
            rope,
            keys: Vec::new(),
            values: Vec::new(),
            len: 0,
            traffic: Traffic::default(),
            scratch_acc: vec![0.0; shape.head_dim],
            scratch_qr: Vec::new(),
        }
    }

    /// Read-only view of the cached post-RoPE keys (used by analyses).
    pub fn keys(&self) -> &[f32] {
        &self.keys
    }
}

impl AttentionBackend for FullAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        assert_eq!(k.len(), kvd);
        assert_eq!(v.len(), kvd);
        let pos = self.len;
        let mut kr = k.to_vec();
        self.rope.apply_multihead(&mut kr, pos);
        self.keys.extend_from_slice(&kr);
        self.values.extend_from_slice(v);
        self.len += 1;
        self.traffic.write_f32(2 * kvd);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        let d = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        assert_eq!(q.len(), self.shape.q_dim());
        assert_eq!(out.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let pos = self.len - 1;
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        let qr = &mut self.scratch_qr;
        self.rope.apply_multihead(qr, pos);

        let scale = 1.0 / (d as f32).sqrt();
        let group = self.shape.group_size();
        out.fill(0.0);
        for h in 0..self.shape.n_heads {
            let kvh = h / group;
            let qh = &qr[h * d..(h + 1) * d];
            // Online softmax (FlashAttention recurrence): single pass,
            // running max m, running denom l, running weighted value acc.
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            let acc = &mut self.scratch_acc;
            acc.fill(0.0);
            for j in 0..self.len {
                let krow = &self.keys[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
                let s = crate::tensor::ops::dot(qh, krow) * scale;
                let m_new = m.max(s);
                let corr = (m - m_new).exp();
                let p = (s - m_new).exp();
                l = l * corr + p;
                let vrow = &self.values[j * kvd + kvh * d..j * kvd + (kvh + 1) * d];
                for (a, &vv) in acc.iter_mut().zip(vrow) {
                    *a = *a * corr + p * vv;
                }
                m = m_new;
            }
            let inv = 1.0 / l;
            let oh = &mut out[h * d..(h + 1) * d];
            for (o, a) in oh.iter_mut().zip(acc.iter()) {
                *o = a * inv;
            }
        }
        // Each kv row (key + value) is streamed once per kv head-group pass;
        // query heads sharing a kv head reread it (group× for GQA) but we
        // meter the §4.5 canonical cost: 2·s·kv_dim per decode.
        self.traffic.read_f32(2 * self.len * kvd);
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }

    fn name(&self) -> &'static str {
        "full"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(b: &mut FullAttention, n: usize, rng: &mut Rng) {
        let kvd = b.shape.kv_dim();
        for _ in 0..n {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            b.append(&k, &v);
        }
    }

    #[test]
    fn single_token_attention_is_value() {
        let shape = AttnShape::mha(2, 8, 32);
        let mut b = FullAttention::new(shape);
        let k = vec![0.5f32; 16];
        let v: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        b.append(&k, &v);
        let q = vec![1.0f32; 16];
        let mut out = vec![0.0f32; 16];
        b.attend(&q, &mut out);
        for (o, vv) in out.iter().zip(&v) {
            assert!((o - vv).abs() < 1e-5);
        }
    }

    #[test]
    fn online_softmax_matches_exact() {
        let shape = AttnShape::mha(4, 16, 128);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(51);
        fill(&mut b, 100, &mut rng);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0f32; shape.q_dim()];
        b.attend(&q, &mut out);

        // Exact two-pass computation on the same (post-RoPE) cache.
        let mut qr = q.clone();
        b.rope.apply_multihead(&mut qr, b.len - 1);
        let mut exact = vec![0.0f32; shape.q_dim()];
        super::super::exact_attention(&shape, &qr, &b.keys, &b.values, b.len, &mut exact);
        for (a, e) in out.iter().zip(&exact) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
    }

    #[test]
    fn traffic_grows_linearly_with_len() {
        let shape = AttnShape::mha(1, 4, 64);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(53);
        fill(&mut b, 10, &mut rng);
        let q = rng.normal_vec(4, 1.0);
        let mut out = vec![0.0f32; 4];
        let t0 = b.traffic();
        b.attend(&q, &mut out);
        let dt = b.traffic().read - t0.read;
        assert_eq!(dt, (2 * 10 * 4 * 4) as u64);
    }

    #[test]
    fn gqa_runs() {
        let shape = AttnShape::gqa(8, 2, 8, 64);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(55);
        fill(&mut b, 20, &mut rng);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0f32; shape.q_dim()];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}

//! Full (dense) attention backend — the accuracy baseline and the
//! FlashAttention-2 stand-in for the latency tables.
//!
//! Keys are cached **post-RoPE** (standard serving practice: rotate once at
//! append). `attend` makes a single streaming pass per head with an online
//! softmax (the FlashAttention recurrence), so its traffic is exactly the
//! `2·s·d` elements §4.5 charges full attention with.
//!
//! The batched-prefill path (`append_batch`/`prefill_attend`) rotates a
//! whole chunk of keys/queries in one sweep and runs the blocked causal
//! kernel [`crate::tensor::ops::causal_attend_chunk`] — tiled QKᵀ,
//! row-softmax, PV — instead of n streaming decode passes.

use super::{AttentionBackend, AttnShape, FootprintModel, PrefixSnapshot, SharedVec, Traffic};
use crate::rope::RopeTable;
use std::sync::Arc;

/// Payload behind the dense fp32 backends' [`PrefixSnapshot`]s
/// (FullAttention and the `DenseCache`-based baselines): post-RoPE key and
/// value rows frozen behind `Arc`s, plus the donor's traffic meter at fork
/// time (which bit-equals a cold prefill's, so adopters' meters continue
/// identically).
pub(crate) struct DensePrefixData {
    pub keys: Arc<[f32]>,
    pub values: Arc<[f32]>,
    pub traffic: Traffic,
}

/// Dense KV cache + streaming-softmax attention.
pub struct FullAttention {
    shape: AttnShape,
    rope: RopeTable,
    /// (len, kv_dim) post-RoPE keys, row-major, grown by append; the
    /// leading rows may be held by reference to an adopted shared prefix.
    keys: SharedVec,
    /// (len, kv_dim) values.
    values: SharedVec,
    len: usize,
    traffic: Traffic,
    /// Scratch: per-head accumulator + rotated query (hot path must not
    /// allocate — §Perf L3 iteration 1).
    scratch_acc: Vec<f32>,
    scratch_qr: Vec<f32>,
    /// Panel/tile buffers for the blocked batched-prefill kernel.
    scratch_chunk: crate::tensor::ops::ChunkAttendScratch,
}

impl FullAttention {
    pub fn new(shape: AttnShape) -> FullAttention {
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        FullAttention {
            shape,
            rope,
            keys: SharedVec::new(),
            values: SharedVec::new(),
            len: 0,
            traffic: Traffic::default(),
            scratch_acc: vec![0.0; shape.head_dim],
            scratch_qr: Vec::new(),
            scratch_chunk: crate::tensor::ops::ChunkAttendScratch::default(),
        }
    }

    /// Read-only view of the cached post-RoPE keys (used by analyses).
    pub fn keys(&self) -> &SharedVec {
        &self.keys
    }
}

impl AttentionBackend for FullAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        assert_eq!(k.len(), kvd);
        assert_eq!(v.len(), kvd);
        let pos = self.len;
        let mut kr = k.to_vec();
        self.rope.apply_multihead(&mut kr, pos);
        self.keys.extend_from_slice(&kr);
        self.values.extend_from_slice(v);
        self.len += 1;
        self.traffic.write_f32(2 * kvd);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        let d = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        assert_eq!(q.len(), self.shape.q_dim());
        assert_eq!(out.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let pos = self.len - 1;
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        let qr = &mut self.scratch_qr;
        self.rope.apply_multihead(qr, pos);

        let scale = 1.0 / (d as f32).sqrt();
        let group = self.shape.group_size();
        out.fill(0.0);
        for h in 0..self.shape.n_heads {
            let kvh = h / group;
            let qh = &qr[h * d..(h + 1) * d];
            // Online softmax (FlashAttention recurrence): single pass,
            // running max m, running denom l, running weighted value acc.
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            let acc = &mut self.scratch_acc;
            acc.fill(0.0);
            for j in 0..self.len {
                let krow = self.keys.row(j * kvd + kvh * d, d);
                let s = crate::tensor::ops::dot(qh, krow) * scale;
                let m_new = m.max(s);
                let corr = (m - m_new).exp();
                let p = (s - m_new).exp();
                l = l * corr + p;
                let vrow = self.values.row(j * kvd + kvh * d, d);
                for (a, &vv) in acc.iter_mut().zip(vrow) {
                    *a = *a * corr + p * vv;
                }
                m = m_new;
            }
            let inv = 1.0 / l;
            let oh = &mut out[h * d..(h + 1) * d];
            for (o, a) in oh.iter_mut().zip(acc.iter()) {
                *o = a * inv;
            }
        }
        // Each kv row (key + value) is streamed once per kv head-group pass;
        // query heads sharing a kv head reread it (group× for GQA) but we
        // meter the §4.5 canonical cost: 2·s·kv_dim per decode.
        self.traffic.read_f32(2 * self.len * kvd);
    }

    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        let kvd = self.shape.kv_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        let start = self.len;
        self.keys.extend_from_slice(ks);
        // Batched RoPE: one sweep over the chunk's rows at their positions
        // (the just-appended private tail — never the shared prefix).
        self.rope.apply_rows_offset(self.keys.tail_mut(n * kvd), kvd, start);
        self.values.extend_from_slice(vs);
        self.len += n;
        self.traffic.write_f32(2 * n * kvd);
    }

    fn prefill_attend(&mut self, qs: &[f32], n: usize, out: &mut [f32]) {
        let d = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        let qd = self.shape.q_dim();
        assert!(n > 0 && n <= self.len, "chunk {n} vs cache {}", self.len);
        assert_eq!(qs.len(), n * qd);
        assert_eq!(out.len(), n * qd);
        let start = self.len - n;
        // Batched query RoPE into scratch.
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(qs);
        self.rope.apply_rows_offset(&mut self.scratch_qr, qd, start);
        crate::tensor::ops::causal_attend_chunk_seg(
            &self.scratch_qr,
            &self.keys.segs(),
            &self.values.segs(),
            n,
            self.len,
            self.shape.n_heads,
            self.shape.n_kv_heads,
            d,
            &mut self.scratch_chunk,
            out,
        );
        // Canonical metering: each query row pays what its single-token
        // attend would have — 2·(visible rows)·kv_dim.
        let visible_rows: usize = (0..n).map(|t| start + t + 1).sum();
        self.traffic.read_f32(2 * visible_rows * kvd);
    }

    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        self.append_batch(ks, vs, n);
        self.prefill_attend(qs, n, out);
    }

    fn fork_prefix(&self, n_tokens: usize) -> Option<PrefixSnapshot> {
        if n_tokens == 0 || n_tokens != self.len {
            return None;
        }
        let keys = self.keys.fork_arc();
        let values = self.values.fork_arc();
        let shared_bytes = (keys.len() + values.len()) * 4;
        Some(PrefixSnapshot {
            n_tokens,
            shared_bytes,
            data: Arc::new(DensePrefixData { keys, values, traffic: self.traffic }),
        })
    }

    fn adopt_prefix(&mut self, snap: &PrefixSnapshot) -> bool {
        if !self.is_empty() {
            return false;
        }
        let Some(d) = snap.data.downcast_ref::<DensePrefixData>() else {
            return false;
        };
        if d.keys.len() != snap.n_tokens * self.shape.kv_dim() {
            return false;
        }
        self.keys = SharedVec::from_shared(Arc::clone(&d.keys));
        self.values = SharedVec::from_shared(Arc::clone(&d.values));
        self.len = snap.n_tokens;
        self.traffic = d.traffic;
        true
    }

    fn shared_prefix_bytes(&self) -> usize {
        self.keys.shared_bytes() + self.values.shared_bytes()
    }

    fn end_prefill(&mut self) {
        // The chunk panels scale with the full cache length (≈2·len·d
        // floats per layer) and decode never reads them — release them.
        // scratch_qr grew to chunk·q_dim during prefill; decode only needs
        // q_dim, so shrink to that (not drop: decode's attend() reuses it
        // every step under the no-alloc hot-path invariant).
        self.scratch_chunk = crate::tensor::ops::ChunkAttendScratch::default();
        self.scratch_qr.clear();
        self.scratch_qr.shrink_to(self.shape.q_dim());
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }

    fn footprint(&self) -> FootprintModel {
        // Dense fp32: one key + one value row per token, no fixed state.
        FootprintModel::linear(0, 2 * self.shape.kv_dim() * 4)
    }

    fn name(&self) -> &'static str {
        "full"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(b: &mut FullAttention, n: usize, rng: &mut Rng) {
        let kvd = b.shape.kv_dim();
        for _ in 0..n {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            b.append(&k, &v);
        }
    }

    #[test]
    fn single_token_attention_is_value() {
        let shape = AttnShape::mha(2, 8, 32);
        let mut b = FullAttention::new(shape);
        let k = vec![0.5f32; 16];
        let v: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        b.append(&k, &v);
        let q = vec![1.0f32; 16];
        let mut out = vec![0.0f32; 16];
        b.attend(&q, &mut out);
        for (o, vv) in out.iter().zip(&v) {
            assert!((o - vv).abs() < 1e-5);
        }
    }

    #[test]
    fn online_softmax_matches_exact() {
        let shape = AttnShape::mha(4, 16, 128);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(51);
        fill(&mut b, 100, &mut rng);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0f32; shape.q_dim()];
        b.attend(&q, &mut out);

        // Exact two-pass computation on the same (post-RoPE) cache.
        let mut qr = q.clone();
        b.rope.apply_multihead(&mut qr, b.len - 1);
        let mut exact = vec![0.0f32; shape.q_dim()];
        let (keys, values) = (b.keys.to_vec(), b.values.to_vec());
        super::super::exact_attention(&shape, &qr, &keys, &values, b.len, &mut exact);
        for (a, e) in out.iter().zip(&exact) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
    }

    #[test]
    fn traffic_grows_linearly_with_len() {
        let shape = AttnShape::mha(1, 4, 64);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(53);
        fill(&mut b, 10, &mut rng);
        let q = rng.normal_vec(4, 1.0);
        let mut out = vec![0.0f32; 4];
        let t0 = b.traffic();
        b.attend(&q, &mut out);
        let dt = b.traffic().read - t0.read;
        assert_eq!(dt, (2 * 10 * 4 * 4) as u64);
    }

    #[test]
    fn batched_prefill_matches_sequential_and_meters_identically() {
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let (kvd, qd) = (shape.kv_dim(), shape.q_dim());
        let mut rng = Rng::new(57);
        let mut seq = FullAttention::new(shape);
        let mut bat = FullAttention::new(shape);
        // Warm prefix.
        for _ in 0..5 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            seq.append(&k, &v);
            bat.append(&k, &v);
        }
        let n = 21;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut o_seq = vec![0.0f32; n * qd];
        for t in 0..n {
            seq.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            seq.attend(&qs[t * qd..(t + 1) * qd], &mut o_seq[t * qd..(t + 1) * qd]);
        }
        let mut o_bat = vec![0.0f32; n * qd];
        bat.forward_batch(&ks, &vs, &qs, n, &mut o_bat);
        for (a, b) in o_seq.iter().zip(&o_bat) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // Cache contents and canonical traffic metering agree exactly.
        assert_eq!(seq.len, bat.len);
        for (a, b) in seq.keys.iter().zip(bat.keys.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(seq.traffic().read, bat.traffic().read);
        assert_eq!(seq.traffic().written, bat.traffic().written);
    }

    #[test]
    fn fork_adopt_decode_bit_identical_to_cold() {
        use crate::attention::AttentionBackend as _;
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let (kvd, qd) = (shape.kv_dim(), shape.q_dim());
        let mut rng = Rng::new(59);
        // Donor prefills 24 tokens and forks; cold gets the same tokens
        // appended directly.
        let mut donor = FullAttention::new(shape);
        let mut cold = FullAttention::new(shape);
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..24).map(|_| (rng.normal_vec(kvd, 1.0), rng.normal_vec(kvd, 1.0))).collect();
        for (k, v) in &rows {
            donor.append(k, v);
            cold.append(k, v);
        }
        let snap = donor.fork_prefix(donor.len()).expect("full fork");
        let mut adopted = FullAttention::new(shape);
        assert!(adopted.adopt_prefix(&snap));
        assert_eq!(adopted.len(), cold.len());
        assert_eq!(adopted.kv_bytes(), cold.kv_bytes());
        assert_eq!(adopted.traffic(), cold.traffic());
        assert!(adopted.shared_prefix_bytes() > 0);
        assert_eq!(cold.shared_prefix_bytes(), 0);
        // Divergent suffix + decode must be bit-identical to cold.
        for _ in 0..9 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            let q = rng.normal_vec(qd, 1.0);
            let (mut oa, mut oc) = (vec![0.0f32; qd], vec![0.0f32; qd]);
            adopted.append(&k, &v);
            cold.append(&k, &v);
            adopted.attend(&q, &mut oa);
            cold.attend(&q, &mut oc);
            assert_eq!(oa, oc, "adopted decode must bit-match cold");
        }
        assert_eq!(adopted.kv_bytes(), cold.kv_bytes());
        assert_eq!(adopted.traffic(), cold.traffic());
        // The donor is unaffected by its adopters' appends.
        assert_eq!(donor.len(), 24);
        // Fork requires a full capture.
        assert!(donor.fork_prefix(23).is_none());
        assert!(FullAttention::new(shape).fork_prefix(0).is_none());
    }

    #[test]
    fn gqa_runs() {
        let shape = AttnShape::gqa(8, 2, 8, 64);
        let mut b = FullAttention::new(shape);
        let mut rng = Rng::new(55);
        fill(&mut b, 20, &mut rng);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0f32; shape.q_dim()];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}

//! StreamingLLM baseline (Xiao et al., 2023): fixed-pattern sparsity —
//! attention sinks (first tokens) + a sliding recent window, nothing else.
//! Table 1 classifies it "Fixed pattern / low data movement / low accuracy".

use crate::attention::baselines::common::DenseCache;
use crate::attention::{exact_attention, merge_selection, AttentionBackend, AttnShape, Traffic};

pub struct StreamingLlmAttention {
    cache: DenseCache,
    sink: usize,
    recent: usize,
    traffic: Traffic,
}

impl StreamingLlmAttention {
    pub fn new(shape: AttnShape, sink: usize, recent: usize) -> StreamingLlmAttention {
        StreamingLlmAttention { cache: DenseCache::new(shape), sink, recent, traffic: Traffic::default() }
    }
}

impl AttentionBackend for StreamingLlmAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        // A production StreamingLLM evicts non-sink/non-recent tokens; we
        // keep them resident (like the reference implementation's cache) but
        // never touch them, so *traffic* matches the method's claim while
        // kv_bytes reports the un-evicted variant. Eviction is modeled in
        // kv_bytes() below by reporting only live tokens.
        self.cache.append(k, v, &mut self.traffic);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let sel = merge_selection(self.cache.len, self.sink, self.recent, &[]);
        let qr = self.cache.rotate_query(q);
        let (ks, vs) = self.cache.gather(&sel, &mut self.traffic);
        exact_attention(&self.cache.shape, &qr, &ks, &vs, sel.len(), out);
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        // Live set after eviction: sink + recent window.
        let live = (self.sink + self.recent).min(self.cache.len);
        live * 2 * self.cache.shape.kv_dim() * 4
    }

    fn name(&self) -> &'static str {
        "streaming_llm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ignores_middle_tokens() {
        let shape = AttnShape::mha(1, 8, 128);
        let mut b = StreamingLlmAttention::new(shape, 2, 4);
        let mut rng = Rng::new(85);
        // Put a huge-magnitude value in the middle; it must not leak into out.
        for i in 0..50 {
            let k = rng.normal_vec(8, 1.0);
            let v = if i == 25 { vec![1000.0; 8] } else { rng.normal_vec(8, 1.0) };
            b.append(&k, &v);
        }
        let q = rng.normal_vec(8, 1.0);
        let mut out = vec![0.0; 8];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.abs() < 100.0), "middle token leaked: {out:?}");
    }

    #[test]
    fn kv_bytes_bounded_by_window() {
        let shape = AttnShape::mha(1, 8, 512);
        let mut b = StreamingLlmAttention::new(shape, 4, 16);
        let mut rng = Rng::new(87);
        for _ in 0..400 {
            let k = rng.normal_vec(8, 1.0);
            let v = rng.normal_vec(8, 1.0);
            b.append(&k, &v);
        }
        assert_eq!(b.kv_bytes(), 20 * 2 * 8 * 4);
    }
}

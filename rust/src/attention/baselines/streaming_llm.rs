//! StreamingLLM baseline (Xiao et al., 2023): fixed-pattern sparsity —
//! attention sinks (first tokens) + a sliding recent window, nothing else.
//! Table 1 classifies it "Fixed pattern / low data movement / low accuracy".

use crate::attention::baselines::common::{dense_prefix_rows, BaselineScratch, DenseCache};
use crate::attention::full::DensePrefixData;
use crate::attention::{
    merge_selection_into, AttentionBackend, AttnShape, FootprintModel, PrefixSnapshot, Traffic,
};
use crate::tensor::ops::sparse_attend_threaded;
use crate::util::threadpool::Workers;
use std::sync::Arc;

pub struct StreamingLlmAttention {
    cache: DenseCache,
    sink: usize,
    recent: usize,
    traffic: Traffic,
    scratch: BaselineScratch,
}

impl StreamingLlmAttention {
    pub fn new(shape: AttnShape, sink: usize, recent: usize) -> StreamingLlmAttention {
        StreamingLlmAttention {
            cache: DenseCache::new(shape),
            sink,
            recent,
            traffic: Traffic::default(),
            scratch: BaselineScratch::default(),
        }
    }

    /// Attend for the query at absolute position `pos` (visible prefix
    /// `0..=pos`). The fixed sink+recent pattern is position-relative, so
    /// this is exact for any chunk position — the batched prefill path
    /// reproduces the sequential outputs bit-for-bit.
    fn attend_at(&mut self, q: &[f32], pos: usize, out: &mut [f32]) {
        let vis = pos + 1;
        let shape = self.cache.shape;
        merge_selection_into(
            vis,
            self.sink,
            self.recent,
            &[],
            &mut self.scratch.crit_sorted,
            &mut self.scratch.sel,
        );
        self.cache.rotate_query_into(q, pos, &mut self.scratch.qr);
        self.cache.gather_into(
            &self.scratch.sel,
            &mut self.scratch.keys,
            &mut self.scratch.vals,
            &mut self.traffic,
        );
        sparse_attend_threaded(
            &self.scratch.qr,
            &self.scratch.keys,
            &self.scratch.vals,
            self.scratch.sel.len(),
            shape.n_heads,
            shape.n_kv_heads,
            shape.head_dim,
            &self.scratch.workers,
            &mut self.scratch.attend,
            out,
        );
    }
}

impl AttentionBackend for StreamingLlmAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        // A production StreamingLLM evicts non-sink/non-recent tokens; we
        // keep them resident (like the reference implementation's cache) but
        // never touch them, so *traffic* matches the method's claim while
        // kv_bytes reports the un-evicted variant. Eviction is modeled in
        // kv_bytes() below by reporting only live tokens.
        self.cache.append(k, v, &mut self.traffic);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let pos = self.cache.len - 1;
        self.attend_at(q, pos, out);
    }

    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        self.cache.append_batch(ks, vs, n, &mut self.traffic);
    }

    fn prefill_attend(&mut self, qs: &[f32], n: usize, out: &mut [f32]) {
        let qd = self.cache.shape.q_dim();
        let len = self.cache.len;
        // Leading rows whose whole prefix fits in sink+recent see dense
        // causal attention — one blocked-kernel call instead of n_dense
        // per-position selection/gather/attend rounds. The remaining rows
        // keep per-position semantics (their recent window slides per row).
        let start = len - n;
        let n_dense = dense_prefix_rows(start, n, self.sink + self.recent);
        if n_dense > 0 {
            self.cache.prefill_attend_dense_rows(
                qs,
                n,
                n_dense,
                &mut self.scratch.qrows,
                &mut self.scratch.chunk,
                &mut out[..n_dense * qd],
                &mut self.traffic,
            );
        }
        if n_dense < n {
            DenseCache::prefill_attend_rows(
                len,
                qd,
                &qs[n_dense * qd..],
                n - n_dense,
                &mut out[n_dense * qd..],
                |q, pos, o| self.attend_at(q, pos, o),
            );
        }
    }

    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        self.append_batch(ks, vs, n);
        self.prefill_attend(qs, n, out);
    }

    fn end_prefill(&mut self) {
        self.scratch.end_prefill();
    }

    fn fork_prefix(&self, n_tokens: usize) -> Option<PrefixSnapshot> {
        if n_tokens == 0 || n_tokens != self.cache.len {
            return None;
        }
        let dense = self.cache.snapshot(self.traffic);
        let shared_bytes = (dense.keys.len() + dense.values.len()) * 4;
        Some(PrefixSnapshot { n_tokens, shared_bytes, data: Arc::new(dense) })
    }

    fn adopt_prefix(&mut self, snap: &PrefixSnapshot) -> bool {
        if self.cache.len != 0 {
            return false;
        }
        let Some(d) = snap.data.downcast_ref::<DensePrefixData>() else {
            return false;
        };
        if !self.cache.adopt(snap.n_tokens, d) {
            return false;
        }
        self.traffic = d.traffic;
        true
    }

    fn shared_prefix_bytes(&self) -> usize {
        self.cache.shared_bytes()
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.scratch.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        // Live set after eviction: sink + recent window.
        let live = (self.sink + self.recent).min(self.cache.len);
        live * self.cache.bytes_per_token()
    }

    fn footprint(&self) -> FootprintModel {
        // Bounded cache: dense rate up to the sink+recent window, then
        // flat — footprint is independent of prompt length (Table 1's
        // "low data movement" is also low *capacity* cost). Models the
        // method's post-eviction live set, consistent with kv_bytes();
        // this CPU reference keeps the dense rows resident (see append),
        // so like kv_bytes this is the method's claim, not this process's
        // RSS — flagged in the attention/mod.rs footprint contract.
        FootprintModel {
            fixed_bytes: 0,
            bytes_per_token: self.cache.bytes_per_token(),
            cap_tokens: Some(self.sink + self.recent),
        }
    }

    fn name(&self) -> &'static str {
        "streaming_llm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ignores_middle_tokens() {
        let shape = AttnShape::mha(1, 8, 128);
        let mut b = StreamingLlmAttention::new(shape, 2, 4);
        let mut rng = Rng::new(85);
        // Put a huge-magnitude value in the middle; it must not leak into out.
        for i in 0..50 {
            let k = rng.normal_vec(8, 1.0);
            let v = if i == 25 { vec![1000.0; 8] } else { rng.normal_vec(8, 1.0) };
            b.append(&k, &v);
        }
        let q = rng.normal_vec(8, 1.0);
        let mut out = vec![0.0; 8];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.abs() < 100.0), "middle token leaked: {out:?}");
    }

    #[test]
    fn batched_prefill_matches_sequential_exactly() {
        let shape = AttnShape::mha(2, 8, 128);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(89);
        let mut seq = StreamingLlmAttention::new(shape, 2, 4);
        let mut bat = StreamingLlmAttention::new(shape, 2, 4);
        let n = 30;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut o_seq = vec![0.0f32; n * qd];
        for t in 0..n {
            seq.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            seq.attend(&qs[t * qd..(t + 1) * qd], &mut o_seq[t * qd..(t + 1) * qd]);
        }
        let mut o_bat = vec![0.0f32; n * qd];
        bat.forward_batch(&ks, &vs, &qs, n, &mut o_bat);
        // The first sink+recent rows take the blocked kernel (reassociated
        // arithmetic, ~1e-5 drift); the sliding-window rows share the exact
        // per-position path, so they stay bit-identical.
        let window = 2 + 4;
        for (i, (a, b)) in o_seq.iter().zip(&o_bat).enumerate() {
            assert!((a - b).abs() < 1e-4, "row {}: {a} vs {b}", i / qd);
        }
        assert_eq!(o_seq[window * qd..], o_bat[window * qd..]);
        // Canonical metering is path-independent: blocked dense rows charge
        // exactly what their full-prefix gathers would have.
        assert_eq!(seq.traffic().read, bat.traffic().read);
    }

    #[test]
    fn dense_window_prefill_matches_full_attention() {
        // A chunk entirely inside sink+recent sees every token — the
        // blocked fast path must agree with dense full attention.
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(91);
        let n = 24;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut sllm = StreamingLlmAttention::new(shape, 8, 32);
        let mut full = crate::attention::FullAttention::new(shape);
        let mut o_s = vec![0.0f32; n * qd];
        let mut o_f = vec![0.0f32; n * qd];
        sllm.forward_batch(&ks, &vs, &qs, n, &mut o_s);
        full.forward_batch(&ks, &vs, &qs, n, &mut o_f);
        assert_eq!(o_s, o_f, "full-window rows must run the same blocked kernel");
        sllm.end_prefill();
        // Decode after prefill still works on the per-position path.
        let q = rng.normal_vec(qd, 1.0);
        let mut out = vec![0.0f32; qd];
        sllm.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn kv_bytes_bounded_by_window() {
        let shape = AttnShape::mha(1, 8, 512);
        let mut b = StreamingLlmAttention::new(shape, 4, 16);
        let mut rng = Rng::new(87);
        for _ in 0..400 {
            let k = rng.normal_vec(8, 1.0);
            let v = rng.normal_vec(8, 1.0);
            b.append(&k, &v);
        }
        assert_eq!(b.kv_bytes(), 20 * 2 * 8 * 4);
    }
}

//! Palu baseline (Chang et al., 2024): pure low-rank KV-cache compression.
//!
//! Keys AND values are stored as rank-r latents (pre-RoPE for keys, per the
//! accuracy-preserving choice Palu and §3.1 agree on). At every decode step
//! the **entire** key cache must be reconstructed and re-rotated before
//! dense attention — the overhead Figure 1(a) plots and the reason Table 1
//! charges Palu with "High" computation. Optional latent quantization
//! mirrors Palu's 3-bit variant (we use the nearest supported width).

use crate::attention::{AttentionBackend, AttnShape, FootprintModel, Traffic};
use crate::lowrank::Projector;
use crate::quant::{dequantize_group, quantize_group, Bits, QuantGroup};
use crate::rope::RopeTable;
use crate::tensor::ops::{sparse_attend_threaded, SparseAttendScratch};
use crate::util::threadpool::Workers;

pub struct PaluAttention {
    shape: AttnShape,
    rope: RopeTable,
    k_proj: Projector,
    v_proj: Projector,
    rank: usize,
    /// Latent caches, optionally quantized per token row.
    k_latents: Vec<f32>,
    v_latents: Vec<f32>,
    k_quant: Vec<QuantGroup>,
    v_quant: Vec<QuantGroup>,
    quant_bits: Option<Bits>,
    len: usize,
    traffic: Traffic,
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    scratch_qr: Vec<f32>,
    scratch_lat: Vec<f32>,
    scratch_attend: SparseAttendScratch,
    /// Worker handle for the per-KV-head attend fan-out; default serial.
    workers: Workers,
}

impl PaluAttention {
    /// `k_proj`/`v_proj` are calibrated on pre-RoPE keys / values
    /// respectively. `quant_bits` adds Palu's latent quantization.
    pub fn new(
        shape: AttnShape,
        k_proj: Projector,
        v_proj: Projector,
        rank: usize,
        quant_bits: Option<Bits>,
    ) -> PaluAttention {
        assert_eq!(k_proj.dim, shape.kv_dim());
        assert_eq!(v_proj.dim, shape.kv_dim());
        assert!(rank <= k_proj.rank && rank <= v_proj.rank);
        PaluAttention {
            shape,
            rope: RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base),
            k_proj,
            v_proj,
            rank,
            k_latents: Vec::new(),
            v_latents: Vec::new(),
            k_quant: Vec::new(),
            v_quant: Vec::new(),
            quant_bits,
            len: 0,
            traffic: Traffic::default(),
            scratch_k: Vec::new(),
            scratch_v: Vec::new(),
            scratch_qr: Vec::new(),
            scratch_lat: Vec::new(),
            scratch_attend: SparseAttendScratch::default(),
            workers: Workers::serial(),
        }
    }

    fn latent_row(&self, quant: &[QuantGroup], latents: &[f32], j: usize, out: &mut [f32]) {
        if self.quant_bits.is_some() {
            dequantize_group(&quant[j], out);
        } else {
            out.copy_from_slice(&latents[j * self.rank..(j + 1) * self.rank]);
        }
    }

    fn latent_row_bytes(&self) -> usize {
        match self.quant_bits {
            Some(b) => self.rank * b.bits() as usize / 8 + 8,
            None => self.rank * 4,
        }
    }
}

impl AttentionBackend for PaluAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let r = self.rank;
        let mut lat = std::mem::take(&mut self.scratch_lat);
        lat.resize(2 * r, 0.0);
        let (klat, vlat) = lat.split_at_mut(r);
        self.k_proj.project(k, klat);
        self.v_proj.project(v, vlat);
        if let Some(bits) = self.quant_bits {
            self.k_quant.push(quantize_group(klat, bits));
            self.v_quant.push(quantize_group(vlat, bits));
        } else {
            self.k_latents.extend_from_slice(klat);
            self.v_latents.extend_from_slice(vlat);
        }
        self.scratch_lat = lat;
        self.traffic.write_bytes(2 * self.latent_row_bytes());
        self.len += 1;
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.len > 0);
        let kvd = self.shape.kv_dim();
        let r = self.rank;
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.scratch_qr, self.len - 1);

        // FULL reconstruction of the key and value caches — the Figure-1(a)
        // overhead: O(s·r·kv_dim) work and O(s·r) cache traffic per step.
        self.scratch_k.resize(self.len * kvd, 0.0);
        self.scratch_v.resize(self.len * kvd, 0.0);
        let mut lat = std::mem::take(&mut self.scratch_lat);
        lat.resize(2 * r, 0.0);
        for j in 0..self.len {
            self.latent_row(&self.k_quant, &self.k_latents, j, &mut lat[..r]);
            self.k_proj.reconstruct(&lat[..r], &mut self.scratch_k[j * kvd..(j + 1) * kvd]);
            self.rope.apply_multihead(&mut self.scratch_k[j * kvd..(j + 1) * kvd], j);
            self.latent_row(&self.v_quant, &self.v_latents, j, &mut lat[..r]);
            self.v_proj.reconstruct(&lat[..r], &mut self.scratch_v[j * kvd..(j + 1) * kvd]);
            self.traffic.read_bytes(2 * self.latent_row_bytes());
        }
        self.scratch_lat = lat;
        sparse_attend_threaded(
            &self.scratch_qr,
            &self.scratch_k,
            &self.scratch_v,
            self.len,
            self.shape.n_heads,
            self.shape.n_kv_heads,
            self.shape.head_dim,
            &self.workers,
            &mut self.scratch_attend,
            out,
        );
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        if self.quant_bits.is_some() {
            self.k_quant.iter().chain(&self.v_quant).map(|g| g.packed.len() + 8).sum()
        } else {
            (self.k_latents.len() + self.v_latents.len()) * 4
        }
    }

    fn footprint(&self) -> FootprintModel {
        // Pure low-rank: one K latent + one V latent row per token
        // (optionally quantized), nothing fixed.
        FootprintModel::linear(0, 2 * self.latent_row_bytes())
    }

    fn name(&self) -> &'static str {
        "palu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::lowrank::Calibrator;
    use crate::util::rng::Rng;

    fn projector_for(kv_dim: usize, rank: usize, true_rank: usize, seed: u64) -> Projector {
        let mut rng = Rng::new(seed);
        let basis: Vec<Vec<f32>> = (0..true_rank).map(|_| rng.normal_vec(kv_dim, 1.0)).collect();
        let mut cal = Calibrator::new(kv_dim);
        let mut row = vec![0.0f32; kv_dim];
        for _ in 0..400 {
            row.fill(0.0);
            for b in &basis {
                crate::tensor::ops::axpy(rng.normal_f32(), b, &mut row);
            }
            cal.add_key(&row);
        }
        cal.fit(rank).unwrap()
    }

    #[test]
    fn full_rank_palu_matches_full_attention() {
        let shape = AttnShape::mha(2, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(121);
        let kp = projector_for(kvd, kvd, kvd, 122);
        let vp = projector_for(kvd, kvd, kvd, 123);
        let mut palu = PaluAttention::new(shape, kp, vp, kvd, None);
        let mut full = FullAttention::new(shape);
        for _ in 0..30 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            palu.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let (mut o1, mut o2) = (vec![0.0; shape.q_dim()], vec![0.0; shape.q_dim()]);
        palu.attend(&q, &mut o1);
        full.attend(&q, &mut o2);
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn memory_small_but_traffic_grows_with_rank_times_len() {
        let shape = AttnShape::mha(2, 16, 256);
        let kvd = shape.kv_dim();
        let kp = projector_for(kvd, kvd / 4, 6, 125);
        let vp = projector_for(kvd, kvd / 4, 6, 126);
        let mut palu = PaluAttention::new(shape, kp, vp, kvd / 4, None);
        let mut rng = Rng::new(127);
        for _ in 0..100 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            palu.append(&k, &v);
        }
        // Cache is 4× smaller than dense fp32.
        assert_eq!(palu.kv_bytes(), 100 * 2 * (kvd / 4) * 4);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0; shape.q_dim()];
        let t0 = palu.traffic();
        palu.attend(&q, &mut out);
        // Per-step read = 2 * len * r floats.
        assert_eq!(palu.traffic().read - t0.read, (2 * 100 * (kvd / 4) * 4) as u64);
    }

    #[test]
    fn quantized_variant_roundtrips() {
        let shape = AttnShape::mha(1, 8, 64);
        let kvd = shape.kv_dim();
        let kp = projector_for(kvd, 4, 3, 129);
        let vp = projector_for(kvd, 4, 3, 130);
        let mut palu = PaluAttention::new(shape, kp, vp, 4, Some(Bits::B4));
        let mut rng = Rng::new(131);
        for _ in 0..20 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            palu.append(&k, &v);
        }
        let q = rng.normal_vec(kvd, 1.0);
        let mut out = vec![0.0; kvd];
        palu.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        // Quantized latent cache is ~8× smaller than fp32 latents.
        assert!(palu.kv_bytes() < 20 * 2 * 4 * 4);
    }
}

//! Quest baseline (Tang et al., 2024): query-aware page-level sparsity.
//!
//! The cache is organized in fixed-size pages; each page keeps per-channel
//! min/max metadata of its (post-RoPE) keys. At decode, every page gets an
//! upper-bound score Σ_c max(q_c·min_c, q_c·max_c); the top pages within the
//! token budget are selected and *all* their tokens attend exactly.

use crate::attention::baselines::common::{
    dense_prefix_rows, pool_query, BaselineScratch, DenseCache,
};
use crate::attention::full::DensePrefixData;
use crate::attention::{
    merge_selection_into, AttentionBackend, AttnShape, FootprintModel, PrefixSnapshot, Traffic,
};
use crate::tensor::ops::sparse_attend_threaded;
use crate::tensor::top_k_indices_into;
use crate::util::threadpool::Workers;
use std::sync::Arc;

/// Quest's [`PrefixSnapshot`] payload: the dense rows plus the per-page
/// min/max metadata at fork time. The metadata is copied per adopter (the
/// final partial page's bounds keep folding as private tokens append).
struct QuestPrefixData {
    dense: DensePrefixData,
    page_min: Vec<f32>,
    page_max: Vec<f32>,
}

pub struct QuestAttention {
    cache: DenseCache,
    page: usize,
    /// Per page: (kv_dim mins, kv_dim maxs), contiguous.
    page_min: Vec<f32>,
    page_max: Vec<f32>,
    sink: usize,
    recent: usize,
    /// Token budget for selected pages.
    budget: usize,
    traffic: Traffic,
    scratch: BaselineScratch,
}

impl QuestAttention {
    pub fn new(shape: AttnShape, page: usize, sink: usize, recent: usize, budget: usize) -> QuestAttention {
        assert!(page > 0);
        QuestAttention {
            cache: DenseCache::new(shape),
            page,
            page_min: Vec::new(),
            page_max: Vec::new(),
            sink,
            recent,
            budget,
            traffic: Traffic::default(),
            scratch: BaselineScratch::default(),
        }
    }

    /// Fold one post-RoPE key row (already resident in the cache at
    /// `pos`) into its page's min/max metadata.
    fn update_page_meta(&mut self, pos: usize) {
        let kvd = self.cache.shape.kv_dim();
        let rot = self.cache.keys.row(pos * kvd, kvd);
        if pos % self.page == 0 {
            // New page.
            self.page_min.extend_from_slice(rot);
            self.page_max.extend_from_slice(rot);
        } else {
            let p = pos / self.page;
            for c in 0..kvd {
                let lo = &mut self.page_min[p * kvd + c];
                *lo = lo.min(rot[c]);
                let hi = &mut self.page_max[p * kvd + c];
                *hi = hi.max(rot[c]);
            }
        }
        self.traffic.write_f32(2 * kvd);
    }

    /// Attend for the query at absolute position `pos` (visible prefix
    /// `0..=pos`). Page min/max bounds stay valid upper bounds for any
    /// visible subset of a page, so causal page scoring just clips the
    /// final page's token range to the prefix. (After a batched append the
    /// last page's metadata may include chunk rows a mid-chunk query can't
    /// see — the bound is looser than the sequential one but still sound,
    /// so selection can differ slightly from token-at-a-time execution.)
    fn attend_at(&mut self, q: &[f32], pos: usize, out: &mut [f32]) {
        let vis = pos + 1;
        let shape = self.cache.shape;
        let kvd = shape.kv_dim();
        self.cache.rotate_query_into(q, pos, &mut self.scratch.qr);
        // Pooled rotated query (kv_dim) for page scoring.
        pool_query(&shape, &self.scratch.qr, &mut self.scratch.pooled);
        // Upper-bound scores over the pages intersecting the prefix.
        let np = vis.div_ceil(self.page);
        self.scratch.scores.clear();
        self.scratch.scores.reserve(np);
        for p in 0..np {
            let mut s = 0.0f32;
            for c in 0..kvd {
                let qv = self.scratch.pooled[c];
                s += (qv * self.page_min[p * kvd + c]).max(qv * self.page_max[p * kvd + c]);
            }
            self.scratch.scores.push(s);
        }
        self.traffic.read_f32(2 * np * kvd);
        // Select top pages within the token budget, expand to tokens.
        let pages_allowed = (self.budget / self.page).max(1);
        top_k_indices_into(&self.scratch.scores, pages_allowed, &mut self.scratch.idx);
        self.scratch.crit.clear();
        for &p in &self.scratch.idx {
            let lo = p * self.page;
            let hi = ((p + 1) * self.page).min(vis);
            self.scratch.crit.extend(lo..hi);
        }
        merge_selection_into(
            vis,
            self.sink,
            self.recent,
            &self.scratch.crit,
            &mut self.scratch.crit_sorted,
            &mut self.scratch.sel,
        );
        self.cache.gather_into(
            &self.scratch.sel,
            &mut self.scratch.keys,
            &mut self.scratch.vals,
            &mut self.traffic,
        );
        sparse_attend_threaded(
            &self.scratch.qr,
            &self.scratch.keys,
            &self.scratch.vals,
            self.scratch.sel.len(),
            shape.n_heads,
            shape.n_kv_heads,
            shape.head_dim,
            &self.scratch.workers,
            &mut self.scratch.attend,
            out,
        );
    }
}

impl AttentionBackend for QuestAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v, &mut self.traffic);
        self.update_page_meta(self.cache.len - 1);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let pos = self.cache.len - 1;
        self.attend_at(q, pos, out);
    }

    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        let start = self.cache.len;
        self.cache.append_batch(ks, vs, n, &mut self.traffic);
        for pos in start..start + n {
            self.update_page_meta(pos);
        }
    }

    fn prefill_attend(&mut self, qs: &[f32], n: usize, out: &mut [f32]) {
        let qd = self.cache.shape.q_dim();
        let len = self.cache.len;
        // Rows whose prefix fits in sink+recent select everything no
        // matter how the pages score — skip the per-row page scan and run
        // them through the blocked kernel in one call. Later rows keep the
        // per-position loop: page top-k genuinely differs per query.
        let start = len - n;
        let n_dense = dense_prefix_rows(start, n, self.sink + self.recent);
        if n_dense > 0 {
            self.cache.prefill_attend_dense_rows(
                qs,
                n,
                n_dense,
                &mut self.scratch.qrows,
                &mut self.scratch.chunk,
                &mut out[..n_dense * qd],
                &mut self.traffic,
            );
        }
        if n_dense < n {
            DenseCache::prefill_attend_rows(
                len,
                qd,
                &qs[n_dense * qd..],
                n - n_dense,
                &mut out[n_dense * qd..],
                |q, pos, o| self.attend_at(q, pos, o),
            );
        }
    }

    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        self.append_batch(ks, vs, n);
        self.prefill_attend(qs, n, out);
    }

    fn end_prefill(&mut self) {
        self.scratch.end_prefill();
    }

    fn fork_prefix(&self, n_tokens: usize) -> Option<PrefixSnapshot> {
        if n_tokens == 0 || n_tokens != self.cache.len {
            return None;
        }
        let dense = self.cache.snapshot(self.traffic);
        let shared_bytes = (dense.keys.len() + dense.values.len()) * 4;
        Some(PrefixSnapshot {
            n_tokens,
            shared_bytes,
            data: Arc::new(QuestPrefixData {
                dense,
                page_min: self.page_min.clone(),
                page_max: self.page_max.clone(),
            }),
        })
    }

    fn adopt_prefix(&mut self, snap: &PrefixSnapshot) -> bool {
        if self.cache.len != 0 {
            return false;
        }
        let Some(d) = snap.data.downcast_ref::<QuestPrefixData>() else {
            return false;
        };
        if !self.cache.adopt(snap.n_tokens, &d.dense) {
            return false;
        }
        self.page_min = d.page_min.clone();
        self.page_max = d.page_max.clone();
        self.traffic = d.dense.traffic;
        true
    }

    fn shared_prefix_bytes(&self) -> usize {
        self.cache.shared_bytes()
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.scratch.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        // Dense cache + page metadata (Table 1: memory "High").
        self.cache.kv_bytes() + (self.page_min.len() + self.page_max.len()) * 4
    }

    fn footprint(&self) -> FootprintModel {
        // Dense rate plus per-page min/max metadata (2·kv_dim fp32 per
        // page) amortized per token, rounded up.
        let meta = (2 * self.cache.shape.kv_dim() * 4).div_ceil(self.page);
        FootprintModel::linear(0, self.cache.bytes_per_token() + meta)
    }

    fn name(&self) -> &'static str {
        "quest"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn page_metadata_bounds_hold() {
        let shape = AttnShape::mha(1, 8, 128);
        let mut rng = Rng::new(101);
        let mut b = QuestAttention::new(shape, 4, 0, 0, 8);
        for _ in 0..20 {
            let k = rng.normal_vec(8, 1.0);
            b.append(&k, &k.clone());
        }
        let kvd = 8;
        let keys = b.cache.keys.to_vec();
        for (pos, row) in keys.chunks_exact(kvd).enumerate() {
            let p = pos / 4;
            for c in 0..kvd {
                assert!(b.page_min[p * kvd + c] <= row[c] + 1e-6);
                assert!(b.page_max[p * kvd + c] >= row[c] - 1e-6);
            }
        }
    }

    #[test]
    fn selects_page_with_matching_key() {
        // One page contains keys aligned with the query: its upper bound
        // must rank it first.
        let shape = AttnShape::mha(1, 4, 256);
        let mut b = QuestAttention::new(shape, 4, 0, 0, 4);
        let mut rng = Rng::new(103);
        for i in 0..32 {
            let k = if (8..12).contains(&i) {
                vec![5.0f32, 5.0, 5.0, 5.0]
            } else {
                rng.normal_vec(4, 0.1)
            };
            b.append(&k, &k.clone());
        }
        let q = vec![1.0f32; 4];
        let mut out = vec![0.0; 4];
        b.attend(&q, &mut out);
        // Output should be dominated by the big-key page's values (~5 before
        // rotation mixes dims; check it is far from the small-noise scale).
        assert!(out.iter().map(|x| x.abs()).fold(0.0f32, f32::max) > 1.0, "{out:?}");
    }

    #[test]
    fn batched_append_preserves_page_bounds() {
        let shape = AttnShape::mha(1, 8, 128);
        let mut rng = Rng::new(107);
        let kvd = 8;
        let n = 26; // not page-aligned: last page is partial
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let mut a = QuestAttention::new(shape, 4, 0, 0, 8);
        let mut b = QuestAttention::new(shape, 4, 0, 0, 8);
        a.append_batch(&ks, &vs, n);
        for t in 0..n {
            b.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
        assert_eq!(a.cache.len, b.cache.len);
        assert_eq!(a.cache.keys, b.cache.keys);
        assert_eq!(a.page_min, b.page_min);
        assert_eq!(a.page_max, b.page_max);
        assert_eq!(a.traffic().written, b.traffic().written);
    }

    #[test]
    fn fork_adopt_decode_bit_identical_to_cold() {
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let (kvd, qd) = (shape.kv_dim(), shape.q_dim());
        let mut rng = Rng::new(113);
        let mut donor = QuestAttention::new(shape, 4, 2, 4, 8);
        let mut cold = QuestAttention::new(shape, 4, 2, 4, 8);
        // 26 tokens: the last page is partial, so its min/max metadata
        // keeps folding as private appends land after adoption.
        for _ in 0..26 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            donor.append(&k, &v);
            cold.append(&k, &v);
        }
        let snap = donor.fork_prefix(donor.len()).expect("quest fork");
        let mut adopted = QuestAttention::new(shape, 4, 2, 4, 8);
        assert!(adopted.adopt_prefix(&snap));
        assert_eq!(adopted.kv_bytes(), cold.kv_bytes());
        assert_eq!(adopted.traffic(), cold.traffic());
        assert!(adopted.shared_prefix_bytes() > 0);
        for _ in 0..7 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            let q = rng.normal_vec(qd, 1.0);
            let (mut oa, mut oc) = (vec![0.0f32; qd], vec![0.0f32; qd]);
            adopted.append(&k, &v);
            cold.append(&k, &v);
            adopted.attend(&q, &mut oa);
            cold.attend(&q, &mut oc);
            assert_eq!(oa, oc);
        }
        assert_eq!(adopted.page_min, cold.page_min);
        assert_eq!(adopted.page_max, cold.page_max);
        // Donor metadata is untouched by adopter appends.
        assert_eq!(donor.len(), 26);
    }

    #[test]
    fn batched_prefill_is_causal() {
        // A huge-magnitude KEY/VALUE planted late in the chunk must not
        // influence the outputs of earlier chunk positions.
        let shape = AttnShape::mha(1, 4, 128);
        let kvd = 4;
        let mut rng = Rng::new(109);
        let n = 20;
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for i in 0..n {
            ks.extend(rng.normal_vec(kvd, 0.5));
            vs.extend(if i == n - 1 { vec![1000.0f32; kvd] } else { rng.normal_vec(kvd, 0.5) });
        }
        let qs = rng.normal_vec(n * kvd, 1.0);
        let mut b = QuestAttention::new(shape, 4, 1, 2, 8);
        let mut out = vec![0.0f32; n * kvd];
        b.forward_batch(&ks, &vs, &qs, n, &mut out);
        for t in 0..n - 1 {
            for &x in &out[t * kvd..(t + 1) * kvd] {
                assert!(x.abs() < 100.0, "future value leaked into position {t}: {x}");
            }
        }
    }

    #[test]
    fn dense_window_rows_match_per_position_path() {
        // A chunk entirely inside sink+recent selects the full prefix on
        // every row: the blocked fast path must agree with the sequential
        // per-position selection/gather/attend loop (≤1e-4: the blocked
        // kernel reassociates the softmax arithmetic).
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(111);
        let n = 14; // < sink + recent = 20
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut seq = QuestAttention::new(shape, 4, 4, 16, 8);
        let mut bat = QuestAttention::new(shape, 4, 4, 16, 8);
        let mut o_seq = vec![0.0f32; n * qd];
        for t in 0..n {
            seq.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            seq.attend(&qs[t * qd..(t + 1) * qd], &mut o_seq[t * qd..(t + 1) * qd]);
        }
        let mut o_bat = vec![0.0f32; n * qd];
        bat.forward_batch(&ks, &vs, &qs, n, &mut o_bat);
        for (a, b) in o_seq.iter().zip(&o_bat) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        bat.end_prefill();
    }

    #[test]
    fn attends_finite_gqa() {
        let shape = AttnShape::gqa(4, 2, 8, 64);
        let mut rng = Rng::new(105);
        let mut b = QuestAttention::new(shape, 8, 2, 4, 16);
        for _ in 0..40 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            b.append(&k, &v);
        }
        let q = rng.normal_vec(32, 1.0);
        let mut out = vec![0.0; 32];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}

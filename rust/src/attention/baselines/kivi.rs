//! KIVI baseline (Liu et al., 2024): tuning-free asymmetric KV-cache
//! quantization — keys per-channel, values per-token, with a full-precision
//! recent window. Attention itself stays dense (every token participates),
//! so accuracy is high but traffic scales with the full sequence.

use crate::attention::{AttentionBackend, AttnShape, FootprintModel, Traffic};
use crate::quant::{Bits, TokenQuantStore};
use crate::rope::RopeTable;
use crate::tensor::ops::{sparse_attend_pv, SparseAttendScratch};
use crate::util::threadpool::Workers;

pub struct KiviAttention {
    shape: AttnShape,
    rope: RopeTable,
    /// Post-RoPE keys, per-channel group quantized (KIVI's key mode).
    keys: TokenQuantStore,
    /// Values, quantized per token group (same packed store, per-channel
    /// grouping is the closest shared representation; KIVI's per-token mode
    /// differs only in grouping axis — both are asymmetric affine).
    values: TokenQuantStore,
    len: usize,
    traffic: Traffic,
    scratch_k: Vec<f32>,
    scratch_kr: Vec<f32>,
    scratch_qr: Vec<f32>,
    scratch_attend: SparseAttendScratch,
    /// Worker handle for the per-KV-head attend fan-out; default serial.
    workers: Workers,
}

impl KiviAttention {
    pub fn new(shape: AttnShape, bits: Bits, group: usize, window: usize) -> KiviAttention {
        let kvd = shape.kv_dim();
        KiviAttention {
            shape,
            rope: RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base),
            keys: TokenQuantStore::new(kvd, bits, group, window),
            values: TokenQuantStore::new(kvd, bits, group, window),
            len: 0,
            traffic: Traffic::default(),
            scratch_k: Vec::new(),
            scratch_kr: Vec::new(),
            scratch_qr: Vec::new(),
            scratch_attend: SparseAttendScratch::default(),
            workers: Workers::serial(),
        }
    }
}

impl AttentionBackend for KiviAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.scratch_kr.clear();
        self.scratch_kr.extend_from_slice(k);
        self.rope.apply_multihead(&mut self.scratch_kr, self.len);
        self.keys.append(&self.scratch_kr);
        self.values.append(v);
        self.len += 1;
        self.traffic.write_bytes(self.keys.row_read_bytes(self.len - 1));
        self.traffic.write_bytes(self.values.row_read_bytes(self.len - 1));
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.len > 0);
        let kvd = self.shape.kv_dim();
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.scratch_qr, self.len - 1);
        // Keys dequantize densely (every token scores); values stream
        // straight from their quantized pages inside the PV stage via the
        // fused dequant-GEMV — no fp32 value panel is ever staged. Both
        // meters charge the quantized bytes the stream actually moves (the
        // bandwidth saving KIVI delivers) and are unchanged by the fusion:
        // `read_all_bytes` describes what is *streamed*, not staged.
        self.scratch_k.resize(self.len * kvd, 0.0);
        self.keys.read_all(&mut self.scratch_k);
        self.traffic.read_bytes(self.keys.read_all_bytes());
        self.traffic.read_bytes(self.values.read_all_bytes());
        let d = self.shape.head_dim;
        let group = self.shape.group_size();
        let values = &self.values;
        let pv = |kvh: usize, scores: &[f32], staging: &mut Vec<f32>, ohead: &mut [f32]| {
            ohead.fill(0.0);
            values.dequant_matmul_acc_all(kvh * d, (kvh + 1) * d, scores, group, staging, ohead);
        };
        sparse_attend_pv(
            &self.scratch_qr,
            &self.scratch_k,
            self.len,
            self.shape.n_heads,
            self.shape.n_kv_heads,
            d,
            &self.workers,
            pv,
            &mut self.scratch_attend,
            out,
        );
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        self.keys.nbytes() + self.values.nbytes()
    }

    fn footprint(&self) -> FootprintModel {
        // Two quantized stores (K and V): each grows at its frozen rate,
        // each carries a fixed fp32-window excess.
        FootprintModel::linear(
            self.keys.tail_excess_bytes() + self.values.tail_excess_bytes(),
            self.keys.frozen_row_bytes() + self.values.frozen_row_bytes(),
        )
    }

    fn name(&self) -> &'static str {
        "kivi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::util::rng::Rng;

    #[test]
    fn kivi4_close_to_full() {
        let shape = AttnShape::mha(2, 8, 128);
        let mut rng = Rng::new(113);
        let mut kivi = KiviAttention::new(shape, Bits::B4, 16, 16);
        let mut full = FullAttention::new(shape);
        for _ in 0..80 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            kivi.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(16, 1.0);
        let mut o1 = vec![0.0; 16];
        let mut o2 = vec![0.0; 16];
        kivi.attend(&q, &mut o1);
        full.attend(&q, &mut o2);
        let err = crate::util::stats::rel_l2(&o1, &o2);
        assert!(err < 0.15, "rel err {err}");
    }

    #[test]
    fn kivi2_worse_than_kivi4() {
        let shape = AttnShape::mha(2, 8, 128);
        let mut rng = Rng::new(115);
        let mut k4 = KiviAttention::new(shape, Bits::B4, 16, 8);
        let mut k2 = KiviAttention::new(shape, Bits::B2, 16, 8);
        let mut full = FullAttention::new(shape);
        for _ in 0..80 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            k4.append(&k, &v);
            k2.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(16, 1.0);
        let (mut o4, mut o2, mut of) = (vec![0.0; 16], vec![0.0; 16], vec![0.0; 16]);
        k4.attend(&q, &mut o4);
        k2.attend(&q, &mut o2);
        full.attend(&q, &mut of);
        let e4 = crate::util::stats::rel_l2(&o4, &of);
        let e2 = crate::util::stats::rel_l2(&o2, &of);
        assert!(e4 < e2, "e4={e4} e2={e2}");
    }

    #[test]
    fn cache_smaller_than_fp32() {
        let shape = AttnShape::mha(2, 8, 512);
        let mut rng = Rng::new(117);
        let mut kivi = KiviAttention::new(shape, Bits::B2, 32, 32);
        for _ in 0..400 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            kivi.append(&k, &v);
        }
        let fp32 = 400 * 2 * 16 * 4;
        assert!(kivi.kv_bytes() < fp32 / 3, "{} vs {fp32}", kivi.kv_bytes());
    }
}

//! Double Sparsity baseline (Yang et al., 2024): token sparsity guided by
//! **important channels** selected offline.
//!
//! DS picks, per layer, the channels of the (post-RoPE) key space with the
//! largest calibration magnitude; decode-time approximate scores use only
//! those channels ("label cache"), then exact attention runs on the top-k
//! tokens from the full-precision cache. Like Loki/HShare it reduces
//! traffic, not resident memory.

use crate::attention::baselines::common::{pool_query, BaselineScratch, DenseCache};
use crate::attention::{
    merge_selection_into, AttentionBackend, AttnShape, FootprintModel, Traffic,
};
use crate::tensor::ops::sparse_attend_threaded;
use crate::tensor::{top_k_indices, top_k_indices_into};
use crate::util::threadpool::Workers;

pub struct DoubleSparseAttention {
    cache: DenseCache,
    /// Offline-selected important channel indices (into kv_dim).
    channels: Vec<usize>,
    /// (len, channels.len()) label cache: selected channels of rotated
    /// keys — contiguous rows, so scoring is a unit-stride matmul_tn.
    labels: Vec<f32>,
    sink: usize,
    recent: usize,
    critical: usize,
    traffic: Traffic,
    scratch: BaselineScratch,
}

impl DoubleSparseAttention {
    pub fn new(
        shape: AttnShape,
        channels: Vec<usize>,
        sink: usize,
        recent: usize,
        critical: usize,
    ) -> DoubleSparseAttention {
        assert!(!channels.is_empty());
        assert!(channels.iter().all(|&c| c < shape.kv_dim()));
        DoubleSparseAttention {
            cache: DenseCache::new(shape),
            channels,
            labels: Vec::new(),
            sink,
            recent,
            critical,
            traffic: Traffic::default(),
            scratch: BaselineScratch::default(),
        }
    }

    /// Offline channel selection: top-`n_channels` by mean |k_c| over a
    /// calibration batch of **post-RoPE** keys ((n, kv_dim) row-major).
    pub fn select_channels(calib_keys: &[f32], kv_dim: usize, n_channels: usize) -> Vec<usize> {
        assert_eq!(calib_keys.len() % kv_dim, 0);
        let n = calib_keys.len() / kv_dim;
        let mut mag = vec![0.0f64; kv_dim];
        for row in calib_keys.chunks_exact(kv_dim) {
            for (c, &x) in row.iter().enumerate() {
                mag[c] += x.abs() as f64;
            }
        }
        let _ = n;
        let mag32: Vec<f32> = mag.iter().map(|&x| x as f32).collect();
        let mut idx = top_k_indices(&mag32, n_channels);
        idx.sort_unstable();
        idx
    }
}

impl AttentionBackend for DoubleSparseAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v, &mut self.traffic);
        let kvd = self.cache.shape.kv_dim();
        let rot = self.cache.keys.row((self.cache.len - 1) * kvd, kvd);
        for &c in &self.channels {
            self.labels.push(rot[c]);
        }
        self.traffic.write_f32(self.channels.len());
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let shape = self.cache.shape;
        let len = self.cache.len;
        self.cache.rotate_query_into(q, len - 1, &mut self.scratch.qr);
        // Pool rotated query heads to kv_dim, pick the important channels.
        pool_query(&shape, &self.scratch.qr, &mut self.scratch.pooled);
        self.scratch.lat.clear();
        for &c in &self.channels {
            self.scratch.lat.push(self.scratch.pooled[c]);
        }
        let nc = self.channels.len();
        // Label-cache scoring: one unit-stride matmul_tn over the
        // contiguous (len, nc) label rows.
        self.scratch.scores.resize(len, 0.0);
        crate::tensor::ops::matmul_tn(
            &self.scratch.lat,
            &self.labels,
            &mut self.scratch.scores,
            1,
            nc,
            len,
        );
        self.traffic.read_f32(len * nc);
        top_k_indices_into(&self.scratch.scores, self.critical, &mut self.scratch.idx);
        merge_selection_into(
            len,
            self.sink,
            self.recent,
            &self.scratch.idx,
            &mut self.scratch.crit_sorted,
            &mut self.scratch.sel,
        );
        self.cache.gather_into(
            &self.scratch.sel,
            &mut self.scratch.keys,
            &mut self.scratch.vals,
            &mut self.traffic,
        );
        sparse_attend_threaded(
            &self.scratch.qr,
            &self.scratch.keys,
            &self.scratch.vals,
            self.scratch.sel.len(),
            shape.n_heads,
            shape.n_kv_heads,
            shape.head_dim,
            &self.scratch.workers,
            &mut self.scratch.attend,
            out,
        );
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.scratch.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        self.cache.kv_bytes() + self.labels.len() * 4
    }

    fn footprint(&self) -> FootprintModel {
        // Dense rate plus the per-token label-cache row (selected channels
        // of the rotated key, fp32).
        FootprintModel::linear(0, self.cache.bytes_per_token() + self.channels.len() * 4)
    }

    fn name(&self) -> &'static str {
        "double_sparse"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn channel_selection_prefers_high_magnitude() {
        let kv_dim = 8;
        // Channel 3 and 6 carry 10× magnitude.
        let mut rng = Rng::new(95);
        let mut keys = Vec::new();
        for _ in 0..100 {
            let mut row = rng.normal_vec(kv_dim, 0.1);
            row[3] += 5.0;
            row[6] -= 5.0;
            keys.extend_from_slice(&row);
        }
        let ch = DoubleSparseAttention::select_channels(&keys, kv_dim, 2);
        assert_eq!(ch, vec![3, 6]);
    }

    #[test]
    fn attends_finite() {
        let shape = AttnShape::mha(2, 8, 128);
        let mut rng = Rng::new(97);
        let mut b = DoubleSparseAttention::new(shape, vec![0, 3, 7, 11], 2, 4, 8);
        for _ in 0..50 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            b.append(&k, &v);
        }
        let q = rng.normal_vec(16, 1.0);
        let mut out = vec![0.0; 16];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn label_cache_grows_with_channels_only() {
        let shape = AttnShape::mha(1, 8, 64);
        let mut rng = Rng::new(99);
        let mut b = DoubleSparseAttention::new(shape, vec![1, 2], 1, 2, 4);
        for _ in 0..10 {
            let k = rng.normal_vec(8, 1.0);
            b.append(&k, &k.clone());
        }
        assert_eq!(b.labels.len(), 10 * 2);
    }
}

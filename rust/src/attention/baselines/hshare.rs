//! HShare baseline (Wu et al., 2025): hierarchical critical-token sharing.
//!
//! HShare amortizes top-k selection by sharing critical-token indices at
//! three granularities: across heads in a KV group, across adjacent layers,
//! and across decode steps (indices are refreshed every `refresh` steps and
//! reused in between). Our per-layer backend implements head-level sharing
//! (scores from the pooled query, like the leader-head scheme) plus
//! step-level reuse; layer-level sharing is wired in the model layer by
//! cloning the previous layer's index set (see `model::sparse_llama`).

use crate::attention::baselines::common::{pool_query, BaselineScratch, DenseCache};
use crate::attention::{
    merge_selection_into, AttentionBackend, AttnShape, FootprintModel, Traffic,
};
use crate::tensor::ops::sparse_attend_threaded;
use crate::tensor::top_k_indices_into;
use crate::util::threadpool::Workers;

pub struct HShareAttention {
    cache: DenseCache,
    sink: usize,
    recent: usize,
    critical: usize,
    /// Re-select critical tokens every `refresh` decode steps.
    refresh: usize,
    steps: usize,
    shared_indices: Vec<usize>,
    traffic: Traffic,
    scratch: BaselineScratch,
}

impl HShareAttention {
    pub fn new(shape: AttnShape, sink: usize, recent: usize, critical: usize, refresh: usize) -> HShareAttention {
        HShareAttention {
            cache: DenseCache::new(shape),
            sink,
            recent,
            critical,
            refresh: refresh.max(1),
            steps: 0,
            shared_indices: Vec::new(),
            traffic: Traffic::default(),
            scratch: BaselineScratch::default(),
        }
    }

    /// Adopt critical indices shared from another layer (layer-level
    /// hierarchy); resets the refresh countdown.
    pub fn share_indices_from(&mut self, indices: &[usize]) {
        self.shared_indices = indices.to_vec();
        self.steps = 1; // counts as freshly selected
    }

    /// Current shared critical indices (for propagating to the next layer).
    pub fn shared_indices(&self) -> &[usize] {
        &self.shared_indices
    }
}

impl AttentionBackend for HShareAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v, &mut self.traffic);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let shape = self.cache.shape;
        let kvd = shape.kv_dim();
        let len = self.cache.len;
        self.cache.rotate_query_into(q, len - 1, &mut self.scratch.qr);

        let needs_refresh = self.steps % self.refresh == 0 || self.shared_indices.is_empty();
        if needs_refresh {
            // Leader scoring: pooled query against full keys (one head-group
            // pass instead of n_heads passes — the head-level sharing); the
            // dense key rows are contiguous, so this is one matmul_tn.
            pool_query(&shape, &self.scratch.qr, &mut self.scratch.pooled);
            self.scratch.scores.resize(len, 0.0);
            // Per-token dots are independent, so scoring the shared and
            // private key segments separately is bit-identical to one
            // contiguous matmul_tn.
            let mut j0 = 0usize;
            for seg in self.cache.keys.segs() {
                let rows = seg.len() / kvd;
                if rows > 0 {
                    crate::tensor::ops::matmul_tn(
                        &self.scratch.pooled,
                        seg,
                        &mut self.scratch.scores[j0..j0 + rows],
                        1,
                        kvd,
                        rows,
                    );
                }
                j0 += rows;
            }
            self.traffic.read_f32(len * kvd);
            top_k_indices_into(&self.scratch.scores, self.critical, &mut self.shared_indices);
        }
        self.steps += 1;

        merge_selection_into(
            len,
            self.sink,
            self.recent,
            &self.shared_indices,
            &mut self.scratch.crit_sorted,
            &mut self.scratch.sel,
        );
        self.cache.gather_into(
            &self.scratch.sel,
            &mut self.scratch.keys,
            &mut self.scratch.vals,
            &mut self.traffic,
        );
        sparse_attend_threaded(
            &self.scratch.qr,
            &self.scratch.keys,
            &self.scratch.vals,
            self.scratch.sel.len(),
            shape.n_heads,
            shape.n_kv_heads,
            shape.head_dim,
            &self.scratch.workers,
            &mut self.scratch.attend,
            out,
        );
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.scratch.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        self.cache.kv_bytes()
    }

    fn footprint(&self) -> FootprintModel {
        // Traffic-sparse, memory-dense: plain dense rate (the shared index
        // set is O(critical), negligible and not metered by kv_bytes).
        FootprintModel::linear(0, self.cache.bytes_per_token())
    }

    fn name(&self) -> &'static str {
        "hshare"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reuses_indices_between_refreshes() {
        let shape = AttnShape::mha(1, 8, 128);
        let mut rng = Rng::new(107);
        let mut b = HShareAttention::new(shape, 1, 2, 4, 4);
        for _ in 0..30 {
            let k = rng.normal_vec(8, 1.0);
            b.append(&k, &k.clone());
        }
        let q = rng.normal_vec(8, 1.0);
        let mut out = vec![0.0; 8];
        b.attend(&q, &mut out);
        let first = b.shared_indices().to_vec();
        // Next step with a different query but before refresh: same indices.
        let q2 = rng.normal_vec(8, 1.0);
        b.append(&rng.normal_vec(8, 1.0), &rng.normal_vec(8, 1.0));
        b.attend(&q2, &mut out);
        assert_eq!(b.shared_indices(), first.as_slice());
    }

    #[test]
    fn refresh_recomputes() {
        let shape = AttnShape::mha(1, 8, 256);
        let mut rng = Rng::new(109);
        let mut b = HShareAttention::new(shape, 0, 1, 3, 2);
        for _ in 0..40 {
            let k = rng.normal_vec(8, 1.0);
            b.append(&k, &k.clone());
        }
        let mut out = vec![0.0; 8];
        // Step 1 selects; step 2 reuses; step 3 refreshes. Feed a query
        // aligned with a specific late key to change the ranking.
        b.attend(&rng.normal_vec(8, 1.0), &mut out);
        let first = b.shared_indices().to_vec();
        b.attend(&rng.normal_vec(8, 1.0), &mut out); // reuse
        assert_eq!(b.shared_indices(), first.as_slice());
        // Insert a dominant key, then refresh step must include it.
        let big = vec![10.0f32; 8];
        b.append(&big, &big);
        b.attend(&big, &mut out); // step 3 -> refresh
        let last_idx = b.len() - 1;
        assert!(b.shared_indices().contains(&last_idx), "{:?}", b.shared_indices());
    }

    #[test]
    fn share_from_other_layer() {
        let shape = AttnShape::mha(1, 4, 64);
        let mut b = HShareAttention::new(shape, 0, 1, 2, 8);
        let mut rng = Rng::new(111);
        for _ in 0..10 {
            let k = rng.normal_vec(4, 1.0);
            b.append(&k, &k.clone());
        }
        b.share_indices_from(&[3, 7]);
        let mut out = vec![0.0; 4];
        b.attend(&rng.normal_vec(4, 1.0), &mut out);
        assert_eq!(b.shared_indices(), &[3, 7]);
    }
}

//! Loki baseline (Singhania et al., 2024): low-rank keys for sparse
//! attention, computed with **post-RoPE** PCA.
//!
//! Loki runs PCA on rotated keys offline, scores tokens with the leading
//! principal components of the *rotated* query/key, selects top-k, then
//! attends with the full-precision cache (the cache is NOT compressed —
//! Table 1: memory "Median"). SALS's §3.1 argument is precisely that this
//! post-RoPE latent space needs a higher rank for the same fidelity.

use crate::attention::baselines::common::{pool_query, BaselineScratch, DenseCache};
use crate::attention::{
    merge_selection_into, AttentionBackend, AttnShape, FootprintModel, Traffic,
};
use crate::lowrank::Projector;
use crate::tensor::ops::sparse_attend_threaded;
use crate::tensor::top_k_indices_into;
use crate::util::threadpool::Workers;

pub struct LokiAttention {
    cache: DenseCache,
    /// PCA projector fitted on post-RoPE keys (dim = kv_dim).
    projector: Projector,
    /// Scoring dims (Loki's r).
    r: usize,
    /// (len, r) latent copies of the rotated keys, for scoring only —
    /// contiguous r-length rows, so scoring is a unit-stride matmul_tn.
    latents: Vec<f32>,
    sink: usize,
    recent: usize,
    critical: usize,
    traffic: Traffic,
    scratch: BaselineScratch,
}

impl LokiAttention {
    /// `projector` must be calibrated on **post-RoPE** keys.
    pub fn new(
        shape: AttnShape,
        projector: Projector,
        r: usize,
        sink: usize,
        recent: usize,
        critical: usize,
    ) -> LokiAttention {
        assert_eq!(projector.dim, shape.kv_dim());
        assert!(r <= projector.rank);
        LokiAttention {
            cache: DenseCache::new(shape),
            projector,
            r,
            latents: Vec::new(),
            sink,
            recent,
            critical,
            traffic: Traffic::default(),
            scratch: BaselineScratch::default(),
        }
    }
}

impl AttentionBackend for LokiAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v, &mut self.traffic);
        // Latent copy of the *rotated* key (post-RoPE PCA).
        let kvd = self.cache.shape.kv_dim();
        self.scratch.lat.resize(self.projector.rank, 0.0);
        let rot = self.cache.keys.row((self.cache.len - 1) * kvd, kvd);
        self.projector.project(rot, &mut self.scratch.lat);
        self.latents.extend_from_slice(&self.scratch.lat[..self.r]);
        self.traffic.write_f32(self.r);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let shape = self.cache.shape;
        let len = self.cache.len;
        self.cache.rotate_query_into(q, len - 1, &mut self.scratch.qr);
        // Pool rotated query heads to kv_dim, then project (mirrors SALS's
        // GQA handling so the comparison is apples-to-apples).
        pool_query(&shape, &self.scratch.qr, &mut self.scratch.pooled);
        self.scratch.lat.resize(self.projector.rank, 0.0);
        let pooled = std::mem::take(&mut self.scratch.pooled);
        self.projector.project(&pooled, &mut self.scratch.lat);
        self.scratch.pooled = pooled;
        // Score all tokens in the post-RoPE latent space: one unit-stride
        // matmul_tn over the contiguous (len, r) latent rows.
        self.scratch.scores.resize(len, 0.0);
        crate::tensor::ops::matmul_tn(
            &self.scratch.lat[..self.r],
            &self.latents,
            &mut self.scratch.scores,
            1,
            self.r,
            len,
        );
        self.traffic.read_f32(len * self.r);
        top_k_indices_into(&self.scratch.scores, self.critical, &mut self.scratch.idx);
        merge_selection_into(
            len,
            self.sink,
            self.recent,
            &self.scratch.idx,
            &mut self.scratch.crit_sorted,
            &mut self.scratch.sel,
        );
        self.cache.gather_into(
            &self.scratch.sel,
            &mut self.scratch.keys,
            &mut self.scratch.vals,
            &mut self.traffic,
        );
        sparse_attend_threaded(
            &self.scratch.qr,
            &self.scratch.keys,
            &self.scratch.vals,
            self.scratch.sel.len(),
            shape.n_heads,
            shape.n_kv_heads,
            shape.head_dim,
            &self.scratch.workers,
            &mut self.scratch.attend,
            out,
        );
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.scratch.workers = workers.clone();
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        // Full cache + scoring latents stay resident.
        self.cache.kv_bytes() + self.latents.len() * 4
    }

    fn footprint(&self) -> FootprintModel {
        // The cache is NOT compressed (Table 1: memory "Median"): dense
        // rate plus r fp32 scoring latents per token.
        FootprintModel::linear(0, self.cache.bytes_per_token() + self.r * 4)
    }

    fn name(&self) -> &'static str {
        "loki"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::Calibrator;
    use crate::rope::RopeTable;
    use crate::util::rng::Rng;

    fn post_rope_projector(shape: AttnShape, rank: usize, rng: &mut Rng) -> Projector {
        // Calibrate on rotated keys, as Loki does.
        let kvd = shape.kv_dim();
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        let mut cal = Calibrator::new(kvd);
        for pos in 0..300 {
            let mut k = rng.normal_vec(kvd, 1.0);
            rope.apply_multihead(&mut k, pos % shape.max_seq);
            cal.add_key(&k);
        }
        cal.fit(rank).unwrap()
    }

    #[test]
    fn selects_and_attends() {
        let shape = AttnShape::mha(2, 8, 128);
        let mut rng = Rng::new(91);
        let proj = post_rope_projector(shape, 8, &mut rng);
        let mut b = LokiAttention::new(shape, proj, 4, 2, 4, 8);
        for _ in 0..60 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            b.append(&k, &v);
        }
        let q = rng.normal_vec(16, 1.0);
        let mut out = vec![0.0; 16];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn memory_not_compressed() {
        // Loki keeps the dense cache + latents: kv_bytes > dense-only.
        let shape = AttnShape::mha(1, 8, 64);
        let mut rng = Rng::new(93);
        let proj = post_rope_projector(shape, 4, &mut rng);
        let mut b = LokiAttention::new(shape, proj, 4, 1, 2, 4);
        for _ in 0..30 {
            let k = rng.normal_vec(8, 1.0);
            let v = rng.normal_vec(8, 1.0);
            b.append(&k, &v);
        }
        assert!(b.kv_bytes() > 30 * 2 * 8 * 4);
    }
}

//! Loki baseline (Singhania et al., 2024): low-rank keys for sparse
//! attention, computed with **post-RoPE** PCA.
//!
//! Loki runs PCA on rotated keys offline, scores tokens with the leading
//! principal components of the *rotated* query/key, selects top-k, then
//! attends with the full-precision cache (the cache is NOT compressed —
//! Table 1: memory "Median"). SALS's §3.1 argument is precisely that this
//! post-RoPE latent space needs a higher rank for the same fidelity.

use crate::attention::baselines::common::DenseCache;
use crate::attention::{
    exact_attention, merge_selection, AttentionBackend, AttnShape, FootprintModel, Traffic,
};
use crate::lowrank::Projector;
use crate::tensor::top_k_indices;

pub struct LokiAttention {
    cache: DenseCache,
    /// PCA projector fitted on post-RoPE keys (dim = kv_dim).
    projector: Projector,
    /// Scoring dims (Loki's r).
    r: usize,
    /// (len, r) latent copies of the rotated keys, for scoring only.
    latents: Vec<f32>,
    sink: usize,
    recent: usize,
    critical: usize,
    traffic: Traffic,
}

impl LokiAttention {
    /// `projector` must be calibrated on **post-RoPE** keys.
    pub fn new(
        shape: AttnShape,
        projector: Projector,
        r: usize,
        sink: usize,
        recent: usize,
        critical: usize,
    ) -> LokiAttention {
        assert_eq!(projector.dim, shape.kv_dim());
        assert!(r <= projector.rank);
        LokiAttention {
            cache: DenseCache::new(shape),
            projector,
            r,
            latents: Vec::new(),
            sink,
            recent,
            critical,
            traffic: Traffic::default(),
        }
    }
}

impl AttentionBackend for LokiAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        self.cache.append(k, v, &mut self.traffic);
        // Latent copy of the *rotated* key (post-RoPE PCA).
        let kvd = self.cache.shape.kv_dim();
        let rot = &self.cache.keys[(self.cache.len - 1) * kvd..self.cache.len * kvd];
        let mut lat = vec![0.0f32; self.projector.rank];
        self.projector.project(rot, &mut lat);
        self.latents.extend_from_slice(&lat[..self.r]);
        self.traffic.write_f32(self.r);
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert!(self.cache.len > 0);
        let qr = self.cache.rotate_query(q);
        // Pool rotated query heads to kv_dim, then project (mirrors SALS's
        // GQA handling so the comparison is apples-to-apples).
        let shape = self.cache.shape;
        let (d, kvd, group) = (shape.head_dim, shape.kv_dim(), shape.group_size());
        let mut pooled = vec![0.0f32; kvd];
        let inv = 1.0 / group as f32;
        for h in 0..shape.n_heads {
            let kvh = h / group;
            for (a, &b) in pooled[kvh * d..(kvh + 1) * d].iter_mut().zip(&qr[h * d..(h + 1) * d]) {
                *a += b * inv;
            }
        }
        let mut qlat = vec![0.0f32; self.projector.rank];
        self.projector.project(&pooled, &mut qlat);
        // Score all tokens in the post-RoPE latent space.
        let mut scores = Vec::with_capacity(self.cache.len);
        for j in 0..self.cache.len {
            scores.push(crate::tensor::ops::dot(&qlat[..self.r], &self.latents[j * self.r..(j + 1) * self.r]));
        }
        self.traffic.read_f32(self.cache.len * self.r);
        let crit = top_k_indices(&scores, self.critical);
        let sel = merge_selection(self.cache.len, self.sink, self.recent, &crit);
        let (ks, vs) = self.cache.gather(&sel, &mut self.traffic);
        exact_attention(&shape, &qr, &ks, &vs, sel.len(), out);
    }

    fn len(&self) -> usize {
        self.cache.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        // Full cache + scoring latents stay resident.
        self.cache.kv_bytes() + self.latents.len() * 4
    }

    fn footprint(&self) -> FootprintModel {
        // The cache is NOT compressed (Table 1: memory "Median"): dense
        // rate plus r fp32 scoring latents per token.
        FootprintModel::linear(0, self.cache.bytes_per_token() + self.r * 4)
    }

    fn name(&self) -> &'static str {
        "loki"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::Calibrator;
    use crate::rope::RopeTable;
    use crate::util::rng::Rng;

    fn post_rope_projector(shape: AttnShape, rank: usize, rng: &mut Rng) -> Projector {
        // Calibrate on rotated keys, as Loki does.
        let kvd = shape.kv_dim();
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        let mut cal = Calibrator::new(kvd);
        for pos in 0..300 {
            let mut k = rng.normal_vec(kvd, 1.0);
            rope.apply_multihead(&mut k, pos % shape.max_seq);
            cal.add_key(&k);
        }
        cal.fit(rank).unwrap()
    }

    #[test]
    fn selects_and_attends() {
        let shape = AttnShape::mha(2, 8, 128);
        let mut rng = Rng::new(91);
        let proj = post_rope_projector(shape, 8, &mut rng);
        let mut b = LokiAttention::new(shape, proj, 4, 2, 4, 8);
        for _ in 0..60 {
            let k = rng.normal_vec(16, 1.0);
            let v = rng.normal_vec(16, 1.0);
            b.append(&k, &v);
        }
        let q = rng.normal_vec(16, 1.0);
        let mut out = vec![0.0; 16];
        b.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn memory_not_compressed() {
        // Loki keeps the dense cache + latents: kv_bytes > dense-only.
        let shape = AttnShape::mha(1, 8, 64);
        let mut rng = Rng::new(93);
        let proj = post_rope_projector(shape, 4, &mut rng);
        let mut b = LokiAttention::new(shape, proj, 4, 1, 2, 4);
        for _ in 0..30 {
            let k = rng.normal_vec(8, 1.0);
            let v = rng.normal_vec(8, 1.0);
            b.append(&k, &v);
        }
        assert!(b.kv_bytes() > 30 * 2 * 8 * 4);
    }
}

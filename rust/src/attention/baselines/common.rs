//! Shared plumbing for baseline backends: a dense post-RoPE KV cache plus
//! the backend-owned decode scratch that keeps every baseline's hot path
//! allocation-free (the `attention/mod.rs` decode hot-path contract).
//!
//! The attend kernels every baseline funnels into
//! ([`crate::tensor::ops::sparse_attend`] and friends) dispatch their
//! elementwise loops through [`crate::tensor::simd`], so all baselines
//! pick up the runtime AVX2/NEON tier — and stay comparable to SALS —
//! without any per-backend kernel code. Quantized-value backends (KIVI)
//! additionally route their PV stage through the fused
//! [`crate::quant::TokenQuantStore::dequant_matmul_acc_all`], never
//! staging an fp32 value panel (see DESIGN.md §Perf).

use crate::attention::full::DensePrefixData;
use crate::attention::{AttnShape, SharedVec, Traffic};
use crate::rope::RopeTable;
use crate::tensor::ops::{causal_attend_chunk_seg, ChunkAttendScratch, SparseAttendScratch};
use crate::util::threadpool::Workers;
use std::sync::Arc;

/// Per-backend decode scratch shared by the DenseCache baselines. Every
/// per-(layer, token) buffer the selection→gather→attend pipeline needs
/// lives here and grows to its high-water mark; steady-state decode never
/// heap-allocates.
#[derive(Default)]
pub struct BaselineScratch {
    /// Rotated query (q_dim).
    pub qr: Vec<f32>,
    /// Query heads mean-pooled per KV group (kv_dim).
    pub pooled: Vec<f32>,
    /// Per-token (or per-page) approximate scores.
    pub scores: Vec<f32>,
    /// Top-k output.
    pub idx: Vec<usize>,
    /// Expanded critical candidates (page→token expansion etc.).
    pub crit: Vec<usize>,
    /// Sort/dedup staging for [`crate::attention::merge_selection_into`].
    pub crit_sorted: Vec<usize>,
    /// Merged sorted selection.
    pub sel: Vec<usize>,
    /// Gathered key rows ((n_sel, kv_dim)).
    pub keys: Vec<f32>,
    /// Gathered value rows ((n_sel, kv_dim)).
    pub vals: Vec<f32>,
    /// Panel/tile buffers for [`crate::tensor::ops::sparse_attend`].
    pub attend: SparseAttendScratch,
    /// Projection/label staging (Loki query latent, DoubleSparse channel
    /// gather, Loki append-row latent).
    pub lat: Vec<f32>,
    /// Worker handle for the per-KV-head attend fan-out
    /// ([`crate::tensor::ops::sparse_attend_threaded`]); default serial.
    /// Set by the engine through
    /// [`crate::attention::AttentionBackend::set_workers`] — a pooled
    /// handle lends a lane range of the engine's persistent pool.
    pub workers: Workers,
    /// Chunk of batch-rotated queries for the blocked dense-window
    /// prefill path ([`DenseCache::prefill_attend_dense_rows`]).
    pub qrows: Vec<f32>,
    /// Panel/tile buffers for the blocked prefill kernel.
    pub chunk: ChunkAttendScratch,
}

impl BaselineScratch {
    /// Prefill finished: the blocked-prefill buffers are chunk/cache-sized
    /// and decode never touches them — release them (the decode-side
    /// buffers stay, per the no-alloc hot-path contract).
    pub fn end_prefill(&mut self) {
        self.qrows = Vec::new();
        self.chunk = ChunkAttendScratch::default();
    }
}

/// How many leading rows of a prefill chunk see their *entire* causal
/// prefix under a sink+recent selection pattern: row `t` (absolute
/// position `start + t`) has `vis = start + t + 1` visible tokens, and
/// sink ∪ recent covers all of them iff `vis <= window`. Those rows are
/// exactly dense causal attention, so they can take the blocked kernel
/// instead of the per-position selection loop.
pub fn dense_prefix_rows(start: usize, n: usize, window: usize) -> usize {
    window.saturating_sub(start).min(n)
}

/// Mean-pool a rotated query's heads per KV group into (kv_dim) — the
/// leader-query used for approximate scoring by SALS, Loki, DoubleSparse,
/// HShare, and Quest (see DESIGN.md §3 on GQA pooling). `pooled` is a
/// reused buffer.
pub fn pool_query(shape: &AttnShape, qr: &[f32], pooled: &mut Vec<f32>) {
    let d = shape.head_dim;
    let kvd = shape.kv_dim();
    let group = shape.group_size();
    pooled.resize(kvd, 0.0);
    if group == 1 {
        pooled.copy_from_slice(&qr[..kvd]);
        return;
    }
    pooled.fill(0.0);
    let inv = 1.0 / group as f32;
    for h in 0..shape.n_heads {
        let kvh = h / group;
        let qh = &qr[h * d..(h + 1) * d];
        for (a, &b) in pooled[kvh * d..(kvh + 1) * d].iter_mut().zip(qh) {
            *a += b * inv;
        }
    }
}

/// Dense fp32 KV cache with keys rotated at append time. Most token-sparse
/// baselines (Loki, DoubleSparse, HShare, Quest, StreamingLLM) keep the full
/// cache resident and only reduce *traffic*; this is their common store.
pub struct DenseCache {
    pub shape: AttnShape,
    pub rope: RopeTable,
    /// (len, kv_dim) post-RoPE keys; leading rows may be held by
    /// reference to an adopted shared prefix.
    pub keys: SharedVec,
    /// (len, kv_dim) values.
    pub values: SharedVec,
    pub len: usize,
}

impl DenseCache {
    pub fn new(shape: AttnShape) -> DenseCache {
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        DenseCache { shape, rope, keys: SharedVec::new(), values: SharedVec::new(), len: 0 }
    }

    /// Append pre-RoPE key (rotated in place after the copy — no temporary
    /// row allocation) + value.
    pub fn append(&mut self, k: &[f32], v: &[f32], traffic: &mut Traffic) {
        let kvd = self.shape.kv_dim();
        assert_eq!(k.len(), kvd);
        assert_eq!(v.len(), kvd);
        self.keys.extend_from_slice(k);
        self.rope.apply_multihead(self.keys.tail_mut(kvd), self.len);
        self.values.extend_from_slice(v);
        self.len += 1;
        traffic.write_f32(2 * kvd);
    }

    /// Append a chunk of `n` pre-RoPE keys/values ((n, kv_dim) row-major
    /// each) with one batched RoPE sweep over the new rows.
    pub fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize, traffic: &mut Traffic) {
        let kvd = self.shape.kv_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        self.keys.extend_from_slice(ks);
        self.rope.apply_rows_offset(self.keys.tail_mut(n * kvd), kvd, self.len);
        self.values.extend_from_slice(vs);
        self.len += n;
        traffic.write_f32(2 * n * kvd);
    }

    /// Freeze the cache's full contents for prefix publication. `traffic`
    /// is the owning backend's meter at fork time, which bit-equals a cold
    /// prefill's, so adopters' meters continue identically.
    pub fn snapshot(&self, traffic: Traffic) -> DensePrefixData {
        DensePrefixData { keys: self.keys.fork_arc(), values: self.values.fork_arc(), traffic }
    }

    /// Adopt a dense snapshot's rows by reference into an empty cache.
    /// Returns false on a non-empty cache or a shape mismatch.
    pub fn adopt(&mut self, n_tokens: usize, d: &DensePrefixData) -> bool {
        if self.len != 0 || d.keys.len() != n_tokens * self.shape.kv_dim() {
            return false;
        }
        self.keys = SharedVec::from_shared(Arc::clone(&d.keys));
        self.values = SharedVec::from_shared(Arc::clone(&d.values));
        self.len = n_tokens;
        true
    }

    /// Bytes held by reference to an adopted shared prefix.
    pub fn shared_bytes(&self) -> usize {
        self.keys.shared_bytes() + self.values.shared_bytes()
    }

    /// The shared `prefill_attend` loop for DenseCache-backed baselines:
    /// drive a per-position `attend_at(q_row, pos, out_row)` over the last
    /// `n` cached tokens (row `t` at absolute position `len - n + t`).
    pub fn prefill_attend_rows(
        cache_len: usize,
        qd: usize,
        qs: &[f32],
        n: usize,
        out: &mut [f32],
        mut attend_at: impl FnMut(&[f32], usize, &mut [f32]),
    ) {
        assert!(n > 0 && n <= cache_len);
        assert_eq!(qs.len(), n * qd);
        assert_eq!(out.len(), n * qd);
        let start = cache_len - n;
        for t in 0..n {
            attend_at(&qs[t * qd..(t + 1) * qd], start + t, &mut out[t * qd..(t + 1) * qd]);
        }
    }

    /// Blocked attend for the first `n_dense` rows of an `n_chunk`-row
    /// prefill chunk — rows whose selection is the full causal prefix
    /// (see [`dense_prefix_rows`]). Batch-rotates their queries and runs
    /// [`causal_attend_chunk`] against the cache prefix they can see,
    /// metering the canonical per-row cost `2·(visible rows)·kv_dim` —
    /// exactly what the per-position gather path reads for a full-prefix
    /// selection, so traffic accounting is path-independent.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_attend_dense_rows(
        &self,
        qs: &[f32],
        n_chunk: usize,
        n_dense: usize,
        qrows: &mut Vec<f32>,
        scratch: &mut ChunkAttendScratch,
        out: &mut [f32],
        traffic: &mut Traffic,
    ) {
        let shape = self.shape;
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        assert!(n_dense > 0 && n_dense <= n_chunk && n_chunk <= self.len);
        assert_eq!(out.len(), n_dense * qd);
        let start = self.len - n_chunk;
        let prefix = start + n_dense;
        qrows.clear();
        qrows.extend_from_slice(&qs[..n_dense * qd]);
        self.rope.apply_rows_offset(qrows, qd, start);
        causal_attend_chunk_seg(
            qrows,
            &self.keys.segs_to(prefix * kvd),
            &self.values.segs_to(prefix * kvd),
            n_dense,
            prefix,
            shape.n_heads,
            shape.n_kv_heads,
            shape.head_dim,
            scratch,
            out,
        );
        let visible_rows: usize = (0..n_dense).map(|t| start + t + 1).sum();
        traffic.read_f32(2 * visible_rows * kvd);
    }

    /// Rotate a query for an explicit absolute position into a reused
    /// buffer, allocation-free (batched prefill rotates each chunk row at
    /// its own position; decode rotates at `len - 1`).
    pub fn rotate_query_into(&self, q: &[f32], pos: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(q);
        self.rope.apply_multihead(out, pos);
    }

    /// Gather rows of keys+values for a selection, metering reads.
    /// Allocates; decode hot paths use [`DenseCache::gather_into`].
    pub fn gather(&self, sel: &[usize], traffic: &mut Traffic) -> (Vec<f32>, Vec<f32>) {
        let (mut ks, mut vs) = (Vec::new(), Vec::new());
        self.gather_into(sel, &mut ks, &mut vs, traffic);
        (ks, vs)
    }

    /// Allocation-free K/V row gather into reused (n_sel, kv_dim) buffers.
    pub fn gather_into(
        &self,
        sel: &[usize],
        ks: &mut Vec<f32>,
        vs: &mut Vec<f32>,
        traffic: &mut Traffic,
    ) {
        let kvd = self.shape.kv_dim();
        ks.clear();
        vs.clear();
        ks.reserve(sel.len() * kvd);
        vs.reserve(sel.len() * kvd);
        for &j in sel {
            ks.extend_from_slice(self.keys.row(j * kvd, kvd));
            vs.extend_from_slice(self.values.row(j * kvd, kvd));
        }
        traffic.read_f32(2 * sel.len() * kvd);
    }

    pub fn kv_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 4
    }

    /// Footprint rate of the dense store: one fp32 key + value row per
    /// token. The shared base rate of every DenseCache-backed baseline's
    /// [`crate::attention::FootprintModel`].
    pub fn bytes_per_token(&self) -> usize {
        2 * self.shape.kv_dim() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_gather_roundtrip() {
        let shape = AttnShape::mha(1, 4, 16);
        let mut c = DenseCache::new(shape);
        let mut t = Traffic::default();
        let mut rng = Rng::new(83);
        let mut vals = Vec::new();
        for _ in 0..5 {
            let k = rng.normal_vec(4, 1.0);
            let v = rng.normal_vec(4, 1.0);
            vals.push(v.clone());
            c.append(&k, &v, &mut t);
        }
        let (_, vs) = c.gather(&[1, 3], &mut t);
        assert_eq!(&vs[..4], vals[1].as_slice());
        assert_eq!(&vs[4..], vals[3].as_slice());
        assert_eq!(t.written, (5 * 2 * 4 * 4) as u64);
        assert_eq!(t.read, (2 * 2 * 4 * 4) as u64);
    }

    #[test]
    fn append_batch_matches_append_loop() {
        let shape = AttnShape::mha(2, 4, 32);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(95);
        let n = 9;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let mut a = DenseCache::new(shape);
        let mut b = DenseCache::new(shape);
        let (mut ta, mut tb) = (Traffic::default(), Traffic::default());
        a.append_batch(&ks, &vs, n, &mut ta);
        for t in 0..n {
            b.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd], &mut tb);
        }
        assert_eq!(a.len, b.len);
        assert_eq!(a.keys, b.keys);
        assert_eq!(a.values, b.values);
        assert_eq!(ta.written, tb.written);
    }

    #[test]
    fn keys_are_rotated() {
        let shape = AttnShape::mha(1, 4, 16);
        let mut c = DenseCache::new(shape);
        let mut t = Traffic::default();
        let k = vec![1.0f32, 0.0, 0.0, 0.0];
        c.append(&k, &k, &mut t); // pos 0: identity
        c.append(&k, &k, &mut t); // pos 1: rotated
        assert_eq!(c.keys.row(0, 4), k.as_slice());
        assert_ne!(c.keys.row(4, 4), k.as_slice());
    }

    #[test]
    fn pool_query_mha_is_identity_gqa_is_mean() {
        let mha = AttnShape::mha(2, 4, 16);
        let q = vec![1.0f32, 2., 3., 4., 5., 6., 7., 8.];
        let mut pooled = Vec::new();
        pool_query(&mha, &q, &mut pooled);
        assert_eq!(pooled, q);
        let gqa = AttnShape::gqa(2, 1, 4, 16);
        pool_query(&gqa, &q, &mut pooled);
        assert_eq!(pooled, vec![3.0, 4.0, 5.0, 6.0]);
    }
}

//! Memory-traffic metering and the §4.5 roofline model.
//!
//! Attention decode is memory-bandwidth bound; the paper's performance
//! claims reduce to "how many cache bytes does one decode step move".
//! Every backend meters reads/writes of its KV store through [`Traffic`],
//! and the closed-form speedup model of §4.5 is implemented alongside so
//! benches can print model-vs-measured.

/// Cumulative cache traffic counters (bytes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Bytes read from the KV store during scoring + attention.
    pub read: u64,
    /// Bytes written to the KV store (appends, quantization, eviction).
    pub written: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.read + self.written
    }

    /// Meter a read of `n` f32 elements.
    #[inline]
    pub fn read_f32(&mut self, n: usize) {
        self.read += (n * 4) as u64;
    }

    /// Meter a write of `n` f32 elements.
    #[inline]
    pub fn write_f32(&mut self, n: usize) {
        self.written += (n * 4) as u64;
    }

    /// Meter a read of `n` raw bytes (packed quantized codes).
    #[inline]
    pub fn read_bytes(&mut self, n: usize) {
        self.read += n as u64;
    }

    /// Meter a write of `n` raw bytes.
    #[inline]
    pub fn write_bytes(&mut self, n: usize) {
        self.written += n as u64;
    }
}

/// §4.5 closed-form: full attention moves `2 s d` elements per decode step
/// (keys + values, stacked dim d = n_kv_heads*head_dim); SALS moves
/// `s r* + 2 k r` (latent scoring pass + selected low-rank K and quantized V).
///
/// Returns the predicted memory-bound speedup
/// `2 s d / (s r* + 2 k r)  =  1 / (d_{r*}/2 + d_r k_s)`.
pub fn sals_speedup_model(s: usize, d: usize, r: usize, r_star: usize, k: usize) -> f64 {
    let full = 2.0 * s as f64 * d as f64;
    let sals = s as f64 * r_star as f64 + 2.0 * k as f64 * r as f64;
    full / sals
}

/// The same model in the paper's ratio form: `1 / (d_{r*}/2 + d_r·k_s)`.
pub fn sals_speedup_ratio_form(d_r_star: f64, d_r: f64, k_s: f64) -> f64 {
    1.0 / (d_r_star / 2.0 + d_r * k_s)
}

/// Traffic reduction of the fused reconstruct-RoPE kernel vs standard
/// FlashAttention (paper: 7.69×–14.28× depending on sparsity + rank).
pub fn fused_kernel_traffic_cut(s: usize, d: usize, r: usize, r_star: usize, k: usize) -> f64 {
    sals_speedup_model(s, d, r, r_star, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut t = Traffic::default();
        t.read_f32(10);
        t.write_f32(2);
        t.read_bytes(3);
        assert_eq!(t.read, 43);
        assert_eq!(t.written, 8);
        assert_eq!(t.total(), 51);
    }

    #[test]
    fn model_forms_agree() {
        let (s, d, r, rs, k) = (4096usize, 1024usize, 256usize, 128usize, 512usize);
        let a = sals_speedup_model(s, d, r, rs, k);
        let b = sals_speedup_ratio_form(rs as f64 / d as f64, r as f64 / d as f64, k as f64 / s as f64);
        assert!((a - b).abs() / a < 1e-12);
    }

    #[test]
    fn paper_range_72x_to_14x() {
        // Paper §4.5: fused kernel cuts traffic 7.69×–14.28× depending on
        // settings. SALS-25% (r=d/4, r*=r/2, k=s/8):
        let d = 4096;
        let cut25 = fused_kernel_traffic_cut(4096, d, d / 4, d / 8, 4096 / 8);
        // 2sd/(s·d/8 + 2·(s/8)·(d/4)) = 2/(1/8+1/16) = 10.67
        assert!((cut25 - 10.666).abs() < 0.01, "{cut25}");
        // SALS-12.5%: r=d/8, r*=r/2=d/16, k=s/8 -> 2/(1/16+1/32) = 21.3;
        // paper's quoted 7.69–14.28 window brackets the 25% settings at
        // k/s∈[1/8,1/4]: at k_s=1/4, 2/(1/8+1/8)=8.0.
        let cut_dense_k = fused_kernel_traffic_cut(4096, d, d / 4, d / 8, 4096 / 4);
        assert!((cut_dense_k - 8.0).abs() < 0.01, "{cut_dense_k}");
    }

    #[test]
    fn speedup_grows_with_seq_at_fixed_k() {
        let d = 1024;
        let f = |s| sals_speedup_model(s, d, d / 4, d / 8, 512);
        assert!(f(16_384) > f(4096));
        assert!(f(4096) > f(1024));
    }
}

//! SALS decode attention (Algorithm 1): latent KV cache, critical-token
//! selection in latent space, fused selective reconstruction + RoPE +
//! exact sparse attention — restructured so the decode hot loop is
//! **bandwidth-exact** (streams only the bytes it scores),
//! **allocation-free**, and **fused** (the reconstructed key panel never
//! exists in memory).
//!
//! The production decode step ([`AttentionBackend::attend`]) is three
//! stages:
//!
//! 1. **Score** — `k̃ = U_rᵀ k` appends the new token's key as an r-dim
//!    latent (pre-RoPE, §3.1: post-RoPE keys have higher effective rank);
//!    values go to the channel-wise group-quantized store with an fp32
//!    recent window. Scoring `s_j = q̃[:r*] · k̃_j[:r*]` (§4.3) runs as a
//!    unit-stride [`crate::tensor::ops::matmul_tn`] over the **scoring
//!    panel**: latents are stored split — a contiguous (len, r*) panel
//!    holding each row's leading r* dims and a (len, r−r*) remainder panel
//!    — so the scan streams exactly `len·r*` floats. Long contexts
//!    partition the scan into fixed token blocks across the engine-plumbed
//!    worker share (each score is an independent dot, so the fan-out is
//!    bit-invisible).
//! 2. **Select** — `C = sink ∪ recent ∪ top-k(s)` (§5.2 layout) via
//!    [`super::merge_selection_into`]: O(k·log k) range-merge into
//!    backend-owned scratch, not an O(seq_len) mask allocated per call.
//! 3. **Fused reconstruct·RoPE·QKᵀ·attend** (§4.4) — the selection streams
//!    through [`crate::tensor::ops::fused_sparse_attend`] in L1-resident,
//!    per-KV-head tiles: non-recent rows reconstruct their gathered split
//!    latents against this head's Uᵀ block, recent-ring rows copy their
//!    exact fp32 head slice, every tile row is rotated at its original
//!    position, and the PV stage consumes the quantized value store **as
//!    codes** through the page-coherent fused
//!    [`crate::quant::TokenQuantStore::dequant_matmul_acc`] (§Perf L6:
//!    int4/int2 rows never round-trip through an fp32 staging panel); an
//!    online softmax folds each tile's QKᵀ block into running
//!    (max, denom, PV) state — neither the (n_sel, kvd) key panel, the
//!    full score row, nor a dequantized value tile is ever materialized.
//!    KV-head panels are independent, so the tile loop fans out per KV
//!    head across the worker share.
//!
//! The PR-4 **staged** pipeline (materializing reconstruct → packed
//! [`crate::tensor::ops::sparse_attend`]) survives as
//! [`SalsAttention::attend_staged`] — the parity reference the fused path
//! is proptested against and the bench's comparison column; see
//! `stage_reconstruct`/`stage_attend` for its layout details (recon
//! matmul skips ring rows, page-coherent full-width value gather).
//!
//! Every stage writes only backend-owned scratch: steady-state decode
//! performs zero heap allocations (the `attention/mod.rs` decode hot-path
//! contract).
//!
//! GQA: the latent space is calibrated on stacked **KV-head** keys
//! (kv_dim = n_kv_heads·head_dim). Queries are mean-pooled per KV group to
//! kv_dim before projection — the single-head shared-latent analogue for
//! grouped queries (documented in DESIGN.md §3).
//!
//! Batched prefill: `append_batch`/`forward_batch` compute the whole
//! chunk's latent projection as **one** `K̃ = K·U_r` [`crate::tensor::ops::matmul_tn`]
//! instead of n per-row projections; rows are then split into the two
//! panels at push time. `forward_batch` keeps the *state* pushes
//! interleaved with the attends — the fp32 recent-key ring and the quant
//! store's high-precision window are position-relative, so evolving them
//! token-by-token is what keeps the batched path bit-compatible with
//! sequential decode. (`prefill_attend` deliberately keeps the n == 1
//! default: with a whole chunk pre-appended, tokens that a mid-chunk query
//! should see at full precision may already have been evicted from the
//! ring by later chunk rows.)
//!
//! Block-sparse prefill ([`SalsConfig::prefill`], opt-in): while prefill
//! is live the backend also keeps exact post-RoPE key/value panels
//! (dropped at `end_prefill`, never counted in `kv_bytes`). Each chunk
//! mean-pools its pre-RoPE queries, projects them, scores every cached
//! token RoPE-free over the (len, r*) scoring panel (one `matmul_tn`,
//! same streamed bytes as decode Stage-1), reduces to per-block maxima,
//! and attends only the smallest τ-covering block set (sink + diagonal
//! window always retained) via
//! [`crate::tensor::ops::block_sparse_attend_chunk`]; below
//! [`PREFILL_SPARSE_MIN_LEN`] the dense blocked kernel runs instead. The
//! decode-facing stores evolve through the same push sequence either
//! way, so decode state is identical to the dense prefill path. See
//! DESIGN.md §Prefill-Sparsity for the retention + metering contracts.

use super::baselines::common::pool_query;
use super::{
    merge_selection_into, AttentionBackend, AttnShape, FootprintModel, PrefixSnapshot, SharedVec,
    Traffic,
};
use crate::lowrank::Projector;
use crate::quant::{Bits, QuantSnapshot, TokenQuantStore};
use crate::rope::RopeTable;
use crate::tensor::ops::{FusedAttendScratch, FusedLane, SparseAttendScratch};
use crate::tensor::top_k_indices_into;
use crate::util::threadpool::Workers;
use std::sync::Arc;

/// Below this cache length the Stage-1 score scan runs serial: the scan is
/// one `len·r*` unit-stride pass, and shorter scans finish before even a
/// pool dispatch pays for itself. Re-derived for the persistent
/// [`crate::util::threadpool::WorkerPool`]: dispatch is a slot write + epoch bump
/// (sub-µs, vs ~10µs per scoped spawn), so the guard drops 4096 → 512 —
/// a 512·r* scan (~8K MACs at r*=16) comfortably covers a handful of
/// sub-µs handoffs. Each score is an independent dot product, so the
/// token-block partition (fixed-size blocks via [`Workers::chunks_mut`])
/// is bit-invariant in the worker count.
const SCORE_PAR_MIN_LEN: usize = 512;

/// Fixed token-block size of the parallel score scan. Constant (not
/// derived from the thread count) so the decomposition — and therefore
/// the timing character of each block — is stable as workers vary.
const SCORE_PAR_BLOCK: usize = 2048;

/// Below this much total attend work — `n_sel · (r + group) · d` MACs,
/// the reconstruction matmuls plus the QKᵀ/PV tile passes — the fused
/// attend runs serial. Re-derived for the persistent
/// [`crate::util::threadpool::WorkerPool`] (sub-µs dispatch vs ~10µs scoped spawns):
/// 64K → 8K MACs (a few µs of arithmetic — an order of magnitude over
/// the handoff), which brings the 4K-context bench shape *into* the
/// parallel regime instead of forfeiting the fan-out until 32K. Per-unit
/// arithmetic and merge order are fixed, so the guard cannot change
/// results.
const FUSED_PAR_MIN_WORK: usize = 1 << 13;

/// Below this cache length a sparse-prefill chunk attends densely (the
/// blocked [`crate::tensor::ops::causal_attend_chunk`] path): short
/// contexts fit the dense kernel's bandwidth comfortably and block
/// selection would only add a scan. Default for
/// [`PrefillSparsity::min_len`].
pub const PREFILL_SPARSE_MIN_LEN: usize = 2048;

/// Block-sparse prefill configuration ([`SalsConfig::prefill`]) — the
/// latent-space FlexPrefill/MInference analogue: each prefill chunk's
/// queries are mean-pooled, projected to the r*-dim scoring space, and
/// scored RoPE-free against the split latent scoring panel; per-block
/// score maxima then pick the smallest block set whose softmax mass
/// covers `tau`, always retaining sink blocks and the diagonal window.
/// `None` keeps the dense interleaved prefill (the default everywhere —
/// accuracy tables are unaffected unless a caller opts in).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefillSparsity {
    /// Key-block granularity in tokens (64/128 per the block-sparse
    /// prefill convention; any positive value works).
    pub block: usize,
    /// Score-mass coverage threshold τ ∈ (0, 1]: blocks are taken in
    /// descending softmax-mass order until their cumulative mass reaches
    /// τ. `tau >= 1.0` selects every block (the parity setting).
    pub tau: f32,
    /// Hard cap on the τ-driven block count (0 = uncapped) — the
    /// fallback bound when flat score distributions would make τ select
    /// nearly everything. Sink + diagonal blocks are retained on top.
    pub top_blocks: usize,
    /// Cache lengths below this attend densely ([`PREFILL_SPARSE_MIN_LEN`]).
    pub min_len: usize,
}

impl Default for PrefillSparsity {
    fn default() -> PrefillSparsity {
        PrefillSparsity { block: 64, tau: 0.95, top_blocks: 0, min_len: PREFILL_SPARSE_MIN_LEN }
    }
}

/// SALS hyper-parameters (§5.1/§5.2 defaults).
#[derive(Clone, Debug)]
pub struct SalsConfig {
    /// Latent rank r (compression d_r = r / kv_dim).
    pub rank: usize,
    /// Scoring rank r* (paper: r/2).
    pub r_star: usize,
    /// Sink tokens always kept (x).
    pub sink: usize,
    /// Recent window always kept + stored high-precision (z / w).
    pub recent: usize,
    /// Critical-token budget for top-k (y).
    pub critical: usize,
    /// Value-cache quantization bits (4 at 25%, 2 at 12.5%).
    pub v_bits: Bits,
    /// Quantization group size along the token axis.
    pub group: usize,
    /// Optional block-sparse prefill (None = dense interleaved prefill).
    pub prefill: Option<PrefillSparsity>,
}

impl SalsConfig {
    /// Paper's SALS-25% setting for a given kv_dim: r = kv_dim/4, r* = r/2,
    /// 4-bit values.
    pub fn sals_25(kv_dim: usize, sink: usize, critical: usize, recent: usize) -> SalsConfig {
        SalsConfig {
            rank: kv_dim / 4,
            r_star: kv_dim / 8,
            sink,
            recent,
            critical,
            v_bits: Bits::B4,
            group: 32,
            prefill: None,
        }
    }

    /// Paper's SALS-12.5% setting: r = kv_dim/8, r* = r/2, 2-bit values.
    pub fn sals_125(kv_dim: usize, sink: usize, critical: usize, recent: usize) -> SalsConfig {
        SalsConfig {
            rank: kv_dim / 8,
            r_star: kv_dim / 16,
            sink,
            recent,
            critical,
            v_bits: Bits::B2,
            group: 32,
            prefill: None,
        }
    }
}

/// Wall-time of one decode attend, split by pipeline stage (seconds) —
/// filled by [`SalsAttention::attend_instrumented`] (fused production
/// path) and [`SalsAttention::attend_staged_instrumented`] (staged
/// reference) for `benches/sals_hotpath.rs`. Stages are accumulated
/// (`+=`) so one struct can aggregate a whole decode run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SalsStageTimes {
    /// Stage 1: query pool/projection + latent panel scoring.
    pub score: f64,
    /// Stage 2: top-k + sink/recent merge.
    pub select: f64,
    /// Staged path only — latent gather + reconstruction matmul + RoPE +
    /// value gather. The fused path has no separate reconstruct stage
    /// (it happens inside the attend kernel): stays 0.
    pub reconstruct: f64,
    /// Staged: query RoPE + packed sparse attention. Fused: the whole
    /// reconstruct·RoPE·QKᵀ·online-softmax tile loop.
    pub attend: f64,
}

impl SalsStageTimes {
    /// Sum of all four stages.
    pub fn total(&self) -> f64 {
        self.score + self.select + self.reconstruct + self.attend
    }
}

/// [`PrefixSnapshot`] payload for SALS: the split latent panels behind
/// `Arc`s (adopters index them through [`SharedVec`] by reference — the
/// bulk of the state), the fp32 recent-key ring by copy (appends overwrite
/// slots in place, so it must be private per adopter; it is
/// `recent_cap·kv_dim` floats, length-independent), and the quantized
/// value store as a [`QuantSnapshot`] (frozen pages shared, fp32 tail
/// copied). Carries the donor's traffic meters so an adopter's counters
/// continue exactly as a cold-prefilled sequence's would.
struct SalsPrefixData {
    latent_score: Arc<[f32]>,
    latent_rem: Arc<[f32]>,
    recent_keys: Vec<f32>,
    values: QuantSnapshot,
    traffic: Traffic,
}

/// SALS attention backend for one layer.
pub struct SalsAttention {
    shape: AttnShape,
    cfg: SalsConfig,
    projector: Projector,
    /// Uᵀ (rank, kv_dim) row-major — reconstruction as a blocked matmul
    /// with a unit-stride kv_dim inner loop (§Perf L3 iteration 3; the
    /// per-row rank-length dots were the decode-op bottleneck). Used by
    /// the chunk projection and the staged reference pipeline.
    u_t: crate::tensor::Mat,
    /// Per-KV-head Uᵀ blocks, (n_kv_heads, rank, head_dim) flat: block
    /// `kvh` holds Uᵀ's columns `kvh·d..(kvh+1)·d` row-major, so the
    /// fused kernel's per-head tile reconstruction is a unit-stride
    /// (m, r)·(r, d) matmul — summed over heads, the same FLOPs as one
    /// full-width reconstruction (the partition is free).
    u_t_heads: Vec<f32>,
    rope: RopeTable,
    /// Decode worker handle for the score scan + fused attend (default
    /// serial; the engine lends a share of its persistent pool through
    /// [`AttentionBackend::set_workers`]).
    workers: Workers,
    /// (len, r*) scoring panel: each latent row's leading r* dims,
    /// contiguous — the only latent bytes Stage-1 scoring streams. A
    /// [`SharedVec`]: an adopted prefix's rows live in a refcounted shared
    /// segment, private appends follow (the boundary is row-aligned, so
    /// scans split into at most two unit-stride passes).
    latent_score: SharedVec,
    /// (len, r − r*) remainder panel: the trailing dims, touched only when
    /// a selected row is reconstructed. Shares the [`SharedVec`] layout.
    latent_rem: SharedVec,
    /// fp32 pre-RoPE keys for the recent window (ring buffer of
    /// `recent + 1` rows, indexed by absolute position % capacity).
    recent_keys: Vec<f32>,
    recent_cap: usize,
    /// Quantized value store (fp32 recent window inside).
    values: TokenQuantStore,
    len: usize,
    traffic: Traffic,
    // ---- scratch buffers (hot path must not allocate) ----
    scratch_scores: Vec<f32>,
    scratch_idx: Vec<usize>,
    scratch_crit: Vec<usize>,
    scratch_sel: Vec<usize>,
    scratch_qlat: Vec<f32>,
    scratch_pool: Vec<f32>,
    scratch_keys: Vec<f32>,
    scratch_vals: Vec<f32>,
    scratch_lat: Vec<f32>,
    scratch_recon: Vec<f32>,
    scratch_qr: Vec<f32>,
    scratch_lat_row: Vec<f32>,
    scratch_attend: SparseAttendScratch,
    scratch_fused: FusedAttendScratch,
    /// Chunk-latent staging buffer for the batched prefill path (kept
    /// separate from `scratch_lat`, which `attend` overwrites per token).
    scratch_chunk_lat: Vec<f32>,
    // ---- block-sparse prefill state (cfg.prefill = Some only) ----
    /// True until `end_prefill`: while live (and `cfg.prefill` is set),
    /// every pushed token also lands in the exact prefill panels below.
    prefill_live: bool,
    /// (len, kv_dim) **post-RoPE** exact keys — the sparse-prefill attend
    /// target. Prefill-only scratch: grows during prefill, dropped by
    /// `end_prefill`, never counted in `kv_bytes` (decode reads the
    /// latent/quant stores, not these panels).
    prefill_keys: Vec<f32>,
    /// (len, kv_dim) exact fp32 values, same lifecycle as `prefill_keys`.
    prefill_vals: Vec<f32>,
    /// Chunk-mean query staging for the RoPE-free block scoring.
    scratch_chunk_qpool: Vec<f32>,
    /// Per-block score maxima / softmax-mass staging / descending-mass
    /// order / selected-block flags for the τ selection.
    scratch_block_scores: Vec<f32>,
    scratch_block_probs: Vec<f32>,
    scratch_block_idx: Vec<usize>,
    scratch_block_mask: Vec<u8>,
    /// Sorted disjoint selected block ranges handed to the kernel.
    scratch_blocks: Vec<(usize, usize)>,
    scratch_bs: crate::tensor::ops::BlockSparseScratch,
    /// Dense-fallback kernel scratch for chunks below `min_len`.
    scratch_chunk_dense: crate::tensor::ops::ChunkAttendScratch,
}

impl SalsAttention {
    /// `projector` must be calibrated on stacked pre-RoPE KV-head keys of
    /// dimension `shape.kv_dim()`.
    pub fn new(shape: AttnShape, cfg: SalsConfig, projector: Projector) -> SalsAttention {
        assert_eq!(projector.dim, shape.kv_dim(), "projector dim != kv_dim");
        assert!(cfg.rank <= projector.rank, "config rank exceeds projector rank");
        assert!(cfg.r_star <= cfg.rank, "r* must be <= r");
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        let recent_cap = cfg.recent.max(1);
        let values = TokenQuantStore::new(shape.kv_dim(), cfg.v_bits, cfg.group, cfg.recent.max(cfg.group));
        // Uᵀ truncated to the configured rank.
        let kvd = shape.kv_dim();
        let mut u_t = crate::tensor::Mat::zeros(cfg.rank, kvd);
        for i in 0..kvd {
            for j in 0..cfg.rank {
                u_t.data[j * kvd + i] = projector.u.data[i * projector.rank + j];
            }
        }
        // Per-KV-head column blocks of Uᵀ for the fused kernel.
        let d = shape.head_dim;
        let mut u_t_heads = vec![0.0f32; cfg.rank * kvd];
        for kvh in 0..shape.n_kv_heads {
            for j in 0..cfg.rank {
                let src = j * kvd + kvh * d;
                let dst = kvh * cfg.rank * d + j * d;
                u_t_heads[dst..dst + d].copy_from_slice(&u_t.data[src..src + d]);
            }
        }
        SalsAttention {
            shape,
            projector,
            u_t,
            u_t_heads,
            rope,
            workers: Workers::serial(),
            latent_score: SharedVec::new(),
            latent_rem: SharedVec::new(),
            recent_keys: vec![0.0; recent_cap * shape.kv_dim()],
            recent_cap,
            values,
            len: 0,
            traffic: Traffic::default(),
            scratch_scores: Vec::new(),
            scratch_idx: Vec::new(),
            scratch_crit: Vec::new(),
            scratch_sel: Vec::new(),
            scratch_qlat: vec![0.0; cfg.rank],
            scratch_pool: vec![0.0; shape.kv_dim()],
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
            scratch_lat: Vec::new(),
            scratch_recon: Vec::new(),
            scratch_qr: Vec::new(),
            scratch_lat_row: Vec::new(),
            scratch_attend: SparseAttendScratch::default(),
            scratch_fused: FusedAttendScratch::default(),
            scratch_chunk_lat: Vec::new(),
            prefill_live: true,
            prefill_keys: Vec::new(),
            prefill_vals: Vec::new(),
            scratch_chunk_qpool: Vec::new(),
            scratch_block_scores: Vec::new(),
            scratch_block_probs: Vec::new(),
            scratch_block_idx: Vec::new(),
            scratch_block_mask: Vec::new(),
            scratch_blocks: Vec::new(),
            scratch_bs: crate::tensor::ops::BlockSparseScratch::default(),
            scratch_chunk_dense: crate::tensor::ops::ChunkAttendScratch::default(),
            cfg,
        }
    }

    /// Latent scores of every cached token for a pre-RoPE query — exposed
    /// for the Figure-2 overlap-score analysis and the hotpath bench's
    /// score-stage traffic probe.
    pub fn latent_scores(&mut self, q: &[f32]) -> Vec<f32> {
        self.stage_score(q);
        self.scratch_scores.clone()
    }

    /// Pool query heads per KV group (mean) then project to latent space.
    fn project_query(&mut self, q: &[f32]) {
        pool_query(&self.shape, q, &mut self.scratch_pool);
        let pool = std::mem::take(&mut self.scratch_pool);
        self.projector.project(&pool, &mut self.scratch_qlat);
        self.scratch_pool = pool;
    }

    /// Stage 1: r*-dim latent scores for all cached tokens — a unit-stride
    /// matmul_tn over the (len, r*) scoring panel, partitioned into fixed
    /// [`SCORE_PAR_BLOCK`]-token blocks across the worker share for long
    /// contexts (each score is one independent dot product, so blocking
    /// and thread count are bit-invisible). Meters exactly the panel
    /// bytes the scan streams.
    fn stage_score(&mut self, q: &[f32]) {
        self.project_query(q);
        self.score_panel();
    }

    /// The panel scan of Stage 1, with the projected query already in
    /// `scratch_qlat` — shared by decode scoring and the sparse-prefill
    /// block selection (which projects a chunk-pooled query instead).
    fn score_panel(&mut self) {
        let rs = self.cfg.r_star;
        self.scratch_scores.resize(self.len, 0.0);
        // Each score is an independent dot, so scanning an adopted shared
        // segment and the private tail as separate matmul_tn passes is
        // bit-identical to one contiguous scan.
        if self.workers.width() > 1 && self.len >= SCORE_PAR_MIN_LEN {
            let qlat = &self.scratch_qlat[..rs];
            let panel = &self.latent_score;
            let n0 = panel.shared_len() / rs;
            self.workers.chunks_mut(
                &mut self.scratch_scores,
                SCORE_PAR_BLOCK,
                |bi, chunk| {
                    let lo = bi * SCORE_PAR_BLOCK;
                    let hi = lo + chunk.len();
                    let mid = n0.clamp(lo, hi);
                    if mid > lo {
                        crate::tensor::ops::matmul_tn(
                            qlat,
                            panel.slice(lo * rs, mid * rs),
                            &mut chunk[..mid - lo],
                            1,
                            rs,
                            mid - lo,
                        );
                    }
                    if hi > mid {
                        crate::tensor::ops::matmul_tn(
                            qlat,
                            panel.slice(mid * rs, hi * rs),
                            &mut chunk[mid - lo..],
                            1,
                            rs,
                            hi - mid,
                        );
                    }
                },
            );
        } else {
            let mut j0 = 0usize;
            for seg in self.latent_score.segs() {
                let rows = seg.len() / rs;
                if rows > 0 {
                    crate::tensor::ops::matmul_tn(
                        &self.scratch_qlat[..rs],
                        seg,
                        &mut self.scratch_scores[j0..j0 + rows],
                        1,
                        rs,
                        rows,
                    );
                }
                j0 += rows;
            }
        }
        self.traffic.read_f32(self.len * rs);
    }

    /// Stage 2: top-k over the scores, then sink/recent/critical merge into
    /// the backend-owned selection buffer. Returns the selection size.
    fn stage_select(&mut self) -> usize {
        top_k_indices_into(&self.scratch_scores, self.cfg.critical, &mut self.scratch_idx);
        merge_selection_into(
            self.len,
            self.cfg.sink,
            self.cfg.recent,
            &self.scratch_idx,
            &mut self.scratch_crit,
            &mut self.scratch_sel,
        );
        self.scratch_sel.len()
    }

    /// Stage 3: selective reconstruction + RoPE + value gather. The
    /// selection is partitioned: recent-ring rows skip the reconstruction
    /// matmul entirely (their exact fp32 keys come from the ring), the
    /// rest reconstruct in one (m, r)·(r, kvd) matmul.
    fn stage_reconstruct(&mut self) {
        let kvd = self.shape.kv_dim();
        let r = self.cfg.rank;
        let rs = self.cfg.r_star;
        let rem = r - rs;
        let n_sel = self.scratch_sel.len();
        // First ring slot: positions >= recent_lo are in the fp32 ring.
        let recent_lo = if self.cfg.recent > 0 {
            self.len.saturating_sub(self.recent_cap)
        } else {
            usize::MAX
        };

        // Gather the non-recent rows' split panels back into full latent
        // rows, contiguous in selection order.
        self.scratch_lat.clear();
        self.scratch_lat.reserve(n_sel * r);
        let mut m = 0;
        for &j in &self.scratch_sel {
            if j < recent_lo {
                self.scratch_lat.extend_from_slice(self.latent_score.row(j * rs, rs));
                self.scratch_lat.extend_from_slice(self.latent_rem.row(j * rem, rem));
                m += 1;
            }
        }
        self.scratch_recon.resize(m * kvd, 0.0);
        crate::tensor::ops::matmul(
            &self.scratch_lat,
            &self.u_t.data,
            &mut self.scratch_recon,
            m,
            r,
            kvd,
        );

        // Distribute into the (n_sel, kvd) key panel: reconstructed rows in
        // order, recent rows straight from the ring; RoPE each at its
        // original position (Algorithm 1, line 7).
        self.scratch_keys.resize(n_sel * kvd, 0.0);
        let mut rc = 0;
        for (si, &j) in self.scratch_sel.iter().enumerate() {
            let dst = si * kvd..(si + 1) * kvd;
            if j < recent_lo {
                self.scratch_keys[dst.clone()]
                    .copy_from_slice(&self.scratch_recon[rc * kvd..(rc + 1) * kvd]);
                rc += 1;
                self.traffic.read_f32(r);
            } else {
                // High-precision window: exact pre-RoPE key, no
                // reconstruction work and no wasted latent read.
                let slot = self.recent_slot(j);
                self.scratch_keys[dst.clone()]
                    .copy_from_slice(&self.recent_keys[slot * kvd..(slot + 1) * kvd]);
                self.traffic.read_f32(kvd);
            }
            self.rope.apply_multihead(&mut self.scratch_keys[dst], j);
        }

        // Values: page-coherent dequantizing gather over the sorted
        // selection (recent rows are exact fp32), metered per page.
        self.scratch_vals.resize(n_sel * kvd, 0.0);
        self.values.gather_rows(&self.scratch_sel, &mut self.scratch_vals);
        self.traffic.read_bytes(self.values.gather_read_bytes(&self.scratch_sel));
    }

    /// Stage 4 (staged reference): RoPE the query at its position and run
    /// the packed sparse attention kernel over the gathered panels.
    fn stage_attend(&mut self, q: &[f32], out: &mut [f32]) {
        let pos = self.len - 1;
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.scratch_qr, pos);
        crate::tensor::ops::sparse_attend(
            &self.scratch_qr,
            &self.scratch_keys,
            &self.scratch_vals,
            self.scratch_sel.len(),
            self.shape.n_heads,
            self.shape.n_kv_heads,
            self.shape.head_dim,
            &mut self.scratch_attend,
            out,
        );
    }

    /// Stages 3+4, fused (the production path — the paper's §4.4 kernel
    /// shape): the selection streams through
    /// [`crate::tensor::ops::fused_sparse_attend`] in [`crate::tensor::ops::FUSED_TILE`]-row,
    /// per-KV-head tiles. Per tile, the fill closure reconstructs the
    /// non-recent rows' latents against this head's Uᵀ block into the
    /// L1-resident key tile (recent rows copy their exact fp32 head slice
    /// from the ring) and rotates each tile row at its original position
    /// ([`RopeTable::apply_rows_at`]); the tile's PV partial then streams
    /// the head's value slice straight from quantized pages through the
    /// fused [`TokenQuantStore::dequant_matmul_acc`] (bit-identical to
    /// gather-then-matmul_acc by that kernel's contract) — the
    /// (n_sel, kvd) key panel, the full score row, and the fp32 value
    /// tile never exist; the kernel's online softmax folds each tile in.
    /// KV-head panels are independent, so the worker handle partitions
    /// them ([`FUSED_PAR_MIN_WORK`]-guarded); MQA/narrow-GQA shapes with
    /// long selections instead split fixed selection segments across
    /// workers ([`crate::tensor::ops::split_kv_engages`], shape-only).
    /// Per-unit arithmetic and merge order are fixed, making the output
    /// bit-invariant in the worker-handle width and pool size.
    ///
    /// The sorted selection makes recent-ring rows a contiguous *suffix*
    /// (everything ≥ recent_lo), so each tile splits into a reconstruction
    /// prefix and a ring suffix — no per-row branching inside the matmul.
    ///
    /// Metering: `r` f32 per reconstructed row and `kvd` f32 per ring row
    /// (identical to the staged pipeline), plus
    /// [`TokenQuantStore::gather_read_bytes`] summed **per tile** — the
    /// per-head column walks of one tile sum to exactly that tile's
    /// full-width bytes, and pages straddling a tile boundary genuinely
    /// stream their params once per touched tile (the staged path's
    /// single whole-selection gather charges such pages once, so the
    /// fused meter can exceed the staged meter by that boundary-page
    /// params delta; equal whenever the selection fits one tile).
    fn stage_attend_fused(&mut self, q: &[f32], out: &mut [f32]) {
        let kvd = self.shape.kv_dim();
        let d = self.shape.head_dim;
        let r = self.cfg.rank;
        let rs = self.cfg.r_star;
        let rem = r - rs;
        let n_sel = self.scratch_sel.len();
        let recent_lo = if self.cfg.recent > 0 {
            self.len.saturating_sub(self.recent_cap)
        } else {
            usize::MAX
        };
        // Sorted selection ⇒ rows below recent_lo form a prefix.
        let n_recon = self.scratch_sel.partition_point(|&j| j < recent_lo);

        let pos = self.len - 1;
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.scratch_qr, pos);

        let fused_work = n_sel * (r + self.shape.group_size()) * d;
        let workers = if self.workers.width() > 1 && fused_work >= FUSED_PAR_MIN_WORK {
            self.workers.clone()
        } else {
            Workers::serial()
        };

        // Gather the reconstruction rows' split latent panels ONCE into
        // contiguous (n_recon, r) staging shared read-only by every
        // KV-head lane. The latent STORE streams exactly once (what the
        // n_recon·r meter below records); the per-head matmuls re-read
        // the small staging from cache, which is ordinary blocked-matmul
        // operand reuse, not store traffic.
        self.scratch_lat.clear();
        self.scratch_lat.reserve(n_recon * r);
        for &j in &self.scratch_sel[..n_recon] {
            self.scratch_lat.extend_from_slice(self.latent_score.row(j * rs, rs));
            self.scratch_lat.extend_from_slice(self.latent_rem.row(j * rem, rem));
        }

        let sel = &self.scratch_sel;
        let lat = &self.scratch_lat;
        let recent_keys = &self.recent_keys;
        let recent_cap = self.recent_cap;
        let values = &self.values;
        let rope = &self.rope;
        let u_t_heads = &self.u_t_heads;
        let fill = move |kvh: usize, lo: usize, hi: usize, lane: &mut FusedLane| {
            // Reconstruction prefix of the tile: recon rows are the
            // selection prefix, so staging rows lo..rc_hi line up with
            // tile rows 0..m — one (m, r)·(r, d) matmul against this
            // head's Uᵀ block, straight out of the shared staging.
            let rc_hi = hi.min(n_recon);
            if lo < rc_hi {
                let m = rc_hi - lo;
                crate::tensor::ops::matmul(
                    &lat[lo * r..rc_hi * r],
                    &u_t_heads[kvh * r * d..(kvh + 1) * r * d],
                    &mut lane.ktile[..m * d],
                    m,
                    r,
                    d,
                );
            }
            // Ring suffix: exact pre-RoPE head slices from the fp32 ring.
            for (row, &j) in sel[lo..hi].iter().enumerate().skip(rc_hi.saturating_sub(lo)) {
                let slot = j % recent_cap;
                let src = slot * kvd + kvh * d;
                lane.ktile[row * d..(row + 1) * d]
                    .copy_from_slice(&recent_keys[src..src + d]);
            }
            // RoPE every tile row at its original position.
            rope.apply_rows_at(&mut lane.ktile[..(hi - lo) * d], d, &sel[lo..hi]);
        };
        // PV partial: stream this head's value slice straight from the
        // quantized pages (fused dequant-GEMV), accumulating onto the
        // lane's running PV state; `vtile` serves as the kernel's one-row
        // staging scratch for grouped queries instead of holding an fp32
        // value tile.
        let group = self.shape.group_size();
        let pv = move |kvh: usize, lo: usize, hi: usize, lane: &mut FusedLane| {
            let t = hi - lo;
            let FusedLane { scores, vtile, acc, .. } = lane;
            values.dequant_matmul_acc(
                &sel[lo..hi],
                kvh * d,
                (kvh + 1) * d,
                &scores[..group * t],
                group,
                vtile,
                acc,
            );
        };
        crate::tensor::ops::fused_sparse_attend_with(
            &self.scratch_qr,
            n_sel,
            self.shape.n_heads,
            self.shape.n_kv_heads,
            d,
            &workers,
            fill,
            pv,
            &mut self.scratch_fused,
            out,
        );
        self.traffic.read_f32(n_recon * r + (n_sel - n_recon) * kvd);
        // Value metering is TILE-accurate: the kernel dequantizes per
        // (head, tile), so a quant page whose selected rows straddle a
        // tile boundary streams its scale/zero params once per touched
        // tile (summed across the per-head column slices, params bytes
        // per page per tile — exactly what `gather_read_bytes` charges
        // per tile sub-selection). A whole-selection charge would
        // under-report those boundary pages.
        let mut vbytes = 0usize;
        let mut lo = 0;
        while lo < n_sel {
            let hi = (lo + crate::tensor::ops::FUSED_TILE).min(n_sel);
            vbytes += self.values.gather_read_bytes(&self.scratch_sel[lo..hi]);
            lo = hi;
        }
        self.traffic.read_bytes(vbytes);
    }

    /// [`AttentionBackend::attend`] (the fused production path) with
    /// per-stage wall times accumulated into `times` — the hotpath
    /// bench's probe. The fused path has no separate reconstruct stage
    /// (reconstruction happens inside the attend kernel), so
    /// `times.reconstruct` is untouched and the fused kernel's whole cost
    /// lands in `times.attend`. Identical work to `attend` plus the
    /// `Instant` reads.
    pub fn attend_instrumented(&mut self, q: &[f32], out: &mut [f32], times: &mut SalsStageTimes) {
        assert_eq!(q.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let t0 = std::time::Instant::now();
        self.stage_score(q);
        let t1 = std::time::Instant::now();
        self.stage_select();
        let t2 = std::time::Instant::now();
        self.stage_attend_fused(q, out);
        let t3 = std::time::Instant::now();
        times.score += (t1 - t0).as_secs_f64();
        times.select += (t2 - t1).as_secs_f64();
        times.attend += (t3 - t2).as_secs_f64();
    }

    /// The PR-4 staged pipeline (score → select → materialize+reconstruct
    /// → packed attend) — retained as the reference the fused path is
    /// validated against (`prop_fused_attend_matches_staged_pipeline`)
    /// and the bench's fused-vs-staged comparison column. Pinned serial
    /// (the configured worker share is suspended for the call) so the
    /// reference is the unambiguous single-threaded PR-4 baseline.
    pub fn attend_staged(&mut self, q: &[f32], out: &mut [f32]) {
        assert_eq!(q.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let saved = std::mem::replace(&mut self.workers, Workers::serial());
        self.stage_score(q);
        self.stage_select();
        self.stage_reconstruct();
        self.stage_attend(q, out);
        self.workers = saved;
    }

    /// [`SalsAttention::attend_staged`] with per-stage wall times — the
    /// bench's staged-path probe (what `attend_instrumented` measured
    /// before the fused kernel became the production path). Pinned serial
    /// like [`SalsAttention::attend_staged`].
    pub fn attend_staged_instrumented(
        &mut self,
        q: &[f32],
        out: &mut [f32],
        times: &mut SalsStageTimes,
    ) {
        assert_eq!(q.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let saved = std::mem::replace(&mut self.workers, Workers::serial());
        let t0 = std::time::Instant::now();
        self.stage_score(q);
        let t1 = std::time::Instant::now();
        self.stage_select();
        let t2 = std::time::Instant::now();
        self.stage_reconstruct();
        let t3 = std::time::Instant::now();
        self.stage_attend(q, out);
        let t4 = std::time::Instant::now();
        self.workers = saved;
        times.score += (t1 - t0).as_secs_f64();
        times.select += (t2 - t1).as_secs_f64();
        times.reconstruct += (t3 - t2).as_secs_f64();
        times.attend += (t4 - t3).as_secs_f64();
    }

    fn recent_slot(&self, pos: usize) -> usize {
        pos % self.recent_cap
    }

    /// Push one token whose latent row is already computed: split-panel
    /// latent store, fp32 recent-key ring, quantized values, write-traffic
    /// metering. Shared by the scalar and batched append paths.
    fn push_token(&mut self, lat_row: &[f32], k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        debug_assert_eq!(lat_row.len(), self.cfg.rank);
        let rs = self.cfg.r_star;
        let pos = self.len;
        self.latent_score.extend_from_slice(&lat_row[..rs]);
        self.latent_rem.extend_from_slice(&lat_row[rs..]);
        self.traffic.write_f32(self.cfg.rank);
        let slot = self.recent_slot(pos);
        self.recent_keys[slot * kvd..(slot + 1) * kvd].copy_from_slice(k);
        self.values.append(v);
        self.traffic.write_bytes(self.values.row_read_bytes(pos));
        // Sparse prefill keeps exact post-RoPE panels alongside the
        // compressed stores until `end_prefill` drops them. The coverage
        // check makes the panels self-freezing: if any push ever lands
        // without panel coverage (e.g. decode pushes after a prefill that
        // never ended), the panels stop growing and the next
        // `forward_batch` falls back to the dense interleaved path.
        if self.prefill_live
            && self.cfg.prefill.is_some()
            && self.prefill_keys.len() == pos * kvd
        {
            self.prefill_keys.extend_from_slice(k);
            self.rope.apply_multihead(&mut self.prefill_keys[pos * kvd..], pos);
            self.prefill_vals.extend_from_slice(v);
        }
        self.len += 1;
    }

    /// Mean-pool the whole chunk's queries (over rows, then per KV group)
    /// and project to latent space — the chunk-level analogue of
    /// `project_query` for RoPE-free block selection. Both maps are
    /// linear, so pooling before projecting is exact and the scoring
    /// panel streams once per chunk instead of once per row.
    fn project_chunk_query(&mut self, qs: &[f32], n: usize) {
        let qd = self.shape.q_dim();
        self.scratch_chunk_qpool.resize(qd, 0.0);
        self.scratch_chunk_qpool.fill(0.0);
        let inv = 1.0 / n as f32;
        for t in 0..n {
            crate::tensor::ops::axpy(
                inv,
                &qs[t * qd..(t + 1) * qd],
                &mut self.scratch_chunk_qpool,
            );
        }
        let mean_q = std::mem::take(&mut self.scratch_chunk_qpool);
        pool_query(&self.shape, &mean_q, &mut self.scratch_pool);
        self.scratch_chunk_qpool = mean_q;
        let pool = std::mem::take(&mut self.scratch_pool);
        self.projector.project(&pool, &mut self.scratch_qlat);
        self.scratch_pool = pool;
    }

    /// Block selection for one sparse-prefill chunk, with the token
    /// scores already in `scratch_scores`: reduce to per-block maxima,
    /// softmax the maxima into a block-mass distribution, and take blocks
    /// in descending mass order until the cumulative mass covers τ
    /// (capped at `top_blocks` when set). Sink blocks and the diagonal
    /// window — every block overlapping `[start − recent, len)`, so each
    /// query row's own position and its high-precision recent context are
    /// always attendable — are retained unconditionally (the StreamingLLM
    /// sink + window contract). Writes the sorted disjoint ranges into
    /// `scratch_blocks` and returns the selected cache-row count.
    fn select_prefill_blocks(&mut self, n: usize, ps: PrefillSparsity) -> usize {
        let len = self.len;
        let start = len - n;
        let block = ps.block.max(1);
        let nb = len.div_ceil(block);
        self.scratch_block_scores.resize(nb, 0.0);
        for b in 0..nb {
            let lo = b * block;
            let hi = (lo + block).min(len);
            self.scratch_block_scores[b] =
                self.scratch_scores[lo..hi].iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        }
        self.scratch_block_mask.resize(nb, 0);
        self.scratch_block_mask.fill(0);
        if ps.tau >= 1.0 && ps.top_blocks == 0 {
            // Parity setting: everything selected, no float-undershoot
            // risk from summing masses to 0.999999…
            self.scratch_block_mask.fill(1);
        } else {
            self.scratch_block_probs.clear();
            self.scratch_block_probs.extend_from_slice(&self.scratch_block_scores);
            crate::tensor::ops::softmax(&mut self.scratch_block_probs);
            self.scratch_block_idx.clear();
            self.scratch_block_idx.extend(0..nb);
            let probs = &self.scratch_block_probs;
            self.scratch_block_idx.sort_unstable_by(|&a, &b| {
                probs[b].partial_cmp(&probs[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            let cap = if ps.top_blocks > 0 { ps.top_blocks } else { nb };
            let mut mass = 0.0f32;
            let mut taken = 0usize;
            for &b in &self.scratch_block_idx {
                if mass >= ps.tau || taken >= cap {
                    break;
                }
                self.scratch_block_mask[b] = 1;
                mass += self.scratch_block_probs[b];
                taken += 1;
            }
        }
        // Mandatory retention: sink blocks + diagonal/recent window.
        let sink_blocks = self.cfg.sink.div_ceil(block).min(nb);
        for m in self.scratch_block_mask[..sink_blocks].iter_mut() {
            *m = 1;
        }
        let diag_lo = start.saturating_sub(self.cfg.recent) / block;
        for m in self.scratch_block_mask[diag_lo..].iter_mut() {
            *m = 1;
        }
        // Coalesce adjacent selected blocks into sorted disjoint ranges.
        self.scratch_blocks.clear();
        let mut rows = 0usize;
        let mut b = 0usize;
        while b < nb {
            if self.scratch_block_mask[b] == 0 {
                b += 1;
                continue;
            }
            let lo = b * block;
            while b < nb && self.scratch_block_mask[b] == 1 {
                b += 1;
            }
            let hi = (b * block).min(len);
            rows += hi - lo;
            self.scratch_blocks.push((lo, hi));
        }
        rows
    }

    /// Batched-prefill attend for one chunk against the exact prefill
    /// panels: the dense blocked kernel below `min_len`, latent-space
    /// block selection + [`crate::tensor::ops::block_sparse_attend_chunk`]
    /// beyond it. Metering (the prefill bandwidth contract, DESIGN.md
    /// §Prefill-Sparsity): the dense fallback charges the canonical
    /// `2·Σ visible·kv_dim`; the sparse path charges the streamed scoring
    /// panel (`len·r*` f32, in `score_panel`) plus the gathered block
    /// rows (`2·selected·kv_dim` f32) — the bytes this path actually
    /// touches, not the dense equivalent.
    fn prefill_attend_chunk(
        &mut self,
        qs: &[f32],
        n: usize,
        ps: PrefillSparsity,
        out: &mut [f32],
    ) {
        let kvd = self.shape.kv_dim();
        let qd = self.shape.q_dim();
        let d = self.shape.head_dim;
        let len = self.len;
        let start = len - n;
        debug_assert_eq!(self.prefill_keys.len(), len * kvd);
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(qs);
        self.rope.apply_rows_offset(&mut self.scratch_qr, qd, start);
        if len < ps.min_len {
            crate::tensor::ops::causal_attend_chunk(
                &self.scratch_qr,
                &self.prefill_keys,
                &self.prefill_vals,
                n,
                len,
                self.shape.n_heads,
                self.shape.n_kv_heads,
                d,
                &mut self.scratch_chunk_dense,
                out,
            );
            let visible: usize = (0..n).map(|t| start + t + 1).sum();
            self.traffic.read_f32(2 * visible * kvd);
            return;
        }
        self.project_chunk_query(qs, n);
        self.score_panel(); // meters the len·r* panel stream
        let rows = self.select_prefill_blocks(n, ps);
        crate::tensor::ops::block_sparse_attend_chunk(
            &self.scratch_qr,
            &self.prefill_keys,
            &self.prefill_vals,
            n,
            len,
            self.shape.n_heads,
            self.shape.n_kv_heads,
            d,
            &self.scratch_blocks,
            &self.workers,
            &mut self.scratch_bs,
            out,
        );
        self.traffic.read_f32(2 * rows * kvd);
    }

    /// Latent-project a chunk of pre-RoPE keys ((n, kv_dim)) into the
    /// staging buffer as one `K̃ = K·U_r` matmul_tn against Uᵀ.
    fn project_chunk(&mut self, ks: &[f32], n: usize) -> Vec<f32> {
        let kvd = self.shape.kv_dim();
        let r = self.cfg.rank;
        let mut lat = std::mem::take(&mut self.scratch_chunk_lat);
        lat.resize(n * r, 0.0);
        crate::tensor::ops::matmul_tn(ks, &self.u_t.data, &mut lat, n, kvd, r);
        lat
    }

}

impl AttentionBackend for SalsAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        assert_eq!(k.len(), kvd);
        assert_eq!(v.len(), kvd);
        // Latent projection of the pre-RoPE key (Algorithm 1, line 2) into
        // the reusable row buffer, then split into the panels.
        let mut lat = std::mem::take(&mut self.scratch_lat_row);
        lat.resize(self.cfg.rank, 0.0);
        self.projector.project(k, &mut lat);
        self.push_token(&lat, k, v);
        self.scratch_lat_row = lat;
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        assert_eq!(q.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        self.stage_score(q);
        self.stage_select();
        self.stage_attend_fused(q, out);
    }

    fn fork_prefix(&self, n_tokens: usize) -> Option<PrefixSnapshot> {
        if n_tokens == 0 || n_tokens != self.len {
            return None;
        }
        // While block-sparse prefill is live the exact prefill panels are
        // part of the attend-facing state, and an adopter cannot rebuild
        // them from the compressed stores — forks are only offered once
        // `end_prefill` has dropped the panels (decode state is identical
        // either way, so post-prefill forks stay exact).
        if self.cfg.prefill.is_some() && self.prefill_live {
            return None;
        }
        let data = SalsPrefixData {
            latent_score: self.latent_score.fork_arc(),
            latent_rem: self.latent_rem.fork_arc(),
            recent_keys: self.recent_keys.clone(),
            values: self.values.snapshot(),
            traffic: self.traffic,
        };
        let shared_bytes =
            (data.latent_score.len() + data.latent_rem.len()) * 4 + data.values.shared_bytes();
        Some(PrefixSnapshot { n_tokens, shared_bytes, data: Arc::new(data) })
    }

    fn adopt_prefix(&mut self, snap: &PrefixSnapshot) -> bool {
        if self.len != 0 {
            return false;
        }
        let Some(d) = snap.data.downcast_ref::<SalsPrefixData>() else {
            return false;
        };
        let rs = self.cfg.r_star;
        let rem = self.cfg.rank - rs;
        if d.latent_score.len() != snap.n_tokens * rs
            || d.latent_rem.len() != snap.n_tokens * rem
            || d.recent_keys.len() != self.recent_keys.len()
            || d.values.len() != snap.n_tokens
        {
            return false;
        }
        self.latent_score = SharedVec::from_shared(Arc::clone(&d.latent_score));
        self.latent_rem = SharedVec::from_shared(Arc::clone(&d.latent_rem));
        self.recent_keys.copy_from_slice(&d.recent_keys);
        self.values.adopt(&d.values);
        self.len = snap.n_tokens;
        self.traffic = d.traffic;
        // Forks are gated on the donor having ended (or never run) sparse
        // prefill, so the adopter starts in plain decode state.
        self.prefill_live = false;
        true
    }

    fn shared_prefix_bytes(&self) -> usize {
        self.latent_score.shared_bytes()
            + self.latent_rem.shared_bytes()
            + self.values.shared_bytes()
    }

    fn set_workers(&mut self, workers: &Workers) {
        self.workers = workers.clone();
    }

    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        let kvd = self.shape.kv_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        let r = self.cfg.rank;
        let lat = self.project_chunk(ks, n);
        for t in 0..n {
            self.push_token(
                &lat[t * r..(t + 1) * r],
                &ks[t * kvd..(t + 1) * kvd],
                &vs[t * kvd..(t + 1) * kvd],
            );
        }
        self.scratch_chunk_lat = lat;
    }

    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        let kvd = self.shape.kv_dim();
        let qd = self.shape.q_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        assert_eq!(qs.len(), n * qd);
        assert_eq!(out.len(), n * qd);
        let r = self.cfg.rank;
        // Block-sparse prefill engages only while the exact panels cover
        // the whole cache (push_token keeps them covering as long as
        // `prefill_live`); any gap falls back to the dense path.
        let sparse = match self.cfg.prefill {
            Some(ps) if self.prefill_live && self.prefill_keys.len() == self.len * kvd => {
                Some(ps)
            }
            _ => None,
        };
        let lat = self.project_chunk(ks, n);
        if let Some(ps) = sparse {
            // Push the whole chunk's state first: the chunk attends
            // against the exact prefill panels (not the position-relative
            // ring/quant window), so no interleaving is needed, and the
            // decode-facing stores evolve through the same push sequence
            // as the dense path — decode state is path-independent.
            for t in 0..n {
                self.push_token(
                    &lat[t * r..(t + 1) * r],
                    &ks[t * kvd..(t + 1) * kvd],
                    &vs[t * kvd..(t + 1) * kvd],
                );
            }
            self.scratch_chunk_lat = lat;
            self.prefill_attend_chunk(qs, n, ps, out);
        } else {
            // Chunk-level batched projection; per-token state pushes +
            // attends (see module docs: the recent ring / high-precision
            // window are position-relative, so interleaving is what
            // preserves exactness).
            for t in 0..n {
                self.push_token(
                    &lat[t * r..(t + 1) * r],
                    &ks[t * kvd..(t + 1) * kvd],
                    &vs[t * kvd..(t + 1) * kvd],
                );
                self.attend(&qs[t * qd..(t + 1) * qd], &mut out[t * qd..(t + 1) * qd]);
            }
            self.scratch_chunk_lat = lat;
        }
    }

    fn end_prefill(&mut self) {
        // Chunk-latent staging is (chunk, r) — small, but decode never
        // touches it; release for symmetry with FullAttention.
        self.scratch_chunk_lat = Vec::new();
        // The sparse-prefill panels scale with the full cache (2·len·kvd
        // floats — exactly the dense cache SALS exists to avoid); decode
        // reads the latent/quant stores, so drop them and the chunk-sized
        // kernel scratch, and stop maintaining the panels on future
        // pushes.
        self.prefill_live = false;
        self.prefill_keys = Vec::new();
        self.prefill_vals = Vec::new();
        self.scratch_bs = crate::tensor::ops::BlockSparseScratch::default();
        self.scratch_chunk_dense = crate::tensor::ops::ChunkAttendScratch::default();
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        (self.latent_score.len() + self.latent_rem.len()) * 4
            + self.recent_keys.len() * 4
            + self.values.nbytes()
    }

    fn footprint(&self) -> FootprintModel {
        // Latent panels together grow at rank·4 B/token; values at the
        // quant store's frozen rate. Fixed: the pre-allocated fp32
        // recent-key ring plus the expected excess of the store's fp32
        // tail over the frozen rate — length-independent terms, so the
        // asymptotic rate reflects the §5.1 compression ratio admission is
        // meant to exploit.
        FootprintModel::linear(
            self.recent_cap * self.shape.kv_dim() * 4 + self.values.tail_excess_bytes(),
            self.cfg.rank * 4 + self.values.frozen_row_bytes(),
        )
    }

    fn name(&self) -> &'static str {
        "sals"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::lowrank::Calibrator;
    use crate::util::rng::Rng;

    /// Build a projector from keys with global low-rank structure.
    fn make_projector(kv_dim: usize, rank: usize, true_rank: usize, rng: &mut Rng) -> Projector {
        let basis: Vec<Vec<f32>> = (0..true_rank).map(|_| rng.normal_vec(kv_dim, 1.0)).collect();
        let mut cal = Calibrator::new(kv_dim);
        let mut row = vec![0.0f32; kv_dim];
        for _ in 0..600 {
            row.fill(0.0);
            for b in &basis {
                let c = rng.normal_f32();
                crate::tensor::ops::axpy(c, b, &mut row);
            }
            for v in row.iter_mut() {
                *v += rng.normal_f32() * 0.02;
            }
            cal.add_key(&row);
        }
        cal.fit(rank).unwrap()
    }

    /// Draw a key from the same low-rank family used in make_projector.
    fn lowrank_sampler(kv_dim: usize, true_rank: usize, seed: u64) -> impl FnMut(&mut Rng) -> Vec<f32> {
        let mut brng = Rng::new(seed);
        let basis: Vec<Vec<f32>> = (0..true_rank).map(|_| brng.normal_vec(kv_dim, 1.0)).collect();
        move |rng: &mut Rng| {
            let mut row = vec![0.0f32; kv_dim];
            for b in &basis {
                let c = rng.normal_f32();
                crate::tensor::ops::axpy(c, b, &mut row);
            }
            row
        }
    }

    fn cfg_small(rank: usize) -> SalsConfig {
        SalsConfig {
            rank,
            r_star: rank / 2,
            sink: 2,
            recent: 8,
            critical: 16,
            v_bits: Bits::B4,
            group: 8,
            prefill: None,
        }
    }

    #[test]
    fn matches_full_attention_when_selection_covers_all() {
        // critical >= seq and exact projector rank -> SALS == full attention.
        let shape = AttnShape::mha(2, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(71);
        // Full-rank projector (rank == dim): reconstruction is exact.
        let mut cal = Calibrator::new(kvd);
        for _ in 0..200 {
            cal.add_key(&rng.normal_vec(kvd, 1.0));
        }
        let proj = cal.fit(kvd).unwrap();
        let cfg = SalsConfig {
            rank: kvd,
            r_star: kvd,
            sink: 0,
            recent: 64, // whole sequence high-precision -> values exact too
            critical: 64,
            v_bits: Bits::B8,
            group: 8,
            prefill: None,
        };
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let mut full = FullAttention::new(shape);
        for _ in 0..30 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        sals.attend(&q, &mut o1);
        full.attend(&q, &mut o2);
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn close_to_full_on_low_rank_keys() {
        let shape = AttnShape::mha(2, 8, 256);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(73);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut sample = lowrank_sampler(kvd, 4, 73);
        let mut sals = SalsAttention::new(shape, cfg_small(8), proj);
        let mut full = FullAttention::new(shape);
        for _ in 0..100 {
            let k = sample(&mut rng);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        sals.attend(&q, &mut o1);
        full.attend(&q, &mut o2);
        let err = crate::util::stats::rel_l2(&o1, &o2);
        assert!(err < 0.35, "rel err {err}");
        let cos = crate::util::stats::cosine(&o1, &o2);
        assert!(cos > 0.9, "cosine {cos}");
    }

    #[test]
    fn split_panels_hold_leading_and_trailing_latent_dims() {
        // The scoring panel must hold exactly each projected row's leading
        // r* dims and the remainder panel the trailing r - r* dims.
        let shape = AttnShape::mha(1, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(85);
        let proj = make_projector(kvd, 6, 4, &mut rng);
        let cfg = SalsConfig { rank: 6, r_star: 4, ..cfg_small(6) };
        let mut sals = SalsAttention::new(shape, cfg, proj.clone());
        let mut keys = Vec::new();
        for _ in 0..20 {
            let k = rng.normal_vec(kvd, 1.0);
            keys.push(k.clone());
            sals.append(&k, &rng.normal_vec(kvd, 1.0));
        }
        let mut lat = vec![0.0f32; proj.rank];
        for (j, k) in keys.iter().enumerate() {
            proj.project(k, &mut lat);
            for (c, &v) in lat[..4].iter().enumerate() {
                let p = sals.latent_score[j * 4 + c];
                assert!((p - v).abs() < 1e-5, "score panel row {j} dim {c}: {p} vs {v}");
            }
            for (c, &v) in lat[4..6].iter().enumerate() {
                let p = sals.latent_rem[j * 2 + c];
                assert!((p - v).abs() < 1e-5, "rem panel row {j} dim {c}: {p} vs {v}");
            }
        }
        // And scoring streams the panel: scores == q̃[..r*] · panel rows.
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let scores = sals.latent_scores(&q);
        proj.project(&q, &mut lat);
        for (j, &s) in scores.iter().enumerate() {
            let expect = crate::tensor::ops::dot(&lat[..4], sals.latent_score.row(j * 4, 4));
            assert!((s - expect).abs() < 1e-5, "score {j}: {s} vs {expect}");
        }
    }

    #[test]
    fn traffic_much_lower_than_full() {
        let shape = AttnShape::mha(4, 16, 1024);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(75);
        let proj = make_projector(kvd, kvd / 4, 8, &mut rng);
        let cfg = SalsConfig::sals_25(kvd, 4, 32, 16);
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let mut full = FullAttention::new(shape);
        let mut sample = lowrank_sampler(kvd, 8, 75);
        for _ in 0..512 {
            let k = sample(&mut rng);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0; shape.q_dim()];
        let s0 = sals.traffic();
        sals.attend(&q, &mut out);
        let f0 = full.traffic();
        full.attend(&q, &mut out);
        let sals_read = sals.traffic().read - s0.read;
        let full_read = full.traffic().read - f0.read;
        assert!(
            (sals_read as f64) < full_read as f64 / 4.0,
            "sals {sals_read} vs full {full_read}"
        );
    }

    #[test]
    fn cache_bytes_compressed() {
        let shape = AttnShape::mha(4, 16, 512);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(77);
        let proj = make_projector(kvd, kvd / 4, 8, &mut rng);
        let cfg = SalsConfig::sals_25(kvd, 4, 32, 16);
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let mut full = FullAttention::new(shape);
        for _ in 0..256 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        // Paper Table 2: SALS-25% comp ratio 0.28 vs fp16 baseline.
        // Ours is fp32-relative; latents (r=kvd/4) + 4-bit values + windows
        // must land well under 50% of the dense cache.
        assert!(
            sals.kv_bytes() * 2 < full.kv_bytes(),
            "sals {} vs full {}",
            sals.kv_bytes(),
            full.kv_bytes()
        );
    }

    #[test]
    fn selection_includes_sink_and_recent() {
        let shape = AttnShape::mha(1, 8, 128);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(79);
        let proj = make_projector(kvd, 4, 4, &mut rng);
        let cfg = SalsConfig {
            rank: 4,
            r_star: 2,
            sink: 2,
            recent: 4,
            critical: 2,
            v_bits: Bits::B4,
            group: 4,
            prefill: None,
        };
        let mut sals = SalsAttention::new(shape, cfg, proj);
        for _ in 0..50 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let scores = sals.latent_scores(&q);
        let idx = crate::tensor::top_k_indices(&scores, 2);
        let sel = crate::attention::merge_selection(50, 2, 4, &idx);
        assert!(sel.contains(&0) && sel.contains(&1), "sink missing: {sel:?}");
        for t in 46..50 {
            assert!(sel.contains(&t), "recent {t} missing: {sel:?}");
        }
    }

    #[test]
    fn batched_forward_matches_sequential_loop() {
        // The staged batched path must track the sequential state machine:
        // same stores, same traffic, same outputs (modulo the one-matmul
        // projection's fp reordering, ~1e-7).
        let shape = AttnShape::mha(2, 8, 256);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(83);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut sample = lowrank_sampler(kvd, 4, 83);
        // critical covers the whole sequence so the comparison is immune to
        // top-k order flips from the ~1e-7 projection-reordering jitter;
        // ring wraps and quant-group boundaries are still fully exercised.
        let cfg = SalsConfig { critical: 64, ..cfg_small(8) };
        let mut seq = SalsAttention::new(shape, cfg.clone(), proj.clone());
        let mut bat = SalsAttention::new(shape, cfg, proj);
        // Warm prefix through the scalar path on both.
        for _ in 0..6 {
            let k = sample(&mut rng);
            let v = rng.normal_vec(kvd, 1.0);
            seq.append(&k, &v);
            bat.append(&k, &v);
        }
        let n = 40; // spans several quant groups and ring wraps
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for _ in 0..n {
            ks.extend(sample(&mut rng));
            vs.extend(rng.normal_vec(kvd, 1.0));
        }
        let qs = rng.normal_vec(n * shape.q_dim(), 1.0);
        let qd = shape.q_dim();
        let mut o_seq = vec![0.0f32; n * qd];
        for t in 0..n {
            seq.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            seq.attend(&qs[t * qd..(t + 1) * qd], &mut o_seq[t * qd..(t + 1) * qd]);
        }
        let mut o_bat = vec![0.0f32; n * qd];
        bat.forward_batch(&ks, &vs, &qs, n, &mut o_bat);
        assert_eq!(seq.len, bat.len);
        assert_eq!(seq.kv_bytes(), bat.kv_bytes());
        for (a, b) in o_seq.iter().zip(&o_bat) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // Both split panels must agree between the two paths.
        for (a, b) in seq.latent_score.iter().zip(bat.latent_score.iter()) {
            assert!((a - b).abs() < 1e-4, "score panel {a} vs {b}");
        }
        for (a, b) in seq.latent_rem.iter().zip(bat.latent_rem.iter()) {
            assert!((a - b).abs() < 1e-4, "rem panel {a} vs {b}");
        }
    }

    #[test]
    fn append_batch_matches_append_loop() {
        let shape = AttnShape::mha(1, 8, 128);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(89);
        let proj = make_projector(kvd, 4, 4, &mut rng);
        let cfg = cfg_small(4);
        let mut a = SalsAttention::new(shape, cfg.clone(), proj.clone());
        let mut b = SalsAttention::new(shape, cfg, proj);
        let n = 17;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        a.append_batch(&ks, &vs, n);
        for t in 0..n {
            b.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
        assert_eq!(a.len, b.len);
        assert_eq!(a.kv_bytes(), b.kv_bytes());
        assert_eq!(a.traffic().written, b.traffic().written);
        for (x, y) in a.latent_score.iter().zip(b.latent_score.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        for (x, y) in a.latent_rem.iter().zip(b.latent_rem.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        assert_eq!(a.recent_keys, b.recent_keys);
    }

    #[test]
    fn instrumented_attend_matches_plain_attend() {
        let shape = AttnShape::mha(2, 8, 256);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(87);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut a = SalsAttention::new(shape, cfg_small(8), proj.clone());
        let mut b = SalsAttention::new(shape, cfg_small(8), proj);
        for _ in 0..60 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            a.append(&k, &v);
            b.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        let mut times = SalsStageTimes::default();
        a.attend(&q, &mut o1);
        b.attend_instrumented(&q, &mut o2, &mut times);
        assert_eq!(o1, o2, "instrumentation must not change the math");
        assert_eq!(a.traffic(), b.traffic(), "or the metering");
        assert!(times.total() > 0.0 && times.total().is_finite());
        assert_eq!(times.reconstruct, 0.0, "fused path has no separate reconstruct stage");
        // Staged probe vs staged path, same contract.
        let mut o3 = vec![0.0; shape.q_dim()];
        let mut o4 = vec![0.0; shape.q_dim()];
        let mut st = SalsStageTimes::default();
        a.attend_staged(&q, &mut o3);
        b.attend_staged_instrumented(&q, &mut o4, &mut st);
        assert_eq!(o3, o4);
        assert_eq!(a.traffic(), b.traffic());
        assert!(st.reconstruct > 0.0, "staged probe must time the reconstruct stage");
    }

    #[test]
    fn fused_attend_matches_staged_and_meters_identically() {
        // The fused production path vs the PR-4 staged reference on the
        // same state: ≤1e-4 outputs (only fp summation order differs —
        // online softmax vs materialized softmax) and bit-equal traffic
        // meters — exact equality holds because the selection here fits
        // ONE kernel tile (sink 2 + critical 16 + recent 8 = 26 ≤
        // FUSED_TILE); multi-tile selections may legitimately meter MORE
        // on the fused path (boundary pages' params per touched tile).
        // GQA shape so per-head Uᵀ blocks, per-head value slices, and
        // query-group tiles are all exercised; 60 tokens wraps the 8-row
        // ring and crosses quant-group boundaries (group 8).
        let shape = AttnShape::gqa(4, 2, 8, 256);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(97);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut fused = SalsAttention::new(shape, cfg_small(8), proj.clone());
        let mut staged = SalsAttention::new(shape, cfg_small(8), proj);
        for _ in 0..60 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            fused.append(&k, &v);
            staged.append(&k, &v);
        }
        let t_fused0 = fused.traffic();
        let t_staged0 = staged.traffic();
        let qd = shape.q_dim();
        let max_sel = 2 + 16 + 8; // sink + critical + recent of cfg_small
        assert!(max_sel <= crate::tensor::ops::FUSED_TILE, "premise: single-tile selection");
        for step in 0..3 {
            let q = rng.normal_vec(qd, 1.0);
            let mut of = vec![0.0; qd];
            let mut os = vec![0.0; qd];
            fused.attend(&q, &mut of);
            staged.attend_staged(&q, &mut os);
            for (a, b) in of.iter().zip(&os) {
                assert!((a - b).abs() < 1e-4, "step {step}: {a} vs {b}");
            }
        }
        let df = fused.traffic();
        let ds = staged.traffic();
        assert_eq!(df.read - t_fused0.read, ds.read - t_staged0.read, "read meters must agree");
        assert_eq!(df.written, ds.written);
    }

    #[test]
    fn fused_attend_output_is_thread_invariant() {
        // Per-unit passes (KV-head panels, split-KV segments, score-scan
        // blocks) compute fixed arithmetic no matter which worker runs
        // them and merge in fixed order, so the fused output must be
        // BIT-identical for any worker-handle width and pool size.
        // Sized past both parallel guards: len 4160 ≥ SCORE_PAR_MIN_LEN,
        // and n_sel·(r+group)·d = (4 + 900 + 16)·(8+2)·8 ≈ 74K ≥
        // FUSED_PAR_MIN_WORK. The shape (n_kv_heads=2, n_sel ≈ 920 ≥ 128)
        // also engages the split-KV segment decomposition, so this pins
        // the split path through the full SALS stack.
        let shape = AttnShape::gqa(4, 2, 8, 4200);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(101);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let cfg = SalsConfig {
            rank: 8,
            r_star: 4,
            sink: 4,
            recent: 16,
            critical: 900,
            v_bits: Bits::B4,
            group: 8,
            prefill: None,
        };
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let n = 4160;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        sals.append_batch(&ks, &vs, n);
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut reference = vec![0.0; shape.q_dim()];
        sals.set_workers(&Workers::serial());
        sals.attend(&q, &mut reference);
        let handles = [
            Workers::scoped(2),
            Workers::scoped(8),
            Workers::pooled(1),
            Workers::pooled(2),
            Workers::pooled(8),
        ];
        for workers in &handles {
            sals.set_workers(workers);
            let mut out = vec![0.0; shape.q_dim()];
            sals.attend(&q, &mut out);
            assert_eq!(out, reference, "{workers:?} must be bit-identical");
        }
    }

    #[test]
    fn sparse_prefill_tau_one_matches_dense_fallback() {
        // τ = 1.0 selects every block, so the block-sparse kernel and the
        // dense fallback attend the same set — outputs must agree ≤1e-4
        // (only the online-softmax fold's fp order differs). Chunk sizes
        // that don't divide the length and a block that doesn't divide
        // the cache are both exercised.
        let shape = AttnShape::gqa(4, 2, 8, 256);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(111);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let sparse_cfg = SalsConfig {
            prefill: Some(PrefillSparsity { block: 16, tau: 1.0, top_blocks: 0, min_len: 0 }),
            ..cfg_small(8)
        };
        let dense_cfg = SalsConfig {
            prefill: Some(PrefillSparsity {
                block: 16,
                tau: 1.0,
                top_blocks: 0,
                min_len: usize::MAX,
            }),
            ..cfg_small(8)
        };
        let mut sparse = SalsAttention::new(shape, sparse_cfg, proj.clone());
        let mut dense = SalsAttention::new(shape, dense_cfg, proj);
        for n in [48usize, 29, 17] {
            let ks = rng.normal_vec(n * kvd, 1.0);
            let vs = rng.normal_vec(n * kvd, 1.0);
            let qs = rng.normal_vec(n * qd, 1.0);
            let mut o_sparse = vec![0.0f32; n * qd];
            let mut o_dense = vec![0.0f32; n * qd];
            sparse.forward_batch(&ks, &vs, &qs, n, &mut o_sparse);
            dense.forward_batch(&ks, &vs, &qs, n, &mut o_dense);
            for (a, b) in o_sparse.iter().zip(&o_dense) {
                assert!((a - b).abs() < 1e-4, "chunk n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_prefill_keeps_sink_and_diagonal_blocks() {
        // Even at a τ that would select almost nothing, the sink blocks
        // and every block overlapping [start − recent, len) must survive.
        let shape = AttnShape::gqa(4, 2, 8, 256);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(113);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let cfg = SalsConfig {
            prefill: Some(PrefillSparsity { block: 8, tau: 0.01, top_blocks: 1, min_len: 0 }),
            ..cfg_small(8)
        };
        let mut sals = SalsAttention::new(shape, cfg, proj);
        for n in [64usize, 16] {
            let ks = rng.normal_vec(n * kvd, 1.0);
            let vs = rng.normal_vec(n * kvd, 1.0);
            let qs = rng.normal_vec(n * qd, 1.0);
            let mut out = vec![0.0f32; n * qd];
            sals.forward_batch(&ks, &vs, &qs, n, &mut out);
        }
        // After the second chunk: len 80, start 64, sink 2, recent 8.
        let covered = |p: usize| sals.scratch_blocks.iter().any(|&(lo, hi)| lo <= p && p < hi);
        for p in 0..2 {
            assert!(covered(p), "sink token {p} not covered: {:?}", sals.scratch_blocks);
        }
        for p in 56..80 {
            assert!(covered(p), "diagonal/recent token {p} not covered: {:?}", sals.scratch_blocks);
        }
        // Ranges are sorted and disjoint (the kernel's precondition).
        for w in sals.scratch_blocks.windows(2) {
            assert!(w[0].1 <= w[1].0, "ranges overlap: {:?}", sals.scratch_blocks);
        }
    }

    #[test]
    fn sparse_prefill_leaves_decode_state_identical_to_dense_prefill() {
        // The sparse path pushes the same token sequence through the same
        // stores (only the chunk attends differ), so after end_prefill the
        // decode-facing state — latent panels, ring, quant store — must be
        // BIT-identical to the dense prefill path, and decode attends must
        // agree exactly.
        let shape = AttnShape::gqa(4, 2, 8, 256);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(115);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let sparse_cfg = SalsConfig {
            prefill: Some(PrefillSparsity { block: 16, tau: 0.9, top_blocks: 0, min_len: 0 }),
            ..cfg_small(8)
        };
        let dense_cfg = cfg_small(8);
        let mut sparse = SalsAttention::new(shape, sparse_cfg, proj.clone());
        let mut dense = SalsAttention::new(shape, dense_cfg, proj);
        for n in [40usize, 23] {
            let ks = rng.normal_vec(n * kvd, 1.0);
            let vs = rng.normal_vec(n * kvd, 1.0);
            let qs = rng.normal_vec(n * qd, 1.0);
            let mut o1 = vec![0.0f32; n * qd];
            let mut o2 = vec![0.0f32; n * qd];
            sparse.forward_batch(&ks, &vs, &qs, n, &mut o1);
            dense.forward_batch(&ks, &vs, &qs, n, &mut o2);
        }
        sparse.end_prefill();
        dense.end_prefill();
        assert!(sparse.prefill_keys.is_empty(), "end_prefill must drop the panels");
        assert_eq!(sparse.latent_score, dense.latent_score);
        assert_eq!(sparse.latent_rem, dense.latent_rem);
        assert_eq!(sparse.recent_keys, dense.recent_keys);
        assert_eq!(sparse.kv_bytes(), dense.kv_bytes());
        let q = rng.normal_vec(qd, 1.0);
        let mut d1 = vec![0.0f32; qd];
        let mut d2 = vec![0.0f32; qd];
        sparse.attend(&q, &mut d1);
        dense.attend(&q, &mut d2);
        assert_eq!(d1, d2, "decode after prefill must be path-independent");
    }

    #[test]
    fn fork_adopt_decode_bit_identical_to_cold() {
        // Donor and a cold control ingest the same 29 tokens: wraps the
        // 8-row recent ring 3×, and 29 % group(8) = 5 leaves a partial
        // quant group in the fp32 tail — both boundaries cross the fork.
        // The adopter must then decode BIT-identically to the control,
        // with equal kv_bytes and traffic meters (the engine's accounting
        // and the bench's parity check both rely on this exactness).
        let shape = AttnShape::gqa(4, 2, 8, 128);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(117);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let cfg = cfg_small(8);
        let mut donor = SalsAttention::new(shape, cfg.clone(), proj.clone());
        let mut cold = SalsAttention::new(shape, cfg.clone(), proj.clone());
        let n = 29;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        donor.append_batch(&ks, &vs, n);
        cold.append_batch(&ks, &vs, n);
        assert!(donor.fork_prefix(n - 1).is_none(), "interior forks unsupported");
        let snap = donor.fork_prefix(n).expect("fork at full length");
        let mut adopter = SalsAttention::new(shape, cfg, proj);
        assert!(adopter.adopt_prefix(&snap));
        assert_eq!(adopter.len(), n);
        assert_eq!(adopter.kv_bytes(), cold.kv_bytes());
        assert_eq!(adopter.traffic(), cold.traffic());
        assert!(adopter.shared_prefix_bytes() > 0, "panels must be held by reference");
        // 10 decode steps span a quant-group freeze and more ring wraps.
        for step in 0..10 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            let q = rng.normal_vec(qd, 1.0);
            adopter.append(&k, &v);
            cold.append(&k, &v);
            let mut oa = vec![0.0f32; qd];
            let mut oc = vec![0.0f32; qd];
            adopter.attend(&q, &mut oa);
            cold.attend(&q, &mut oc);
            assert_eq!(oa, oc, "decode step {step} diverged from cold prefill");
        }
        assert_eq!(adopter.kv_bytes(), cold.kv_bytes());
        assert_eq!(adopter.traffic(), cold.traffic());
        // Donor is untouched by its adopters.
        assert_eq!(donor.len(), n);
        // An adopter that has appended past the boundary can itself be
        // forked at its new full length (shared prefix + private tail are
        // materialized into a fresh publication).
        let snap2 = adopter.fork_prefix(n + 10).expect("refork after appends");
        assert_eq!(snap2.n_tokens, n + 10);
    }

    #[test]
    fn fork_gated_while_sparse_prefill_live() {
        // Live block-sparse prefill keeps exact panels an adopter cannot
        // rebuild — fork_prefix must refuse until end_prefill drops them.
        let shape = AttnShape::mha(2, 8, 128);
        let kvd = shape.kv_dim();
        let qd = shape.q_dim();
        let mut rng = Rng::new(119);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let cfg = SalsConfig {
            prefill: Some(PrefillSparsity { block: 8, tau: 1.0, top_blocks: 0, min_len: 0 }),
            ..cfg_small(8)
        };
        let mut b = SalsAttention::new(shape, cfg, proj);
        let n = 16;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        let qs = rng.normal_vec(n * qd, 1.0);
        let mut out = vec![0.0f32; n * qd];
        b.forward_batch(&ks, &vs, &qs, n, &mut out);
        assert!(b.fork_prefix(n).is_none(), "live prefill panels must gate forks");
        b.end_prefill();
        assert!(b.fork_prefix(n).is_some(), "post-prefill forks are exact");
    }

    #[test]
    fn gqa_query_pooling_runs() {
        let shape = AttnShape::gqa(4, 2, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(81);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut sals = SalsAttention::new(shape, cfg_small(8), proj);
        for _ in 0..20 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0; shape.q_dim()];
        sals.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}

//! SALS decode attention (Algorithm 1): latent KV cache, critical-token
//! selection in latent space, selective reconstruction + RoPE, exact sparse
//! attention.
//!
//! Per decode step:
//! 1. `k̃ = U_rᵀ k` — append the new token's key as an r-dim latent
//!    (pre-RoPE, §3.1: post-RoPE keys have higher effective rank); values go
//!    to the channel-wise group-quantized store with an fp32 recent window.
//! 2. `s_j = q̃[:r*] · k̃_j[:r*]` — cheap RoPE-free scores over the whole
//!    latent cache using only the leading r* latent dims (§4.3).
//! 3. `C = sink ∪ recent ∪ top-k(s)` — critical-token set (§5.2 layout).
//! 4. `K_C = K̃_C U_rᵀ`, RoPE(K_C), RoPE(q) — reconstruct only |C| keys.
//!    Recent-window keys are kept fp32 and skip reconstruction (the paper's
//!    half-compressed high-precision window; exactness is the limit case).
//! 5. Exact softmax attention over (K_C, V_C) per head (Eq. 5).
//!
//! GQA: the latent space is calibrated on stacked **KV-head** keys
//! (kv_dim = n_kv_heads·head_dim). Queries are mean-pooled per KV group to
//! kv_dim before projection — the single-head shared-latent analogue for
//! grouped queries (documented in DESIGN.md §3).
//!
//! Batched prefill: `append_batch`/`forward_batch` compute the whole
//! chunk's latent projection as **one** `K̃ = K·U_r` [`crate::tensor::ops::matmul_tn`]
//! instead of n per-row projections. `forward_batch` keeps the *state*
//! pushes interleaved with the attends — the fp32 recent-key ring and the
//! quant store's high-precision window are position-relative, so evolving
//! them token-by-token is what keeps the batched path bit-compatible with
//! sequential decode. (`prefill_attend` deliberately keeps the n == 1
//! default: with a whole chunk pre-appended, tokens that a mid-chunk query
//! should see at full precision may already have been evicted from the
//! ring by later chunk rows.)

use super::{merge_selection, AttentionBackend, AttnShape, FootprintModel, Traffic};
use crate::lowrank::Projector;
use crate::quant::{Bits, TokenQuantStore};
use crate::rope::RopeTable;
use crate::tensor::top_k_indices_into;

/// SALS hyper-parameters (§5.1/§5.2 defaults).
#[derive(Clone, Debug)]
pub struct SalsConfig {
    /// Latent rank r (compression d_r = r / kv_dim).
    pub rank: usize,
    /// Scoring rank r* (paper: r/2).
    pub r_star: usize,
    /// Sink tokens always kept (x).
    pub sink: usize,
    /// Recent window always kept + stored high-precision (z / w).
    pub recent: usize,
    /// Critical-token budget for top-k (y).
    pub critical: usize,
    /// Value-cache quantization bits (4 at 25%, 2 at 12.5%).
    pub v_bits: Bits,
    /// Quantization group size along the token axis.
    pub group: usize,
}

impl SalsConfig {
    /// Paper's SALS-25% setting for a given kv_dim: r = kv_dim/4, r* = r/2,
    /// 4-bit values.
    pub fn sals_25(kv_dim: usize, sink: usize, critical: usize, recent: usize) -> SalsConfig {
        SalsConfig {
            rank: kv_dim / 4,
            r_star: kv_dim / 8,
            sink,
            recent,
            critical,
            v_bits: Bits::B4,
            group: 32,
        }
    }

    /// Paper's SALS-12.5% setting: r = kv_dim/8, r* = r/2, 2-bit values.
    pub fn sals_125(kv_dim: usize, sink: usize, critical: usize, recent: usize) -> SalsConfig {
        SalsConfig {
            rank: kv_dim / 8,
            r_star: kv_dim / 16,
            sink,
            recent,
            critical,
            v_bits: Bits::B2,
            group: 32,
        }
    }
}

/// SALS attention backend for one layer.
pub struct SalsAttention {
    shape: AttnShape,
    cfg: SalsConfig,
    projector: Projector,
    /// Uᵀ (rank, kv_dim) row-major — reconstruction as a blocked matmul
    /// with a unit-stride kv_dim inner loop (§Perf L3 iteration 3; the
    /// per-row rank-length dots were the decode-op bottleneck).
    u_t: crate::tensor::Mat,
    rope: RopeTable,
    /// (len, rank) pre-RoPE latent keys.
    latent_keys: Vec<f32>,
    /// fp32 pre-RoPE keys for the recent window (ring buffer of
    /// `recent + 1` rows, indexed by absolute position % capacity).
    recent_keys: Vec<f32>,
    recent_cap: usize,
    /// Quantized value store (fp32 recent window inside).
    values: TokenQuantStore,
    len: usize,
    traffic: Traffic,
    // ---- scratch buffers (hot path must not allocate) ----
    scratch_scores: Vec<f32>,
    scratch_idx: Vec<usize>,
    scratch_qlat: Vec<f32>,
    scratch_pool: Vec<f32>,
    scratch_keys: Vec<f32>,
    scratch_vals: Vec<f32>,
    scratch_lat: Vec<f32>,
    scratch_qr: Vec<f32>,
    /// Chunk-latent staging buffer for the batched prefill path (kept
    /// separate from `scratch_lat`, which `attend` overwrites per token).
    scratch_chunk_lat: Vec<f32>,
}

impl SalsAttention {
    /// `projector` must be calibrated on stacked pre-RoPE KV-head keys of
    /// dimension `shape.kv_dim()`.
    pub fn new(shape: AttnShape, cfg: SalsConfig, projector: Projector) -> SalsAttention {
        assert_eq!(projector.dim, shape.kv_dim(), "projector dim != kv_dim");
        assert!(cfg.rank <= projector.rank, "config rank exceeds projector rank");
        assert!(cfg.r_star <= cfg.rank, "r* must be <= r");
        let rope = RopeTable::new(shape.head_dim, shape.max_seq, shape.rope_base);
        let recent_cap = cfg.recent.max(1);
        let values = TokenQuantStore::new(shape.kv_dim(), cfg.v_bits, cfg.group, cfg.recent.max(cfg.group));
        // Uᵀ truncated to the configured rank.
        let mut u_t = crate::tensor::Mat::zeros(cfg.rank, shape.kv_dim());
        for i in 0..shape.kv_dim() {
            for j in 0..cfg.rank {
                u_t.data[j * shape.kv_dim() + i] = projector.u.data[i * projector.rank + j];
            }
        }
        SalsAttention {
            shape,
            projector,
            u_t,
            rope,
            latent_keys: Vec::new(),
            recent_keys: vec![0.0; recent_cap * shape.kv_dim()],
            recent_cap,
            values,
            len: 0,
            traffic: Traffic::default(),
            scratch_scores: Vec::new(),
            scratch_idx: Vec::new(),
            scratch_qlat: vec![0.0; cfg.rank],
            scratch_pool: vec![0.0; shape.kv_dim()],
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
            scratch_lat: Vec::new(),
            scratch_qr: Vec::new(),
            scratch_chunk_lat: Vec::new(),
            cfg,
        }
    }

    /// Latent scores of every cached token for a pre-RoPE query — exposed
    /// for the Figure-2 overlap-score analysis.
    pub fn latent_scores(&mut self, q: &[f32]) -> Vec<f32> {
        self.compute_scores(q);
        self.scratch_scores.clone()
    }

    /// Pool query heads per KV group (mean) then project to latent space.
    fn project_query(&mut self, q: &[f32]) {
        let d = self.shape.head_dim;
        let group = self.shape.group_size();
        let kvd = self.shape.kv_dim();
        if group == 1 {
            self.scratch_pool[..kvd].copy_from_slice(q);
        } else {
            let inv = 1.0 / group as f32;
            self.scratch_pool.fill(0.0);
            for h in 0..self.shape.n_heads {
                let kvh = h / group;
                let qh = &q[h * d..(h + 1) * d];
                let dst = &mut self.scratch_pool[kvh * d..(kvh + 1) * d];
                for (a, &b) in dst.iter_mut().zip(qh) {
                    *a += b * inv;
                }
            }
        }
        let pool = std::mem::take(&mut self.scratch_pool);
        self.projector.project(&pool, &mut self.scratch_qlat);
        self.scratch_pool = pool;
    }

    /// Fill scratch_scores with r*-dim latent scores for all cached tokens.
    fn compute_scores(&mut self, q: &[f32]) {
        self.project_query(q);
        let r = self.cfg.rank;
        let rs = self.cfg.r_star;
        self.scratch_scores.clear();
        self.scratch_scores.reserve(self.len);
        let qlat = &self.scratch_qlat[..rs];
        for j in 0..self.len {
            let krow = &self.latent_keys[j * r..j * r + rs];
            self.scratch_scores.push(crate::tensor::ops::dot(qlat, krow));
        }
        self.traffic.read_f32(self.len * rs);
    }

    fn recent_slot(&self, pos: usize) -> usize {
        pos % self.recent_cap
    }

    /// Push one token whose latent row is already computed: latent store,
    /// fp32 recent-key ring, quantized values, write-traffic metering.
    /// Shared by the batched paths (which project whole chunks at once).
    fn push_token(&mut self, lat_row: &[f32], k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        debug_assert_eq!(lat_row.len(), self.cfg.rank);
        let pos = self.len;
        self.latent_keys.extend_from_slice(lat_row);
        self.traffic.write_f32(self.cfg.rank);
        let slot = self.recent_slot(pos);
        self.recent_keys[slot * kvd..(slot + 1) * kvd].copy_from_slice(k);
        self.values.append(v);
        self.traffic.write_bytes(self.values.row_read_bytes(pos));
        self.len += 1;
    }

    /// Latent-project a chunk of pre-RoPE keys ((n, kv_dim)) into the
    /// staging buffer as one `K̃ = K·U_r` matmul_tn against Uᵀ.
    fn project_chunk(&mut self, ks: &[f32], n: usize) -> Vec<f32> {
        let kvd = self.shape.kv_dim();
        let r = self.cfg.rank;
        let mut lat = std::mem::take(&mut self.scratch_chunk_lat);
        lat.resize(n * r, 0.0);
        crate::tensor::ops::matmul_tn(ks, &self.u_t.data, &mut lat, n, kvd, r);
        lat
    }

    /// Is `pos` still inside the fp32 recent-key ring?
    fn in_recent(&self, pos: usize) -> bool {
        pos + self.recent_cap >= self.len && self.cfg.recent > 0
    }
}

impl AttentionBackend for SalsAttention {
    fn append(&mut self, k: &[f32], v: &[f32]) {
        let kvd = self.shape.kv_dim();
        assert_eq!(k.len(), kvd);
        assert_eq!(v.len(), kvd);
        let r = self.cfg.rank;
        let pos = self.len;
        // Latent projection of the pre-RoPE key (Algorithm 1, line 2).
        let start = self.latent_keys.len();
        self.latent_keys.resize(start + r, 0.0);
        self.projector.project(k, &mut self.latent_keys[start..start + r]);
        self.traffic.write_f32(r);
        // fp32 recent-key ring.
        let slot = self.recent_slot(pos);
        self.recent_keys[slot * kvd..(slot + 1) * kvd].copy_from_slice(k);
        // Quantized value store (fp32 recent window inside).
        self.values.append(v);
        self.traffic.write_bytes(self.values.row_read_bytes(pos));
        self.len += 1;
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        let kvd = self.shape.kv_dim();
        let r = self.cfg.rank;
        assert_eq!(q.len(), self.shape.q_dim());
        assert!(self.len > 0, "attend on empty cache");
        let pos = self.len - 1;

        // ---- Stage 2: latent scoring (lines 3–4) ----
        self.compute_scores(q);

        // ---- Stage 2: top-k + sink/recent merge (line 5) ----
        let scores = std::mem::take(&mut self.scratch_scores);
        top_k_indices_into(&scores, self.cfg.critical, &mut self.scratch_idx);
        self.scratch_scores = scores;
        let sel = merge_selection(self.len, self.cfg.sink, self.cfg.recent, &self.scratch_idx);
        let n_sel = sel.len();

        // ---- Stage 3: selective reconstruction + RoPE (lines 6–7) ----
        // Batched reconstruction: gather selected latents contiguously and
        // run ONE (n_sel, r) @ (r, kvd) matmul whose inner loop is a
        // unit-stride kvd-length axpy (SIMD), then overwrite recent rows
        // with their exact fp32 keys (high-precision window).
        self.scratch_keys.resize(n_sel * kvd, 0.0);
        self.scratch_vals.resize(n_sel * kvd, 0.0);
        self.scratch_lat.resize(n_sel * r, 0.0);
        for (row, &j) in sel.iter().enumerate() {
            self.scratch_lat[row * r..(row + 1) * r]
                .copy_from_slice(&self.latent_keys[j * r..(j + 1) * r]);
        }
        crate::tensor::ops::matmul(
            &self.scratch_lat,
            &self.u_t.data,
            &mut self.scratch_keys,
            n_sel,
            r,
            kvd,
        );
        for (row, &j) in sel.iter().enumerate() {
            let kdst_range = row * kvd..(row + 1) * kvd;
            if self.in_recent(j) {
                // High-precision window: exact pre-RoPE key, no reconstruction.
                let slot = self.recent_slot(j);
                self.scratch_keys[kdst_range.clone()]
                    .copy_from_slice(&self.recent_keys[slot * kvd..(slot + 1) * kvd]);
                self.traffic.read_f32(kvd);
            } else {
                self.traffic.read_f32(r);
            }
            // RoPE at the token's original position (line 7).
            self.rope.apply_multihead(&mut self.scratch_keys[kdst_range], j);
            // Values: dequantize (recent rows are exact fp32).
            self.values.get(j, &mut self.scratch_vals[row * kvd..(row + 1) * kvd]);
            self.traffic.read_bytes(self.values.row_read_bytes(j));
        }

        // RoPE the query at its position.
        self.scratch_qr.clear();
        self.scratch_qr.extend_from_slice(q);
        self.rope.apply_multihead(&mut self.scratch_qr, pos);

        // ---- Stage 3: exact sparse attention (lines 8–9, Eq. 5) ----
        super::exact_attention(
            &self.shape,
            &self.scratch_qr,
            &self.scratch_keys,
            &self.scratch_vals,
            n_sel,
            out,
        );
    }

    fn append_batch(&mut self, ks: &[f32], vs: &[f32], n: usize) {
        let kvd = self.shape.kv_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        let r = self.cfg.rank;
        let lat = self.project_chunk(ks, n);
        for t in 0..n {
            self.push_token(
                &lat[t * r..(t + 1) * r],
                &ks[t * kvd..(t + 1) * kvd],
                &vs[t * kvd..(t + 1) * kvd],
            );
        }
        self.scratch_chunk_lat = lat;
    }

    fn forward_batch(&mut self, ks: &[f32], vs: &[f32], qs: &[f32], n: usize, out: &mut [f32]) {
        let kvd = self.shape.kv_dim();
        let qd = self.shape.q_dim();
        assert!(n > 0);
        assert_eq!(ks.len(), n * kvd);
        assert_eq!(vs.len(), n * kvd);
        assert_eq!(qs.len(), n * qd);
        assert_eq!(out.len(), n * qd);
        let r = self.cfg.rank;
        // Chunk-level batched projection; per-token state pushes + attends
        // (see module docs: the recent ring / high-precision window are
        // position-relative, so interleaving is what preserves exactness).
        let lat = self.project_chunk(ks, n);
        for t in 0..n {
            self.push_token(
                &lat[t * r..(t + 1) * r],
                &ks[t * kvd..(t + 1) * kvd],
                &vs[t * kvd..(t + 1) * kvd],
            );
            self.attend(&qs[t * qd..(t + 1) * qd], &mut out[t * qd..(t + 1) * qd]);
        }
        self.scratch_chunk_lat = lat;
    }

    fn end_prefill(&mut self) {
        // Chunk-latent staging is (chunk, r) — small, but decode never
        // touches it; release for symmetry with FullAttention.
        self.scratch_chunk_lat = Vec::new();
    }

    fn len(&self) -> usize {
        self.len
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn kv_bytes(&self) -> usize {
        self.latent_keys.len() * 4 + self.recent_keys.len() * 4 + self.values.nbytes()
    }

    fn footprint(&self) -> FootprintModel {
        // Latent keys grow at rank·4 B/token; values at the quant store's
        // frozen rate. Fixed: the pre-allocated fp32 recent-key ring plus
        // the expected excess of the store's fp32 tail over the frozen
        // rate — length-independent terms, so the asymptotic rate reflects
        // the §5.1 compression ratio admission is meant to exploit.
        FootprintModel::linear(
            self.recent_cap * self.shape.kv_dim() * 4 + self.values.tail_excess_bytes(),
            self.cfg.rank * 4 + self.values.frozen_row_bytes(),
        )
    }

    fn name(&self) -> &'static str {
        "sals"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::FullAttention;
    use crate::lowrank::Calibrator;
    use crate::util::rng::Rng;

    /// Build a projector from keys with global low-rank structure.
    fn make_projector(kv_dim: usize, rank: usize, true_rank: usize, rng: &mut Rng) -> Projector {
        let basis: Vec<Vec<f32>> = (0..true_rank).map(|_| rng.normal_vec(kv_dim, 1.0)).collect();
        let mut cal = Calibrator::new(kv_dim);
        let mut row = vec![0.0f32; kv_dim];
        for _ in 0..600 {
            row.fill(0.0);
            for b in &basis {
                let c = rng.normal_f32();
                crate::tensor::ops::axpy(c, b, &mut row);
            }
            for v in row.iter_mut() {
                *v += rng.normal_f32() * 0.02;
            }
            cal.add_key(&row);
        }
        cal.fit(rank).unwrap()
    }

    /// Draw a key from the same low-rank family used in make_projector.
    fn lowrank_sampler(kv_dim: usize, true_rank: usize, seed: u64) -> impl FnMut(&mut Rng) -> Vec<f32> {
        let mut brng = Rng::new(seed);
        let basis: Vec<Vec<f32>> = (0..true_rank).map(|_| brng.normal_vec(kv_dim, 1.0)).collect();
        move |rng: &mut Rng| {
            let mut row = vec![0.0f32; kv_dim];
            for b in &basis {
                let c = rng.normal_f32();
                crate::tensor::ops::axpy(c, b, &mut row);
            }
            row
        }
    }

    fn cfg_small(rank: usize) -> SalsConfig {
        SalsConfig {
            rank,
            r_star: rank / 2,
            sink: 2,
            recent: 8,
            critical: 16,
            v_bits: Bits::B4,
            group: 8,
        }
    }

    #[test]
    fn matches_full_attention_when_selection_covers_all() {
        // critical >= seq and exact projector rank -> SALS == full attention.
        let shape = AttnShape::mha(2, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(71);
        // Full-rank projector (rank == dim): reconstruction is exact.
        let mut cal = Calibrator::new(kvd);
        for _ in 0..200 {
            cal.add_key(&rng.normal_vec(kvd, 1.0));
        }
        let proj = cal.fit(kvd).unwrap();
        let cfg = SalsConfig {
            rank: kvd,
            r_star: kvd,
            sink: 0,
            recent: 64, // whole sequence high-precision -> values exact too
            critical: 64,
            v_bits: Bits::B8,
            group: 8,
        };
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let mut full = FullAttention::new(shape);
        for _ in 0..30 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        sals.attend(&q, &mut o1);
        full.attend(&q, &mut o2);
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn close_to_full_on_low_rank_keys() {
        let shape = AttnShape::mha(2, 8, 256);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(73);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut sample = lowrank_sampler(kvd, 4, 73);
        let mut sals = SalsAttention::new(shape, cfg_small(8), proj);
        let mut full = FullAttention::new(shape);
        for _ in 0..100 {
            let k = sample(&mut rng);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        sals.attend(&q, &mut o1);
        full.attend(&q, &mut o2);
        let err = crate::util::stats::rel_l2(&o1, &o2);
        assert!(err < 0.35, "rel err {err}");
        let cos = crate::util::stats::cosine(&o1, &o2);
        assert!(cos > 0.9, "cosine {cos}");
    }

    #[test]
    fn traffic_much_lower_than_full() {
        let shape = AttnShape::mha(4, 16, 1024);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(75);
        let proj = make_projector(kvd, kvd / 4, 8, &mut rng);
        let cfg = SalsConfig::sals_25(kvd, 4, 32, 16);
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let mut full = FullAttention::new(shape);
        let mut sample = lowrank_sampler(kvd, 8, 75);
        for _ in 0..512 {
            let k = sample(&mut rng);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0; shape.q_dim()];
        let s0 = sals.traffic();
        sals.attend(&q, &mut out);
        let f0 = full.traffic();
        full.attend(&q, &mut out);
        let sals_read = sals.traffic().read - s0.read;
        let full_read = full.traffic().read - f0.read;
        assert!(
            (sals_read as f64) < full_read as f64 / 4.0,
            "sals {sals_read} vs full {full_read}"
        );
    }

    #[test]
    fn cache_bytes_compressed() {
        let shape = AttnShape::mha(4, 16, 512);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(77);
        let proj = make_projector(kvd, kvd / 4, 8, &mut rng);
        let cfg = SalsConfig::sals_25(kvd, 4, 32, 16);
        let mut sals = SalsAttention::new(shape, cfg, proj);
        let mut full = FullAttention::new(shape);
        for _ in 0..256 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
            full.append(&k, &v);
        }
        // Paper Table 2: SALS-25% comp ratio 0.28 vs fp16 baseline.
        // Ours is fp32-relative; latents (r=kvd/4) + 4-bit values + windows
        // must land well under 50% of the dense cache.
        assert!(
            sals.kv_bytes() * 2 < full.kv_bytes(),
            "sals {} vs full {}",
            sals.kv_bytes(),
            full.kv_bytes()
        );
    }

    #[test]
    fn selection_includes_sink_and_recent() {
        let shape = AttnShape::mha(1, 8, 128);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(79);
        let proj = make_projector(kvd, 4, 4, &mut rng);
        let cfg = SalsConfig {
            rank: 4,
            r_star: 2,
            sink: 2,
            recent: 4,
            critical: 2,
            v_bits: Bits::B4,
            group: 4,
        };
        let mut sals = SalsAttention::new(shape, cfg, proj);
        for _ in 0..50 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let scores = sals.latent_scores(&q);
        let idx = crate::tensor::top_k_indices(&scores, 2);
        let sel = merge_selection(50, 2, 4, &idx);
        assert!(sel.contains(&0) && sel.contains(&1), "sink missing: {sel:?}");
        for t in 46..50 {
            assert!(sel.contains(&t), "recent {t} missing: {sel:?}");
        }
    }

    #[test]
    fn batched_forward_matches_sequential_loop() {
        // The staged batched path must track the sequential state machine:
        // same stores, same traffic, same outputs (modulo the one-matmul
        // projection's fp reordering, ~1e-7).
        let shape = AttnShape::mha(2, 8, 256);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(83);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut sample = lowrank_sampler(kvd, 4, 83);
        // critical covers the whole sequence so the comparison is immune to
        // top-k order flips from the ~1e-7 projection-reordering jitter;
        // ring wraps and quant-group boundaries are still fully exercised.
        let cfg = SalsConfig { critical: 64, ..cfg_small(8) };
        let mut seq = SalsAttention::new(shape, cfg.clone(), proj.clone());
        let mut bat = SalsAttention::new(shape, cfg, proj);
        // Warm prefix through the scalar path on both.
        for _ in 0..6 {
            let k = sample(&mut rng);
            let v = rng.normal_vec(kvd, 1.0);
            seq.append(&k, &v);
            bat.append(&k, &v);
        }
        let n = 40; // spans several quant groups and ring wraps
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for _ in 0..n {
            ks.extend(sample(&mut rng));
            vs.extend(rng.normal_vec(kvd, 1.0));
        }
        let qs = rng.normal_vec(n * shape.q_dim(), 1.0);
        let qd = shape.q_dim();
        let mut o_seq = vec![0.0f32; n * qd];
        for t in 0..n {
            seq.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
            seq.attend(&qs[t * qd..(t + 1) * qd], &mut o_seq[t * qd..(t + 1) * qd]);
        }
        let mut o_bat = vec![0.0f32; n * qd];
        bat.forward_batch(&ks, &vs, &qs, n, &mut o_bat);
        assert_eq!(seq.len, bat.len);
        assert_eq!(seq.kv_bytes(), bat.kv_bytes());
        for (a, b) in o_seq.iter().zip(&o_bat) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        for (a, b) in seq.latent_keys.iter().zip(&bat.latent_keys) {
            assert!((a - b).abs() < 1e-4, "latent {a} vs {b}");
        }
    }

    #[test]
    fn append_batch_matches_append_loop() {
        let shape = AttnShape::mha(1, 8, 128);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(89);
        let proj = make_projector(kvd, 4, 4, &mut rng);
        let cfg = cfg_small(4);
        let mut a = SalsAttention::new(shape, cfg.clone(), proj.clone());
        let mut b = SalsAttention::new(shape, cfg, proj);
        let n = 17;
        let ks = rng.normal_vec(n * kvd, 1.0);
        let vs = rng.normal_vec(n * kvd, 1.0);
        a.append_batch(&ks, &vs, n);
        for t in 0..n {
            b.append(&ks[t * kvd..(t + 1) * kvd], &vs[t * kvd..(t + 1) * kvd]);
        }
        assert_eq!(a.len, b.len);
        assert_eq!(a.kv_bytes(), b.kv_bytes());
        assert_eq!(a.traffic().written, b.traffic().written);
        for (x, y) in a.latent_keys.iter().zip(&b.latent_keys) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        assert_eq!(a.recent_keys, b.recent_keys);
    }

    #[test]
    fn gqa_query_pooling_runs() {
        let shape = AttnShape::gqa(4, 2, 8, 64);
        let kvd = shape.kv_dim();
        let mut rng = Rng::new(81);
        let proj = make_projector(kvd, 8, 4, &mut rng);
        let mut sals = SalsAttention::new(shape, cfg_small(8), proj);
        for _ in 0..20 {
            let k = rng.normal_vec(kvd, 1.0);
            let v = rng.normal_vec(kvd, 1.0);
            sals.append(&k, &v);
        }
        let q = rng.normal_vec(shape.q_dim(), 1.0);
        let mut out = vec![0.0; shape.q_dim()];
        sals.attend(&q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
